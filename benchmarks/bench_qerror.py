"""Paper App F Table 6 + App D (Fig 4) analogue: quantization error by data
type, and the Adam-update error analysis.  The k-bit sweep (DESIGN.md §9)
extends Table 6 down the bitwidth axis and persists a per-bitwidth table to
BENCH_qerror.json so the error/memory trade-off is tracked over PRs."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_bench_json, emit
from repro.core import blockwise as bw
from repro.core import qmap

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_qerror.json")


def _adam_states(n=200_000, seed=0):
    """Synthetic Adam states with realistic ranges: m ~ heavy-tailed signed,
    r ~ lognormal spanning ~5 orders of magnitude (paper §2.2)."""
    rng = np.random.RandomState(seed)
    m = rng.randn(n).astype(np.float32) * 10 ** rng.uniform(-6, -2, n)
    r = (10 ** rng.uniform(-10, -5, n)).astype(np.float32)
    return jnp.asarray(m), jnp.asarray(r)


def bench_table6_dtype_error():
    """Mean relative Adam error + absolute quantization error for the first
    Adam state, per quantization data type (tensor-wise, matching App F)."""
    m, r = _adam_states()
    eps = 1e-8
    u32 = m / (jnp.sqrt(r) + eps)
    for name in ["linear", "quantile_normal", "inverse_dynamic", "dynamic"]:
        cb_s = jnp.asarray(qmap.get_qmap(name, True))
        # App F Table 6 quantizes the FIRST Adam state only (tensor-wise,
        # one block); the second state stays exact.
        cm, am = bw.quantize_blocks(m.reshape(1, -1), cb_s)
        md = bw.dequantize_blocks(cm, am, cb_s).reshape(-1)
        u8 = md / (jnp.sqrt(r) + eps)
        rel = float(jnp.mean(jnp.abs(u8 - u32) / (jnp.abs(u32) + 1e-12)))
        abs_q = float(jnp.mean(jnp.abs(md - m)))
        emit(f"table6/rel_adam_error/{name}", 0.0, f"{rel * 100:.1f}%")
        emit(f"table6/abs_quant_error/{name}", 0.0, f"{abs_q:.3e}")


def bench_blockwise_vs_tensorwise():
    """The §2.1 claim quantified: block-wise beats tensor-wise in the
    presence of outliers."""
    m, _ = _adam_states()
    m = m.at[17].set(5.0)     # inject outlier
    cb = jnp.asarray(qmap.get_qmap("dynamic", True))
    cm, am = bw.quantize_blocks(m.reshape(1, -1), cb)
    err_t = float(jnp.mean(jnp.abs(bw.dequantize_blocks(cm, am, cb).reshape(-1) - m)))
    qt = bw.quantize(m, block_size=2048)
    err_b = float(jnp.mean(jnp.abs(bw.dequantize(qt) - m)))
    emit("appD/abs_err_tensorwise_outlier", 0.0, f"{err_t:.3e}")
    emit("appD/abs_err_blockwise_outlier", 0.0, f"{err_b:.3e}")
    emit("appD/blockwise_improvement", 0.0, f"{err_t / err_b:.1f}x")


def bench_appD_error_by_code():
    """App D/Fig 5: distribution of errors across the 256 code values —
    verifies dynamic quantization has small errors at both ends."""
    m, _ = _adam_states(seed=3)
    for name in ["dynamic", "quantile_normal"]:
        cb = jnp.asarray(qmap.get_qmap(name, True))
        cm, am = bw.quantize_blocks(m.reshape(1, -1), cb)
        md = bw.dequantize_blocks(cm, am, cb).reshape(-1)
        err = np.abs(np.asarray(md - m))
        codes = np.asarray(cm).reshape(-1)
        by_code = np.zeros(256)
        for c in range(256):
            sel = codes == c
            if sel.any():
                by_code[c] = err[sel].mean()
        # report tails vs middle
        emit(f"appD/err_small_codes/{name}", 0.0,
             f"{by_code[120:136].mean():.2e}")
        emit(f"appD/err_large_codes/{name}", 0.0,
             f"{np.concatenate([by_code[:8], by_code[-8:]]).mean():.2e}")


def bench_kbit_error_table():
    """Per-bitwidth quantization/Adam-update error (block-wise, B=2048),
    emitted as CSV rows *and* appended to BENCH_qerror.json."""
    m, r = _adam_states()
    eps = 1e-8
    u32 = m / (jnp.sqrt(r) + eps)
    table = {}
    for bits in (4, 5, 6, 8):
        cb_s = jnp.asarray(qmap.get_qmap("dynamic", True, bits=bits))
        blocks = bw.pad_to_blocks(m, 2048)
        cm, am = bw.quantize_blocks(blocks, cb_s)
        md = bw.dequantize_blocks(cm, am, cb_s).reshape(-1)[:m.shape[0]]
        u_k = md / (jnp.sqrt(r) + eps)
        rel = float(jnp.mean(jnp.abs(u_k - u32) / (jnp.abs(u32) + 1e-12)))
        abs_q = float(jnp.mean(jnp.abs(md - m)))
        table[str(bits)] = {"rel_adam_error": rel, "abs_quant_error": abs_q,
                            "levels": 1 << bits}
        emit(f"kbit/rel_adam_error/{bits}bit", 0.0, f"{rel * 100:.1f}%")
        emit(f"kbit/abs_quant_error/{bits}bit", 0.0, f"{abs_q:.3e}")
    path = append_bench_json(BENCH_JSON, {
        "bench": "kbit_error_table",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "qmap": "dynamic", "block_size": 2048,
        "per_bitwidth": table,
    })
    emit("kbit/error_table_json", 0.0, path)


def bench_muon_kbit_error_table():
    """Muon momentum quantization error per bitwidth, pre- vs post-
    orthogonalization (DESIGN.md §11).  The question the Newton–Schulz
    update raises that element-wise optimizers don't: does block-wise
    rounding of the momentum *matrix* get amplified by orth()?  Measured
    as relative Frobenius error of the dequantized momentum (pre) and of
    NS(5) applied to it vs NS(5) of the exact momentum (post); appended
    to BENCH_qerror.json next to the element-wise k-bit table so the
    4/5/6/8-bit gate covers the matrix-shaped state."""
    from repro.kernels import ref as kref

    rng = np.random.RandomState(7)
    rows, cols = 256, 1024
    # heavy-tailed momentum matrix with layer-like row structure
    m = (rng.randn(rows, cols) *
         10 ** rng.uniform(-4, -2, (rows, 1))).astype(np.float32)
    m = jnp.asarray(m)
    o_exact = kref.newton_schulz_ref(m)
    on_exact = float(jnp.sqrt(jnp.sum(o_exact * o_exact)))
    mn = float(jnp.sqrt(jnp.sum(m * m)))
    table = {}
    for bits in (4, 5, 6, 8):
        cb = jnp.asarray(qmap.get_qmap("dynamic", True, bits=bits))
        blocks = bw.pad_to_blocks(m.reshape(-1), 2048)
        cm, am = bw.quantize_blocks(blocks, cb)
        md = bw.dequantize_blocks(cm, am, cb).reshape(-1)[:m.size]
        md = md.reshape(rows, cols)
        pre = float(jnp.sqrt(jnp.sum((md - m) ** 2))) / mn
        o_q = kref.newton_schulz_ref(md)
        post = float(jnp.sqrt(jnp.sum((o_q - o_exact) ** 2))) / on_exact
        table[str(bits)] = {"rel_err_pre_orth": pre,
                            "rel_err_post_orth": post}
        emit(f"muon/rel_err_pre_orth/{bits}bit", 0.0, f"{pre * 100:.2f}%")
        emit(f"muon/rel_err_post_orth/{bits}bit", 0.0, f"{post * 100:.2f}%")
    path = append_bench_json(BENCH_JSON, {
        "bench": "muon_kbit_error_table", "algo": "muon",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "qmap": "dynamic", "block_size": 2048,
        "shape": [rows, cols],
        "per_bitwidth": table,
    })
    emit("muon/error_table_json", 0.0, path)


def main():
    bench_table6_dtype_error()
    bench_blockwise_vs_tensorwise()
    bench_appD_error_by_code()
    bench_kbit_error_table()
    bench_muon_kbit_error_table()


if __name__ == "__main__":
    main()
