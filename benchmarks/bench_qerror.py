"""Paper App F Table 6 + App D (Fig 4) analogue: quantization error by data
type, and the Adam-update error analysis."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import blockwise as bw
from repro.core import qmap


def _adam_states(n=200_000, seed=0):
    """Synthetic Adam states with realistic ranges: m ~ heavy-tailed signed,
    r ~ lognormal spanning ~5 orders of magnitude (paper §2.2)."""
    rng = np.random.RandomState(seed)
    m = rng.randn(n).astype(np.float32) * 10 ** rng.uniform(-6, -2, n)
    r = (10 ** rng.uniform(-10, -5, n)).astype(np.float32)
    return jnp.asarray(m), jnp.asarray(r)


def bench_table6_dtype_error():
    """Mean relative Adam error + absolute quantization error for the first
    Adam state, per quantization data type (tensor-wise, matching App F)."""
    m, r = _adam_states()
    eps = 1e-8
    u32 = m / (jnp.sqrt(r) + eps)
    for name in ["linear", "quantile_normal", "inverse_dynamic", "dynamic"]:
        cb_s = jnp.asarray(qmap.get_qmap(name, True))
        # App F Table 6 quantizes the FIRST Adam state only (tensor-wise,
        # one block); the second state stays exact.
        cm, am = bw.quantize_blocks(m.reshape(1, -1), cb_s)
        md = bw.dequantize_blocks(cm, am, cb_s).reshape(-1)
        u8 = md / (jnp.sqrt(r) + eps)
        rel = float(jnp.mean(jnp.abs(u8 - u32) / (jnp.abs(u32) + 1e-12)))
        abs_q = float(jnp.mean(jnp.abs(md - m)))
        emit(f"table6/rel_adam_error/{name}", 0.0, f"{rel * 100:.1f}%")
        emit(f"table6/abs_quant_error/{name}", 0.0, f"{abs_q:.3e}")


def bench_blockwise_vs_tensorwise():
    """The §2.1 claim quantified: block-wise beats tensor-wise in the
    presence of outliers."""
    m, _ = _adam_states()
    m = m.at[17].set(5.0)     # inject outlier
    cb = jnp.asarray(qmap.get_qmap("dynamic", True))
    cm, am = bw.quantize_blocks(m.reshape(1, -1), cb)
    err_t = float(jnp.mean(jnp.abs(bw.dequantize_blocks(cm, am, cb).reshape(-1) - m)))
    qt = bw.quantize(m, block_size=2048)
    err_b = float(jnp.mean(jnp.abs(bw.dequantize(qt) - m)))
    emit("appD/abs_err_tensorwise_outlier", 0.0, f"{err_t:.3e}")
    emit("appD/abs_err_blockwise_outlier", 0.0, f"{err_b:.3e}")
    emit("appD/blockwise_improvement", 0.0, f"{err_t / err_b:.1f}x")


def bench_appD_error_by_code():
    """App D/Fig 5: distribution of errors across the 256 code values —
    verifies dynamic quantization has small errors at both ends."""
    m, _ = _adam_states(seed=3)
    for name in ["dynamic", "quantile_normal"]:
        cb = jnp.asarray(qmap.get_qmap(name, True))
        cm, am = bw.quantize_blocks(m.reshape(1, -1), cb)
        md = bw.dequantize_blocks(cm, am, cb).reshape(-1)
        err = np.abs(np.asarray(md - m))
        codes = np.asarray(cm).reshape(-1)
        by_code = np.zeros(256)
        for c in range(256):
            sel = codes == c
            if sel.any():
                by_code[c] = err[sel].mean()
        # report tails vs middle
        emit(f"appD/err_small_codes/{name}", 0.0,
             f"{by_code[120:136].mean():.2e}")
        emit(f"appD/err_large_codes/{name}", 0.0,
             f"{np.concatenate([by_code[:8], by_code[-8:]]).mean():.2e}")


def main():
    bench_table6_dtype_error()
    bench_blockwise_vs_tensorwise()
    bench_appD_error_by_code()


if __name__ == "__main__":
    main()
