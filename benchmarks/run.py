"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --only table3,roofline
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: tiny speed sweep
                                                     # incl. the fused-update
                                                     # interpret path
"""
from __future__ import annotations

import argparse
import inspect
import sys

SUITES = {
    "memory": ("benchmarks.bench_memory", "Tables 1+2 (memory)"),
    "speed": ("benchmarks.bench_speed", "Table 5 (optimizer runtime)"),
    "qerror": ("benchmarks.bench_qerror", "Table 6 + App D (quant error)"),
    "ablation": ("benchmarks.bench_ablation",
                 "Table 3 + App H/I + Fig 3 (training ablations)"),
    "roofline": ("benchmarks.bench_roofline", "Dry-run roofline table"),
    "step_overlap": ("benchmarks.bench_step_overlap",
                     "Optimizer-exposed ms/step: sequential vs overlapped "
                     "ZeRO-2 (DESIGN.md §13)"),
    "telemetry": ("benchmarks.bench_telemetry",
                  "Telemetry JSONL + qhealth probe smoke (DESIGN.md §14)"),
    "analyze": ("benchmarks.bench_analyze",
                "Static VMEM budget table -> BENCH_speed.json "
                "(DESIGN.md §15)"),
    "serve": ("benchmarks.bench_serve",
              "Paged quantized KV serving: bytes/token + continuous-"
              "batching tokens/s + p50/p99 (DESIGN.md §17)"),
}

# Suites a --smoke run exercises (fast enough for CI, covers the kernels).
SMOKE_SUITES = ("speed",)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated suite names")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI sweep (speed suite at tiny sizes, "
                         "fused kernels on the Pallas interpret path)")
    ap.add_argument("--bits", type=int, default=None,
                    help="also run the packed k-bit legs (4/5/6/8) of any "
                         "suite that supports a bitwidth sweep")
    ap.add_argument("--algo", type=str, default=None,
                    help="also run the algorithm-specific legs of any "
                         "suite that supports them (e.g. --algo muon runs "
                         "the Newton–Schulz matrix-optimizer sweep even "
                         "under --smoke; DESIGN.md §11)")
    ap.add_argument("--partition", action="store_true",
                    help="also run the ZeRO-1 partitioned-state legs "
                         "(per-device owned bytes + span launches vs "
                         "shard count, even under --smoke; DESIGN.md §12)")
    ap.add_argument("--overlap", action="store_true",
                    help="also run the step_overlap suite (optimizer-"
                         "exposed ms + ZeRO-2 peak grad bytes on a "
                         "4-device host mesh, even under --smoke; "
                         "DESIGN.md §13)")
    ap.add_argument("--analyze", action="store_true",
                    help="also run the static-analysis suite: the Pallas "
                         "VMEM budget table recorded to BENCH_speed.json "
                         "(headroom per kernel config), even under "
                         "--smoke (DESIGN.md §15)")
    ap.add_argument("--serve", action="store_true",
                    help="also run the serving suite: paged 8/4-bit KV "
                         "bytes/token and continuous-batching vs static-"
                         "bucket tokens/s with their gates (4-bit <= "
                         "0.30x fp16 bytes; continuous >= 1.5x static), "
                         "even under --smoke (DESIGN.md §17)")
    ap.add_argument("--telemetry", action="store_true",
                    help="also run the telemetry legs: the JSONL/qhealth "
                         "smoke suite (schema-validated probe artifact, "
                         "4-device mesh when forced) and the speed "
                         "suite's telemetry-overhead gates, even under "
                         "--smoke (DESIGN.md §14)")
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
    elif args.smoke:
        names = list(SMOKE_SUITES)
    else:
        names = list(SUITES)
    if args.overlap and "step_overlap" not in names:
        names.append("step_overlap")
    if args.telemetry and "telemetry" not in names:
        names.append("telemetry")
    if args.analyze and "analyze" not in names:
        names.append("analyze")
    if args.serve and "serve" not in names:
        names.append("serve")
    print("name,us_per_call,derived")
    for n in names:
        mod_name, desc = SUITES[n]
        print(f"# === {n}: {desc} ===")
        mod = __import__(mod_name, fromlist=["main"])
        kwargs = {}
        params = inspect.signature(mod.main).parameters
        if args.smoke and "smoke" in params:
            kwargs["smoke"] = True
        if args.bits is not None and "bits" in params:
            kwargs["bits"] = args.bits
        if args.algo is not None and "algo" in params:
            kwargs["algo"] = args.algo
        if args.partition and "partition" in params:
            kwargs["partition"] = True
        if args.telemetry and "telemetry" in params:
            kwargs["telemetry"] = True
        try:
            mod.main(**kwargs)
        except Exception as e:  # keep the harness running
            print(f"{n}/ERROR,0,{e!r}", file=sys.stderr)
            print(f"{n}/ERROR,0,{e!r}")
            if args.smoke:
                raise SystemExit(1)  # CI must fail loudly


if __name__ == "__main__":
    main()
