"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --only table3,roofline
"""
from __future__ import annotations

import argparse
import sys

SUITES = {
    "memory": ("benchmarks.bench_memory", "Tables 1+2 (memory)"),
    "speed": ("benchmarks.bench_speed", "Table 5 (optimizer runtime)"),
    "qerror": ("benchmarks.bench_qerror", "Table 6 + App D (quant error)"),
    "ablation": ("benchmarks.bench_ablation",
                 "Table 3 + App H/I + Fig 3 (training ablations)"),
    "roofline": ("benchmarks.bench_roofline", "Dry-run roofline table"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    for n in names:
        mod_name, desc = SUITES[n]
        print(f"# === {n}: {desc} ===")
        mod = __import__(mod_name, fromlist=["main"])
        try:
            mod.main()
        except Exception as e:  # keep the harness running
            print(f"{n}/ERROR,0,{e!r}", file=sys.stderr)
            print(f"{n}/ERROR,0,{e!r}")


if __name__ == "__main__":
    main()
