"""Paper Tables 1 & 2 analogue: optimizer-state memory per arch, and the
largest-trainable-model table for fixed memory budgets."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, small_lm
from repro.configs import base
from repro.core.optim import OptimConfig, make_optimizer


def bench_table1_memory():
    """Analytic bytes/param (+GB for the full arch) per optimizer; the
    'Mem saved' column of Table 1 for every assigned arch."""
    opts = {
        "adam32": OptimConfig(algo="adam", bits=32),
        "adam8": OptimConfig(algo="adam", bits=8),
        "momentum32": OptimConfig(algo="momentum", bits=32),
        "momentum8": OptimConfig(algo="momentum", bits=8),
    }
    for arch in base.list_archs():
        n = base.get_config(arch).param_count()
        gb32 = opts["adam32"].state_bytes_per_param() * n / 2**30
        gb8 = opts["adam8"].state_bytes_per_param() * n / 2**30
        emit(f"table1/state_gb/{arch}/adam32", 0.0, f"{gb32:.2f}GB")
        emit(f"table1/state_gb/{arch}/adam8", 0.0, f"{gb8:.2f}GB")
        emit(f"table1/mem_saved/{arch}", 0.0, f"{gb32 - gb8:.2f}GB")


def bench_table1_measured():
    """Measured state bytes on a reduced config (validates the analytic
    column; ratio ~3.99x for Adam)."""
    cfg, _ = small_lm()
    from repro.train import loop as L
    res = {}
    for name in ["adam32", "adam8", "adafactor32"]:
        kw = {} if name == "adafactor32" else {"min_8bit_size": 1024}
        opt = make_optimizer(name, lr=1e-3, **kw)
        state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        res[name] = opt.state_bytes(state.opt_state)["state_bytes"]
        emit(f"table1/measured_state_bytes/{name}", 0.0, str(res[name]))
    emit("table1/measured_ratio_adam32_over_adam8", 0.0,
         f"{res['adam32'] / res['adam8']:.2f}x")


def bench_kbit_state_bytes():
    """k-bit code-format sweep (DESIGN.md §9): measured packed state bytes
    per bitwidth on the reduced config.  The 4-bit/8-bit ratio is the
    headline — packed 4-bit states must be ≤ 0.55x the 8-bit bytes."""
    cfg, _ = small_lm()
    from repro.train import loop as L
    res = {}
    for bits in (4, 5, 6, 8):
        # Per-slot: k-bit first moment, 8-bit second (Li et al. 2023) and
        # the pure-k point.  Fully quantized state (no embedding override)
        # so the ratio measures the code format, not the fp32 leaves.
        pairs = [(f"m{bits}_r8", (bits, 8))]
        if bits != 8:
            pairs.append((f"m{bits}_r{bits}", bits))
        for tag, sb in pairs:
            opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024,
                                 override_32bit=lambda p: False,
                                 state_bits=sb)
            state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
            res[tag] = opt.state_bytes(state.opt_state)["state_bytes"]
            emit(f"kbit/measured_state_bytes/{tag}", 0.0, str(res[tag]))
    ratio = res["m4_r4"] / res["m8_r8"]
    emit("kbit/ratio_4bit_over_8bit", 0.0, f"{ratio:.3f}x")
    assert ratio <= 0.55, ratio


def bench_table2_largest_finetunable():
    """Paper Table 2: largest model trainable at batch 1 for a given memory
    budget, 32-bit vs 8-bit Adam.  Accounting: bf16 weights+grads (4B/param)
    + optimizer states (8B vs 2.0B/param); activations excluded (batch 1)."""
    budgets = [6, 11, 16, 24, 80]
    archs = sorted(base.list_archs(),
                   key=lambda a: base.get_config(a).param_count())
    for gb in budgets:
        fits = {"adam32": None, "adam8": None}
        for name, state_b in [("adam32", 8.0),
                              ("adam8", 2 * (1 + 4 / 2048))]:
            for arch in archs:
                n = base.get_config(arch).param_count()
                need = n * (2 + 2 + state_b) / 2**30
                if need <= gb:
                    fits[name] = (arch, n)
        for name, hit in fits.items():
            label = f"{hit[0]}({hit[1]/1e9:.1f}B)" if hit else "none"
            emit(f"table2/largest_at_{gb}GB/{name}", 0.0, label)


def main():
    bench_table1_memory()
    bench_table1_measured()
    bench_kbit_state_bytes()
    bench_table2_largest_finetunable()


if __name__ == "__main__":
    main()
