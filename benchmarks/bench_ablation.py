"""Paper Table 3 analogue (small scale): ablation of dynamic quantization,
block-wise quantization, and the stable embedding layer; plus App H
(AdaGrad), App I (stable-embedding components) and Fig 3 (sensitivity).

Each row trains the small LM for a few hundred steps on the synthetic
corpus; 'unstable' = diverged/NaN. Scale is laptop-size by necessity — the
ORDERING of rows is the reproduced claim, and the background runs in
EXPERIMENTS.md extend these to longer horizons."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, small_lm, train_lm


def bench_table3_ablation(steps=120):
    cfg, pipe = small_lm()
    cfg_nostab = dataclasses.replace(cfg, stable_embedding=False)
    rows = [
        # (label, cfg, optimizer, opt kwargs)
        ("adam32", cfg_nostab, "adam32", {}),
        ("adam32+stable", cfg, "adam32", {}),
        ("adam8_linear", cfg_nostab, "adam8",
         dict(qmap_m="linear", qmap_r="linear",
              override_32bit=lambda p: False)),
        ("adam8_linear+stable", cfg, "adam8",
         dict(qmap_m="linear", qmap_r="linear")),
        ("adam8_dynamic_tensorwise", cfg_nostab, "adam8",
         dict(blockwise_norm=False, override_32bit=lambda p: False)),
        ("adam8_dynamic_blockwise", cfg_nostab, "adam8",
         dict(override_32bit=lambda p: False)),
        ("adam8_dynamic_blockwise+stable", cfg, "adam8", {}),
    ]
    results = {}
    for label, c, opt_name, kw in rows:
        loss, _, div = train_lm(c, pipe, opt_name, steps, lr=1e-2, **kw)
        results[label] = (loss, div)
        emit(f"table3/{label}", 0.0,
             "UNSTABLE" if div else f"loss={loss:.3f}")
    return results


def bench_appH_adagrad(steps=120):
    cfg, pipe = small_lm()
    for name in ["adagrad32", "adagrad8"]:
        loss, _, div = train_lm(cfg, pipe, name, steps, lr=5e-3)
        emit(f"appH/{name}", 0.0, "UNSTABLE" if div else f"loss={loss:.3f}")
    loss, _, div = train_lm(cfg, pipe, "adagrad8", steps, lr=5e-3,
                            stochastic_rounding=False)
    emit("appH/adagrad8_det", 0.0, "UNSTABLE" if div else f"loss={loss:.3f}")


def bench_appI_stable_embedding(steps=120):
    cfg, pipe = small_lm()
    import dataclasses as dc
    for label, c in [
        ("stable(ln+xavier+32bit)", cfg),
        ("baseline_embed", dc.replace(cfg, stable_embedding=False)),
    ]:
        loss, _, div = train_lm(c, pipe, "adam8", steps, lr=1e-2)
        emit(f"appI/{label}", 0.0, "UNSTABLE" if div else f"loss={loss:.3f}")
    # 32-bit state override off (embedding quantized too)
    loss, _, div = train_lm(cfg, pipe, "adam8", steps, lr=1e-2,
                            override_32bit=lambda p: False)
    emit("appI/stable_but_8bit_embed_state", 0.0,
         "UNSTABLE" if div else f"loss={loss:.3f}")


def bench_fig3_sensitivity(steps=80):
    """Fig 3: the 8-vs-32-bit gap should be roughly constant across
    hyperparameters."""
    cfg, pipe = small_lm()
    gaps = []
    for lr in [3e-3, 1e-2]:
        for b1 in [0.9, 0.87]:
            l32, _, _ = train_lm(cfg, pipe, "adam32", steps, lr=lr, beta1=b1)
            l8, _, _ = train_lm(cfg, pipe, "adam8", steps, lr=lr, beta1=b1)
            gap = l8 - l32
            gaps.append(gap)
            emit(f"fig3/lr{lr}_b1{b1}", 0.0,
                 f"adam32={l32:.3f} adam8={l8:.3f} gap={gap:+.3f}")
    spread = max(gaps) - min(gaps)
    emit("fig3/gap_spread", 0.0, f"{spread:.3f} (small => drop-in safe)")


def main():
    bench_table3_ablation()
    bench_appH_adagrad()
    bench_appI_stable_embedding()
    bench_fig3_sensitivity()


if __name__ == "__main__":
    main()
