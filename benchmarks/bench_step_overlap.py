"""Optimizer-exposed step time: sequential ZeRO-1 vs the overlapped
ZeRO-2 path (DESIGN.md §13).

The sequential PR-5 step exposes the whole optimizer phase after the last
microbatch's backward: clip over the replicated grad pytree, the
flatten/concat into the arena layout, the reduce-scatter, one owned-span
fused update per device, and the eager params all-gather.  The overlapped
step moves everything but the tail off the critical path: grads flatten
and reduce-scatter bucket-by-bucket *inside* the accumulation loop
(``OptimConfig.shard_grads`` + ``overlap_buckets``), the per-bucket
updates are mutually independent dispatches that fire as their grads
land, and the params all-gather is deferred to the next step's first use
(``materialize_params=False``).  What stays exposed is the finalization
tail: the buffer-clip reduction plus the LAST bucket's owned-span update.

This bench measures both legs on a 4-device host mesh:

  * ``opt_exposed_ms/sequential`` — wall-clock of the full sequential
    phase (clip + apply + params view) from replicated grads;
  * ``opt_exposed_ms/overlap`` — wall-clock of the buffer-clip plus a
    tail-bucket-sized apply (the same machinery at 1/K of the rows —
    the one dispatch that cannot be hidden), deferred params.

and the static ZeRO-2 peak-gradient accounting
(``grad_buffer_bytes``): owned-span share vs the replicated pytree.
Gates: overlap exposed <= 0.5x sequential, 4-way sharded grad bytes
<= 0.35x replicated.  Bit-exactness of the overlapped path vs the
sequential oracle is asserted here end-to-end and proven leaf-by-leaf in
tests/test_overlap.py.  Appends to BENCH_speed.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_bench_json, emit, time_fn
from benchmarks.bench_speed import BENCH_JSON
from repro.core.optim import make_optimizer
from repro.train import loop as L

SHARDS = 4
BUCKETS = 4


def _model(rows, cols, n_leaves):
    key = jax.random.PRNGKey(0)
    params = {f"layer{i:02d}": jax.random.normal(
        jax.random.fold_in(key, i), (rows, cols)) for i in range(n_leaves)}
    grads = jax.tree_util.tree_map(lambda p: p * 0.01, params)
    return params, grads


def _opt(mesh, **kw):
    return make_optimizer("adam8", lr=1e-3, min_8bit_size=256,
                          override_32bit=lambda p: False, mesh=mesh,
                          partition=True, partition_shards=SHARDS, **kw)


def bench_step_overlap(smoke: bool = False):
    if jax.device_count() < SHARDS:
        emit("step_overlap/SKIP", 0.0,
             f"needs {SHARDS} devices "
             f"(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        return None
    mesh = jax.make_mesh((SHARDS,), ("data",))
    rows, cols, n_leaves = (32, 1024, 12) if smoke else (64, 1024, 12)
    iters = 5 if smoke else 10

    # --- sequential PR-5 leg: replicated grads -> clip -> apply (eager
    # params view): the whole phase is exposed after backward.
    params, grads = _model(rows, cols, n_leaves)
    opt_s = _opt(mesh)
    st = opt_s.init(params)

    def seq(g, s):
        g, _ = L.clip_by_global_norm(g, 1.0)
        return opt_s.apply(g, s)

    seq_ms, (seq_params, seq_state) = time_fn(jax.jit(seq), grads, st,
                                              iters=iters, warmup=2)
    seq_ms /= 1e3   # time_fn returns us

    # --- overlapped leg, exposed tail only.  (a) the buffer-clip
    # finalization: global norm off the owned-span buffer + scale.
    opt_o = _opt(mesh, shard_grads=True, overlap_buckets=BUCKETS)
    st_o = opt_o.init(params)
    buf = opt_o.accumulate_grads(opt_o.init_grad_buffer(st_o), grads)

    def bclip(b):
        gn = opt_o.grad_buffer_norm(b)
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gn, 1e-12))
        return jax.tree_util.tree_map(lambda x: x * scale, b)

    clip_ms, _ = time_fn(jax.jit(bclip), buf, iters=iters, warmup=2)
    clip_ms /= 1e3

    # (b) the last bucket's owned-span update: the K per-bucket dispatches
    # are mutually independent (disjoint static slices — see
    # tests/test_overlap.py), so buckets 0..K-2 fire behind the still-
    # arriving grads and only the final one is on the critical path.
    # Measured as a real end-to-end apply of the same leaf structure at
    # 1/K of the rows, deferred params (no all-gather on the tail).
    params_t, grads_t = _model(rows // BUCKETS, cols, n_leaves)
    opt_t = _opt(mesh, shard_grads=True)
    st_t = opt_t.init(params_t)
    buf_t = opt_t.accumulate_grads(opt_t.init_grad_buffer(st_t), grads_t)
    tail_ms, _ = time_fn(
        jax.jit(lambda b, s: opt_t.apply(b, s, materialize_params=False)[1]),
        buf_t, st_t, iters=iters, warmup=2)
    tail_ms /= 1e3

    ov_ms = clip_ms + tail_ms
    ratio = ov_ms / max(seq_ms, 1e-9)
    emit("step_overlap/sequential/opt_exposed_ms", seq_ms * 1e3,
         f"clip+apply+gather, {SHARDS}-dev mesh")
    emit("step_overlap/overlap/opt_exposed_ms", ov_ms * 1e3,
         f"bufclip {clip_ms:.2f}ms + tail bucket {tail_ms:.2f}ms "
         f"(K={BUCKETS}), {ratio:.3f}x of sequential")
    assert ratio <= 0.5, (
        f"overlapped exposed {ov_ms:.2f}ms > 0.5x sequential {seq_ms:.2f}ms")

    # --- ZeRO-2 peak grad bytes (static accounting, DESIGN.md §13b)
    gbb = opt_o.grad_buffer_bytes(st_o)
    frac = gbb["sharded_grad_bytes"] / max(gbb["replicated_grad_bytes"], 1)
    emit("step_overlap/peak_grad_bytes", float(gbb["sharded_grad_bytes"]),
         f"{frac:.3f}x of replicated ({gbb['replicated_grad_bytes']}B), "
         f"{SHARDS} shards")
    assert frac <= 0.35, gbb

    # --- bit-exactness: overlapped path == sequential oracle end-to-end
    def ov_full(b, s):
        gn = opt_o.grad_buffer_norm(b)
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gn, 1e-12))
        b = jax.tree_util.tree_map(lambda x: x * scale, b)
        return opt_o.apply(b, s, materialize_params=False)[1]

    ov_state = jax.jit(ov_full)(buf, st_o)
    for a, b in zip(jax.tree_util.tree_leaves(seq_params),
                    jax.tree_util.tree_leaves(
                        opt_o.params_view(ov_state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    emit("step_overlap/bit_exact", 0.0, "overlap == sequential oracle")

    entry = {
        "bench": "step_overlap",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke, "backend": jax.default_backend(),
        "devices": SHARDS, "overlap_buckets": BUCKETS,
        "n_leaves": n_leaves,
        "opt_exposed_ms": {"sequential": seq_ms, "overlap": ov_ms,
                           "buffer_clip": clip_ms, "tail_bucket": tail_ms},
        "exposed_ratio": ratio,
        "peak_grad_bytes": gbb["sharded_grad_bytes"],
        "replicated_grad_bytes": gbb["replicated_grad_bytes"],
        "peak_grad_fraction": frac,
    }
    path = append_bench_json(BENCH_JSON, entry)
    emit("step_overlap/json", 0.0, path)
    return entry


def main(smoke: bool = False):
    bench_step_overlap(smoke=smoke)


if __name__ == "__main__":
    main()
