"""Roofline table from the dry-run artifacts (deliverable g): per
(arch x shape x mesh) the three terms, dominant bottleneck, and the
MODEL_FLOPS/HLO_FLOPS 'useful compute' ratio."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def bench_roofline(art_dir="artifacts/dryrun"):
    files = sorted(glob.glob(os.path.join(art_dir, "*.json")))
    if not files:
        emit("roofline/NO_ARTIFACTS", 0.0,
             "run scripts/run_dryrun_grid.sh first")
        return
    n_ok = n_skip = n_fail = 0
    for f in files:
        with open(f) as fh:
            art = json.load(fh)
        tag = f"{art['arch']}/{art['shape']}/{art['mesh']}"
        if art["status"] == "ok":
            n_ok += 1
            r = art["roofline"]
            dom = r["bottleneck"]
            emit(f"roofline/{tag}", 0.0,
                 f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                 f"coll={r['collective_s']:.3e}s bottleneck={dom} "
                 f"useful={r['useful_flops_ratio']:.2f} "
                 f"mem/dev={art['memory']['total_per_device']/2**30:.2f}GiB")
        elif art["status"].startswith("skipped"):
            n_skip += 1
            emit(f"roofline/{tag}", 0.0, art["status"])
        else:
            n_fail += 1
            emit(f"roofline/{tag}", 0.0, "FAILED")
    emit("roofline/summary", 0.0, f"ok={n_ok} skipped={n_skip} failed={n_fail}")


def main():
    bench_roofline()


if __name__ == "__main__":
    main()
