"""Shared helpers for the benchmark harness (one module per paper table)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core.optim import make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.train import loop as L

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def append_bench_json(path: str, entry: dict) -> str:
    """Append one entry to a BENCH_*.json trajectory file (tolerates a
    missing or corrupt file) and return the absolute path."""
    path = os.path.abspath(path)
    data = {"entries": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {"entries": []}
    data.setdefault("entries", []).append(entry)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return path


def time_fn(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def small_lm(vocab=256, d_model=128, n_layers=2, seq=64, batch=16,
             **cfg_overrides):
    cfg = base.reduced(base.get_config("paper-lm-209m"), d_model=d_model,
                       n_layers=n_layers, vocab_size=vocab, n_heads=4,
                       n_kv_heads=4, head_dim=d_model // 4,
                       d_ff=4 * d_model, **cfg_overrides)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=vocab, seq_len=seq,
                                          global_batch=batch))
    return cfg, pipe


def train_lm(cfg, pipe, opt_name, steps, lr=5e-3, seed=0, hyper=None,
             **opt_kw):
    """Returns (final_loss, losses, diverged)."""
    opt = make_optimizer(opt_name, lr=lr, min_8bit_size=1024, **opt_kw)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(seed))
    step = jax.jit(L.make_train_step(cfg, opt, hyper or L.TrainHyper()))
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        li = float(m["loss"])
        losses.append(li)
        if not jnp.isfinite(li) or li > 50:
            return li, losses, True
    return losses[-1], losses, False
