"""Shared helpers for the benchmark harness (one module per paper table)."""
from __future__ import annotations

import functools
import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core.optim import make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.train import loop as L

ROWS: list[tuple[str, float, str]] = []

# Fields that identify *what* was measured (vs. the measurement itself):
# two entries agreeing on all of these are repeat runs of the same cell at
# the same commit, and the newer one replaces the older — so BENCH_*.json
# holds one row per (bench cell, commit) and reads as a per-PR trajectory
# instead of an append-only log of CI reruns.
_DEDUPE_FIELDS = ("bench", "git_sha", "smoke", "bits", "algo", "backend",
                  "n_leaves", "qmap", "block_size", "devices",
                  "overlap_buckets")


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


@functools.lru_cache(maxsize=1)
def git_sha() -> str:
    """Current commit SHA (short), or 'unknown' outside a git checkout.
    A dirty working tree gets a '-dirty' suffix so pre-commit runs are not
    attributed to the parent commit (and the post-commit CI rerun at the
    real SHA replaces nothing it shouldn't)."""
    cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=cwd, capture_output=True, text=True,
                             timeout=10)
        sha = out.stdout.strip()
        if out.returncode != 0 or not sha:
            return "unknown"
        st = subprocess.run(["git", "status", "--porcelain"], cwd=cwd,
                            capture_output=True, text=True, timeout=10)
        if st.returncode == 0 and st.stdout.strip():
            sha += "-dirty"
        return sha
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def append_bench_json(path: str, entry: dict) -> str:
    """Record one entry in a BENCH_*.json trajectory file and return the
    absolute path.  Every entry is stamped with the current ``git_sha``;
    an existing entry for the same bench cell at the same commit (see
    ``_DEDUPE_FIELDS``) is *replaced*, so repeat runs don't pile up and
    the file stays a comparable per-PR trajectory.  Tolerates a missing
    or corrupt file.  Delegates to the telemetry trajectory writer
    (``repro.telemetry.export.append_json_trajectory``), so BENCH files
    and telemetry share one writer (DESIGN.md §14)."""
    from repro.telemetry.export import append_json_trajectory
    return append_json_trajectory(path, entry, _DEDUPE_FIELDS,
                                  defaults={"git_sha": git_sha()})


def bench_sink(path: str):
    """A registry sink routing telemetry events into ``path`` as BENCH
    trajectory entries (dedupe per cell+commit, like append_bench_json)."""
    from repro.telemetry.export import BenchJsonSink
    return BenchJsonSink(path, _DEDUPE_FIELDS,
                         defaults={"git_sha": git_sha()})


def time_fn(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def small_lm(vocab=256, d_model=128, n_layers=2, seq=64, batch=16,
             **cfg_overrides):
    cfg = base.reduced(base.get_config("paper-lm-209m"), d_model=d_model,
                       n_layers=n_layers, vocab_size=vocab, n_heads=4,
                       n_kv_heads=4, head_dim=d_model // 4,
                       d_ff=4 * d_model, **cfg_overrides)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=vocab, seq_len=seq,
                                          global_batch=batch))
    return cfg, pipe


def train_lm(cfg, pipe, opt_name, steps, lr=5e-3, seed=0, hyper=None,
             **opt_kw):
    """Returns (final_loss, losses, diverged)."""
    opt = make_optimizer(opt_name, lr=lr, min_8bit_size=1024, **opt_kw)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(seed))
    # donated step (DESIGN.md §13c) — the loop below rebinds state
    step = L.jit_train_step(cfg, opt, hyper or L.TrainHyper())
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        li = float(m["loss"])
        losses.append(li)
        if not jnp.isfinite(li) or li > 50:
            return li, losses, True
    return losses[-1], losses, False
