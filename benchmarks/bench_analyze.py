"""Static-analysis suite for the benchmark harness (DESIGN.md §15).

Records the Pallas VMEM budget table (per-kernel tile bytes + headroom
against the TPU budget) into BENCH_speed.json so headroom regressions
show up in the same trajectory file as the timing sweeps, and runs the
kernel-budget audit as a pass/fail leg.  The heavier compile-contract
matrix stays in ``python -m repro.analysis`` (the CI gate); this suite
is the artifact-producing slice.
"""
from __future__ import annotations

import time

from benchmarks.common import append_bench_json, emit
from benchmarks.bench_speed import BENCH_JSON


def main(smoke: bool = False):
    from repro.analysis import kernel_budget as kb

    table = kb.budget_table()
    worst = min((row for row in table if row["fits"]),
                key=lambda r: r["headroom_bytes"])
    emit("analyze/vmem_rows", 0.0, f"{len(table)}rows")
    emit("analyze/vmem_min_headroom_bytes",
         float(worst["headroom_bytes"]),
         f"{worst['kernel']}")
    emit("analyze/ns_max_m", float(kb.ns_max_m()), "vmem-resident NS dim")

    results = kb.audit()
    bad = [r for r in results if not r[1]]
    emit("analyze/kernel_budget_failures", float(len(bad)),
         "PASS" if not bad else "; ".join(n for n, _, _ in bad))

    path = append_bench_json(BENCH_JSON, {
        "bench": "kernel_budget",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke,
        "budget_bytes": kb.VMEM_BUDGET_BYTES[kb.DEFAULT_BACKEND],
        "ns_max_m": kb.ns_max_m(),
        "min_headroom_bytes": worst["headroom_bytes"],
        "table": table,
    })
    emit("analyze/json", 0.0, path)
    if bad:
        raise SystemExit(f"kernel budget audit failed: "
                         f"{[n for n, _, _ in bad]}")


if __name__ == "__main__":
    main()
