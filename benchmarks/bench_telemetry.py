"""Telemetry smoke leg (DESIGN.md §14): the observable 8-bit stack
end-to-end.

Ten optimizer steps of ``muon8`` — matrix leaves per-leaf (Newton–Schulz
momentum) plus 1-D leaves pooled in the QuantArena, ZeRO-1 partitioned
when 4 host devices are forced — with phase tracing on, qhealth probes
every 2 steps, and the registry routed to a JSONL sink.  The artifact is
then schema-validated (``repro.telemetry.validate_jsonl``) and must
contain:

  * "qhealth" events for BOTH the pooled arena (``target="arena"``) and
    a muon matrix leaf (``target="leaf"``), each with a saturation
    fraction, a 256-bin codebook-utilization histogram, and absmax drift;
  * one "trace" event carrying the per-phase fused-dispatch accounting of
    the compiled step;
  * per-step "phase" timeline events and registry "metric" events.

Appends a summary entry to BENCH_speed.json.  This is the CI
``--telemetry`` leg (scripts/ci.sh runs it on the forced 4-device host
mesh; on fewer devices it degrades to the unpartitioned single-device
run, which validates the same schema).
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_speed import BENCH_JSON
from benchmarks.common import append_bench_json, emit
from repro import telemetry as tel
from repro.core.optim import make_optimizer
from repro.telemetry import tracing

STEPS = 10
EVERY = 2
SHARDS = 4


def bench_telemetry_jsonl(smoke: bool = False):
    shards = SHARDS if jax.device_count() >= SHARDS else 1
    mesh = jax.make_mesh((shards,), ("data",)) if shards > 1 else None
    key = jax.random.PRNGKey(0)
    n_mat, n_vec = 2, 6
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (32, 64)) for i in range(n_mat)}
    params.update({f"v{i}": jax.random.normal(
        jax.random.fold_in(key, 100 + i), (1024,)) for i in range(n_vec)})
    kw = ({"partition": True, "partition_shards": shards, "mesh": mesh}
          if mesh is not None else {})
    opt = make_optimizer("muon8", lr=1e-2, min_8bit_size=256,
                         override_32bit=lambda p: False,
                         telemetry_every=EVERY, **kw)

    # BENCH_TELEMETRY_DIR pins the artifact dir so a later CI leg can
    # point the run inspector at it (scripts/ci.sh, DESIGN.md §16)
    out_dir = os.environ.get("BENCH_TELEMETRY_DIR")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    else:
        out_dir = tempfile.mkdtemp(prefix="bench_telemetry_")
    path = os.path.join(out_dir, "telemetry.jsonl")
    if os.path.exists(path):
        os.remove(path)            # JsonlSink appends; start fresh
    reg = tel.MetricRegistry()
    reg.add_sink(tel.JsonlSink(path))
    tracing.set_phase_tracing(True)   # before tracing: scopes bake in
    tracing.reset_trace_events()
    try:
        state = opt.init(params)
        probe = tel.QHealthProbe(opt, mesh=mesh)
        step = jax.jit(lambda g, s: opt.apply(g, s))
        timer = tracing.StepTimer()
        pv = params
        for i in range(STEPS):
            with timer.step():
                grads = jax.tree_util.tree_map(
                    lambda p: p * (0.01 + 0.001 * i), pv)
                pv, state = step(grads, state)
                jax.block_until_ready(jax.tree_util.tree_leaves(pv)[0])
            if i == 0:
                reg.emit_event(tracing.trace_event_dict(i))
                tracing.reset_trace_events()
            reg.emit_event({"kind": "phase", "step": i, "phase": "step",
                            "wall_s": timer.last_dt})
            reg.record_scalars(
                i, {"p0_norm": jnp.linalg.norm(
                    jax.tree_util.tree_leaves(pv)[0])}, prefix="opt/")
            if (i + 1) % EVERY == 0:
                with tracing.host_phase("qhealth_probe", step=i):
                    for ev in probe.probe(state, step=i):
                        reg.emit_event(ev)
                for ev in tracing.drain_phase_events():
                    reg.emit_event(ev)
        reg.gauge("opt/steady_ms").set(timer.steady_ms())
        reg.flush(step=STEPS - 1)
        reg.close()
    finally:
        tracing.set_phase_tracing(False)

    events, errors = tel.validate_jsonl(path)
    assert not errors, errors[:5]
    kinds = sorted({e["kind"] for e in events})
    assert {"metric", "phase", "qhealth", "trace"} <= set(kinds), kinds
    q = [e for e in events if e["kind"] == "qhealth"]
    targets = {e["target"] for e in q}
    assert targets == {"arena", "leaf"}, targets
    for e in q:
        assert 0.0 <= e["saturation_fraction"] <= 1.0, e
        assert len(e["util_hist"]) == e["n_bins"] == 256, e
        assert e["absmax_drift"] > 0.0, e
    tr = next(e for e in events if e["kind"] == "trace")
    assert any(p["dispatches"] > 0 for p in tr["phases"]), tr
    n_probe = len([e for e in events if e["kind"] == "phase"
                   and e["phase"] == "qhealth_probe"])
    assert n_probe == STEPS // EVERY, n_probe
    emit("telemetry/jsonl_events", float(len(events)),
         f"{len(q)} qhealth over {len({e['segment'] for e in q})} segments, "
         f"{shards}-device, schema-valid")
    entry = {
        "bench": "telemetry_jsonl", "algo": "muon",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke, "backend": jax.default_backend(),
        "devices": shards, "steps": STEPS, "telemetry_every": EVERY,
        "n_events": len(events), "n_qhealth": len(q),
        "qhealth_targets": sorted(targets), "event_kinds": kinds,
    }
    p = append_bench_json(BENCH_JSON, entry)
    emit("telemetry/json", 0.0, p)
    return entry


def main(smoke: bool = False):
    bench_telemetry_jsonl(smoke=smoke)


if __name__ == "__main__":
    main()
