"""Serving benchmark: paged quantized KV + continuous batching (§17).

Three cell families into BENCH_speed.json:

  * ``serve/kv_bytes_per_token`` at bits 16/8/4 — stored KV bytes per
    generated token (codes + per-row absmax, all attn layers).  Gate:
    the 4-bit cell is <= 0.30x the fp16 baseline (the paper's memory
    win reaching inference; head_dim=64 puts the absmax overhead at
    (32+4)/128 = 0.281x).
  * ``serve/tokens_per_s/{continuous,static_bucket}`` — the same
    mixed-length request stream through ``ContinuousBatchingEngine``
    (paged 8-bit KV) vs the fixed-bucket ``ServeEngine`` (fp16 cache,
    arrival-order buckets padded to the bucket max).  Gate: continuous
    >= 1.5x static on the skewed stream — slots recycle the moment a
    short request finishes instead of draining the bucket.
  * ``serve/latency/continuous`` — p50/p99 per-request latency (ms)
    from the timed continuous run.

Both engines are warmed (jit compile paid up front) before timing.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import append_bench_json, emit
from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.kvcache import PagedKVConfig, kv_bytes_per_token
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   SchedulerConfig)

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_speed.json")

# head_dim=64 is the smallest paper-typical head at which the 4-bit row
# (32 code bytes + 4 absmax bytes) clears the 0.30x gate
_CFG = dict(arch_id="bench-serve", family="dense", n_layers=2, d_model=128,
            n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=211, head_dim=64,
            compute_dtype="float32", remat="none", attn_chunk=16)


def _mixed_stream(n_slots: int, n_rounds: int, vocab: int):
    """Arrival-order rounds of one long + (n_slots-1) short requests: the
    static engine pads every bucket to the long request's length."""
    rng = np.random.RandomState(0)
    reqs = []
    for r in range(n_rounds):
        for s in range(n_slots):
            rid = r * n_slots + s
            P = 6 if s else 10
            n_new = 1 if s else 28
            reqs.append(Request(rid=rid,
                                prompt=tuple(rng.randint(0, vocab, P)
                                             .tolist()),
                                max_new_tokens=n_new))
    return reqs


def bench_kv_bytes(smoke: bool = False):
    cfg = ModelConfig(**_CFG)
    base16 = kv_bytes_per_token(cfg, 16)
    for bits in (16, 8, 4):
        v = kv_bytes_per_token(cfg, bits)
        ratio = v / base16
        emit(f"serve/kv_bytes_per_token/b{bits}", 0.0,
             f"{v:.0f}B {ratio:.3f}x_fp16")
        append_bench_json(BENCH_JSON, {
            "bench": "serve/kv_bytes_per_token", "bits": bits,
            "smoke": smoke, "bytes_per_token": v,
            "ratio_vs_fp16": round(ratio, 4),
            "head_dim": cfg.head_dim, "n_kv_heads": cfg.n_kv_heads,
            "n_layers": cfg.n_layers})
        if bits == 4:
            assert ratio <= 0.30, (
                f"4-bit KV bytes/token gate: {ratio:.3f}x fp16 > 0.30x")
    emit("serve/kv_bytes_per_token/json", 0.0, os.path.abspath(BENCH_JSON))


def _run_static(eng, reqs, n_slots):
    """Fixed-bucket baseline: arrival-order buckets of ``n_slots``, padded
    to the bucket's max prompt length, run for the bucket's max new-token
    count.  Returns useful (requested) tokens produced."""
    useful = 0
    for i in range(0, len(reqs), n_slots):
        bucket = reqs[i:i + n_slots]
        P = max(len(r.prompt) for r in bucket)
        n_new = max(r.max_new_tokens for r in bucket)
        prompts = np.zeros((len(bucket), P), np.int32)
        for j, r in enumerate(bucket):   # right-aligned in the pad bucket
            prompts[j, P - len(r.prompt):] = r.prompt
        eng.generate(prompts, n_new)
        useful += sum(r.max_new_tokens for r in bucket)
    return useful


def bench_throughput(smoke: bool = False):
    cfg = ModelConfig(**_CFG)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    n_slots = 4
    n_rounds = 2 if smoke else 4
    reqs = _mixed_stream(n_slots, n_rounds, cfg.vocab_size)
    kv = PagedKVConfig(page_size=8, n_pages=32, n_slots=n_slots,
                       max_pages_per_seq=8, kv_bits=8)
    cont = ContinuousBatchingEngine(cfg, params, SchedulerConfig(kv=kv))
    static = ServeEngine(cfg, params, ServeConfig(max_len=64,
                                                  temperature=0.0))

    # warmup: pay every jit compile (both engines) outside the timed run
    cont.serve(reqs)
    _run_static(static, reqs, n_slots)

    t0 = time.perf_counter()
    cont._latencies_ms.clear()
    out = cont.serve(reqs)
    t_cont = time.perf_counter() - t0
    n_useful = sum(len(v) for v in out.values())
    tps_cont = n_useful / t_cont

    t0 = time.perf_counter()
    useful_static = _run_static(static, reqs, n_slots)
    t_static = time.perf_counter() - t0
    tps_static = useful_static / t_static

    ratio = tps_cont / tps_static
    emit("serve/tokens_per_s/continuous", t_cont / n_useful * 1e6,
         f"{tps_cont:.1f}tok/s")
    emit("serve/tokens_per_s/static_bucket", t_static / useful_static * 1e6,
         f"{tps_static:.1f}tok/s")
    emit("serve/tokens_per_s/ratio", 0.0, f"{ratio:.2f}x")
    lat = cont.latency_percentiles()
    emit("serve/latency/continuous", 0.0,
         f"p50={lat['p50_ms']:.1f}ms p99={lat['p99_ms']:.1f}ms")
    common = {"smoke": smoke, "n_streams": len(reqs), "n_slots": n_slots,
              "bits": kv.kv_bits, "page_size": kv.page_size}
    append_bench_json(BENCH_JSON, {
        "bench": "serve/tokens_per_s/continuous",
        "tokens_per_s": round(tps_cont, 2), **common})
    append_bench_json(BENCH_JSON, {
        "bench": "serve/tokens_per_s/static_bucket",
        "tokens_per_s": round(tps_static, 2), "bits": 16,
        **{k: v for k, v in common.items() if k != "bits"}})
    append_bench_json(BENCH_JSON, {
        "bench": "serve/tokens_per_s/ratio",
        "ratio_vs_static": round(ratio, 3), **common})
    append_bench_json(BENCH_JSON, {
        "bench": "serve/latency/continuous",
        "p50_ms": round(lat["p50_ms"], 2),
        "p99_ms": round(lat["p99_ms"], 2), **common})
    emit("serve/tokens_per_s/json", 0.0, os.path.abspath(BENCH_JSON))
    assert ratio >= 1.5, (
        f"continuous-batching throughput gate: {ratio:.2f}x static < 1.5x "
        f"on the mixed-length stream")


def main(smoke: bool = False):
    bench_kv_bytes(smoke=smoke)
    bench_throughput(smoke=smoke)


if __name__ == "__main__":
    main()
