"""Paper Table 5 analogue: optimizer update runtime in isolation.

The paper reports ms per update per 1B params on V100; this container is
CPU-only so absolute numbers differ, but the *relative* cost of 8-bit vs
32-bit updates (and the Pallas-interpret validation path) is measured, and
the kernel's TPU roofline position is derived analytically (bytes streamed /
HBM bw — the kernel is bandwidth-bound; DESIGN.md §3)."""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import append_bench_json, emit, time_fn
from repro.core import qmap
from repro.core.lowbit import PackedCodes
from repro.kernels import ops, ref

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_speed.json")


def bench_table5_update_speed(n_params: int = 1 << 20):
    nb, bsz = n_params // 2048, 2048
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (nb, bsz))
    g = jax.random.normal(key, (nb, bsz)) * 0.01
    qs = jnp.asarray(qmap.get_qmap("dynamic", True))
    qu = jnp.asarray(qmap.get_qmap("dynamic", False))
    cm, am = ref.quantize_ref(p * 0.01, qs)
    cr, ar = ref.quantize_ref(jnp.abs(p) * 1e-4, qu)
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
              step=3.0)

    @jax.jit
    def adam8_jnp(p, g, cm, am, cr, ar):
        return ops.fused_update("adam", p, g, cm, am, cr, ar, qs, qu,
                                impl="jnp", **kw)

    @jax.jit
    def adam32(p, g, m, r):
        m2 = 0.9 * m + 0.1 * g
        r2 = 0.999 * r + 0.001 * g * g
        return p - 1e-3 * (m2 / (1 - 0.9 ** 3)) / (
            jnp.sqrt(r2 / (1 - 0.999 ** 3)) + 1e-8), m2, r2

    m = jnp.zeros_like(p)
    r = jnp.zeros_like(p)
    us32, _ = time_fn(adam32, p, g, m, r)
    us8, _ = time_fn(adam8_jnp, p, g, cm, am, cr, ar)
    emit(f"table5/adam32_jnp_us_per_{n_params}p", us32,
         f"{us32 * 1e9 / n_params / 1000:.1f}ms/1Bparam")
    emit(f"table5/adam8_jnp_us_per_{n_params}p", us8,
         f"{us8 * 1e9 / n_params / 1000:.1f}ms/1Bparam")

    # Pallas interpret path (correctness-bearing, not perf-bearing on CPU)
    small = 1 << 16
    nb2 = small // 2048
    us8k, _ = time_fn(
        lambda: ops.fused_update("adam", p[:nb2], g[:nb2], cm[:nb2], am[:nb2],
                                 cr[:nb2], ar[:nb2], qs, qu,
                                 impl="interpret", **kw), iters=2, warmup=1)
    emit(f"table5/adam8_pallas_interpret_us_per_{small}p", us8k,
         "validation-path")

    # TPU roofline position (analytic): bytes/param streamed by the fused
    # kernel: p(4+4) g(4) codes(2x(1+1)) absmax(~0) = 16B/param.
    bytes_per_param = 16.0
    t_1b = 1e9 * bytes_per_param / 819e9
    emit("table5/adam8_tpu_hbm_bound_ms_per_1B", 0.0,
         f"{t_1b * 1e3:.1f}ms (819GB/s v5e; paper reports 47ms on V100)")


def _sweep_inputs(algo, nb, bsz):
    qs = jnp.asarray(qmap.get_qmap("dynamic", True))
    qu = jnp.asarray(qmap.get_qmap("dynamic", False))
    kp, kg = jax.random.split(jax.random.PRNGKey(0))
    p = jax.random.normal(kp, (nb, bsz))
    g = jax.random.normal(kg, (nb, bsz)) * 0.01
    two = algo in ("adam", "adamw", "lamb")
    if algo == "adagrad":
        cm, am = ref.quantize_ref(jnp.abs(p) * 1e-3, qu)
        q1 = qu
    else:
        cm, am = ref.quantize_ref(p * 0.01, qs)
        q1 = qs
    cr, ar = ref.quantize_ref(jnp.abs(p) * 1e-4, qu) if two else (None, None)
    return p, g, cm, am, cr, ar, q1, qu


def bench_fused_update_sweep(smoke: bool = False):
    """All six algorithms x {fused (Pallas interpret off-TPU), jnp} through
    the one registry entry point; appends an entry to BENCH_speed.json so
    the LAMB/LARS/AdaGrad fused speedup shows up in the perf trajectory.

    On CPU the interpret path measures correctness-bearing overhead, not
    TPU perf; the jnp column is the XLA fallback every algorithm used to
    take for its non-fused passes."""
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.01,
              step=3.0, trust_coeff=1e-3)
    sizes = {"jnp": (64, 2048) if smoke else (512, 2048),
             "interpret": (8, 256) if smoke else (8, 2048)}
    iters = {"jnp": (3, 1) if smoke else (5, 2), "interpret": (2, 1)}

    def jitted(algo, impl):
        # arrays are traced; algo/impl/hypers close over (strings can't be
        # jit args) — so the jnp column times XLA, not eager dispatch
        @jax.jit
        def run(*arrs):
            return ops.fused_update(algo, *arrs, impl=impl, **kw)
        return run

    results: dict[str, dict[str, float]] = {}
    for algo in ops.ALGOS:
        results[algo] = {}
        for impl in ("jnp", "interpret"):
            nb, bsz = sizes[impl]
            args = _sweep_inputs(algo, nb, bsz)
            fn = jitted(algo, impl)
            it, warm = iters[impl]
            us, _ = time_fn(functools.partial(fn, *args), iters=it,
                            warmup=warm)
            n = nb * bsz
            results[algo][impl] = us
            emit(f"table5/fused_sweep/{algo}/{impl}_us_per_{n}p", us,
                 f"{us * 1e9 / n / 1000:.2f}ms/1Bparam" if impl == "jnp"
                 else "validation-path")
    _append_bench_json({
        "bench": "fused_update_sweep",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke,
        "backend": jax.default_backend(),
        "sizes": {k: list(v) for k, v in sizes.items()},
        "us_per_call": results,
    })
    return results


def _append_bench_json(entry: dict, label: str = "table5/fused_sweep/json") -> None:
    path = append_bench_json(BENCH_JSON, entry)
    emit(label, 0.0, path)


def bench_kbit_fused(bits: int, smoke: bool = False):
    """Packed k-bit fused Adam through the registry (DESIGN.md §9): times
    the jnp entry and exercises the Pallas-interpret in-kernel
    unpack→dequant→update→requant→pack path; appends to BENCH_speed.json.
    This is the CI `--bits` smoke leg."""
    qs = jnp.asarray(qmap.get_qmap("dynamic", True, bits=bits))
    qu = jnp.asarray(qmap.get_qmap("dynamic", False, bits=bits))
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
              step=3.0)
    results = {}
    sizes = {"jnp": (64, 2048) if smoke else (512, 2048),
             "interpret": (8, 256) if smoke else (8, 2048)}
    for impl, (nb, bsz) in sizes.items():
        k = jax.random.PRNGKey(0)
        p = jax.random.normal(k, (nb, bsz))
        g = jax.random.normal(k, (nb, bsz)) * 0.01
        cm8, am = ref.quantize_ref(p * 0.01, qs)
        cr8, ar = ref.quantize_ref(jnp.abs(p) * 1e-4, qu)
        cm = PackedCodes.from_codes(cm8, bits)
        cr = PackedCodes.from_codes(cr8, bits)

        @jax.jit
        def run(p, g, pk_m, am, pk_r, ar):
            return ops.fused_update(
                "adam", p, g, PackedCodes(pk_m, bits, bsz), am,
                PackedCodes(pk_r, bits, bsz), ar, qs, qu, impl=impl, **kw)

        us, out = time_fn(run, p, g, cm.packed, am, cr.packed, ar,
                          iters=2 if impl == "interpret" else 3, warmup=1)
        assert out.codes_m.packed.shape == (nb, bsz * bits // 8)
        results[impl] = us
        n = nb * bsz
        emit(f"kbit/fused_adam_{bits}bit/{impl}_us_per_{n}p", us,
             f"packed {bits}-bit" if impl == "jnp" else "validation-path")
    _append_bench_json({
        "bench": "kbit_fused", "bits": bits,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke, "backend": jax.default_backend(),
        "us_per_call": results,
    }, label=f"kbit/fused_{bits}bit/json")
    return results


def bench_pooled_dispatch(smoke: bool = False):
    """Pooled single-dispatch (DESIGN.md §10) vs per-leaf dispatch on a
    many-leaf parameter tree: fused-update *launches per train step*
    (counted at trace time — what the compiled step actually bakes in) and
    the wall-clock of one optimizer step.  Appends both to
    BENCH_speed.json so the pooled win is tracked over PRs."""
    from repro.core.optim import make_optimizer
    n_leaves = 12 if smoke else 48
    key = jax.random.PRNGKey(0)
    params = {f"layer{i:02d}": jax.random.normal(
        jax.random.fold_in(key, i), (8 + (i % 5) * 8, 256))
        for i in range(n_leaves)}
    grads = jax.tree_util.tree_map(lambda p: p * 0.01, params)
    results: dict[str, dict] = {}
    for mode, pooled in (("pooled", True), ("per_leaf", False)):
        opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=256,
                             override_32bit=lambda p: False, pooled=pooled)
        st = opt.init(params)
        step = jax.jit(lambda g, s: opt.apply(g, s))
        ops.reset_fused_update_count()
        step.lower(grads, st)                 # trace only: launches/step
        calls = ops.fused_update_count()
        us, _ = time_fn(step, grads, st, iters=2 if smoke else 5, warmup=1)
        results[mode] = {"launches_per_step": calls, "us_per_step": us}
        emit(f"pooled/{mode}/us_per_step", us,
             f"{calls} fused launches/step, {n_leaves} leaves")
    assert results["pooled"]["launches_per_step"] <= 2, results
    assert results["per_leaf"]["launches_per_step"] == n_leaves, results
    speedup = (results["per_leaf"]["us_per_step"]
               / max(results["pooled"]["us_per_step"], 1e-9))
    emit("pooled/speedup_vs_per_leaf", 0.0, f"{speedup:.2f}x")
    _append_bench_json({
        "bench": "pooled_dispatch",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke, "backend": jax.default_backend(),
        "n_leaves": n_leaves,
        "launches_per_step": {m: r["launches_per_step"]
                              for m, r in results.items()},
        "us_per_step": {m: r["us_per_step"] for m, r in results.items()},
        "speedup_pooled_vs_per_leaf": speedup,
    }, label="pooled/json")
    return results


def bench_partition(smoke: bool = False):
    """ZeRO-1 partitioned optimizer state (DESIGN.md §12): per-device
    owned state bytes and fused launches vs data-parallel degree on a
    many-leaf tree.  The span-structured dispatch is bit-exact vs the
    unpartitioned pooled oracle (tests/test_partition.py); this bench
    records the memory-scaling claim — owned bytes shrink ~linearly with
    the shard count (gate: 4-way owned <= 0.3x replicated) — into
    BENCH_speed.json.  This is the CI `--partition` smoke leg."""
    from repro.core.optim import make_optimizer
    n_leaves = 12 if smoke else 48
    key = jax.random.PRNGKey(0)
    params = {f"layer{i:02d}": jax.random.normal(
        jax.random.fold_in(key, i), (8 + (i % 5) * 8, 256))
        for i in range(n_leaves)}
    grads = jax.tree_util.tree_map(lambda p: p * 0.01, params)
    results: dict = {}
    for shards in (1, 2, 4):
        opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=256,
                             override_32bit=lambda p: False,
                             partition=True, partition_shards=shards)
        st = opt.init(params)
        step = jax.jit(lambda g, s, o=opt: o.apply(g, s))
        ops.reset_fused_update_count()
        step.lower(grads, st)                 # trace only: launches/step
        calls = ops.fused_update_count()
        sb = opt.state_bytes(st)
        us, _ = time_fn(step, grads, st, iters=2 if smoke else 5, warmup=1)
        results[shards] = {
            "launches_per_step": calls, "us_per_step": us,
            "owned_blocks": sb["owned_blocks"],
            "owned_state_bytes": sb["owned_state_bytes"],
            "state_bytes": sb["state_bytes"],
        }
        emit(f"partition/shards{shards}/owned_state_bytes",
             float(sb["owned_state_bytes"]),
             f"{sb['owned_state_bytes'] / sb['state_bytes']:.3f}x of "
             f"replicated, {calls} span launches")
    r4 = results[4]
    assert r4["owned_state_bytes"] <= 0.3 * r4["state_bytes"], results
    _append_bench_json({
        "bench": "partition",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke, "backend": jax.default_backend(),
        "n_leaves": n_leaves,
        "per_shards": {str(k): v for k, v in results.items()},
        "owned_over_replicated_4way":
            r4["owned_state_bytes"] / r4["state_bytes"],
    }, label="partition/json")
    return results


def bench_muon(smoke: bool = False):
    """Muon matrix-optimizer sweep (DESIGN.md §11): the NS(5) fused update
    through the registry, jnp vs Pallas-interpret, plus the pooled-
    fallback dispatch count on a mixed 2-D/1-D/small model — one fused
    arena launch for the element-wise adamw leaves + one NS launch per
    matrix leaf.  The analytic TPU roofline position comes from
    ``repro.roofline.analysis.muon_update_roofline`` (the first compute-
    bound optimizer kernel in the repo).  Appends to BENCH_speed.json."""
    from repro.core.optim import make_optimizer
    from repro.roofline import analysis as roofline

    qs = jnp.asarray(qmap.get_qmap("dynamic", True))
    kw = dict(lr=1e-3, beta1=0.95, weight_decay=0.01)
    sizes = {"jnp": (128, 512) if smoke else (512, 2048),
             "interpret": (32, 256) if smoke else (64, 2048)}
    results: dict[str, float] = {}
    for impl, (rows, cols) in sizes.items():
        k = jax.random.PRNGKey(0)
        p = jax.random.normal(k, (rows, cols))
        g = jax.random.normal(jax.random.fold_in(k, 1), (rows, cols)) * 0.01
        n = rows * cols
        nb, bsz = -(-n // 2048), 2048
        m0 = jax.random.normal(jax.random.fold_in(k, 2), (nb, bsz)) * 0.01
        cm, am = ref.quantize_ref(m0, qs)

        @jax.jit
        def run(p, g, cm, am):
            return ops.fused_update("muon", p, g, cm, am, qmap_m=qs,
                                    impl=impl, **kw)

        us, _ = time_fn(run, p, g, cm, am,
                        iters=2 if impl == "interpret" else 3, warmup=1)
        results[impl] = us
        rf = roofline.muon_update_roofline((rows, cols))
        emit(f"muon/fused_ns5_{rows}x{cols}/{impl}_us", us,
             f"tpu-roofline {rf['bottleneck']}-bound "
             f"({rf['flops'] / 1e6:.0f}MFLOP)" if impl == "jnp"
             else "validation-path")

    # Pooled fallback dispatch: matrix leaves per-leaf, element-wise adamw
    # leaves in ONE arena launch (trace-time count, DESIGN.md §10/§11).
    n_matrix, n_vec = (3, 6) if smoke else (6, 12)
    key = jax.random.PRNGKey(1)
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (32, 64)) for i in range(n_matrix)}
    params.update({f"v{i}": jax.random.normal(
        jax.random.fold_in(key, 100 + i), (512,)) for i in range(n_vec)})
    grads = jax.tree_util.tree_map(lambda p: p * 0.01, params)
    opt = make_optimizer("muon8", lr=1e-3, min_8bit_size=256,
                         override_32bit=lambda p: False)
    st = opt.init(params)
    ops.reset_fused_update_count()
    jax.jit(lambda g, s: opt.apply(g, s)).lower(grads, st)   # trace only
    launches = ops.fused_update_count()
    emit("muon/pooled_fallback/launches_per_step", 0.0,
         f"{launches} = {n_matrix} NS leaves + 1 adamw arena "
         f"({n_vec} pooled 1-D leaves)")
    assert launches == n_matrix + 1, (launches, n_matrix)
    _append_bench_json({
        "bench": "muon_sweep", "algo": "muon",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke, "backend": jax.default_backend(),
        "sizes": {k: list(v) for k, v in sizes.items()},
        "us_per_call": results,
        "pooled_fallback_launches": launches,
        "n_matrix_leaves": n_matrix,
    }, label="muon/json")
    return results


def bench_telemetry_overhead(smoke: bool = False):
    """Telemetry cost gates (DESIGN.md §14) on a real jitted train step.

    Three legs over the same tiny LM:

      * ``baseline`` — telemetry fully off (the default build);
      * ``off`` — phase-tracing annotations compiled into the step
        (``set_phase_tracing(True)`` before tracing) and
        ``telemetry_every`` set in the config, but no probes and no sinks.
        The annotations are named scopes, not ops, so the computation is
        unchanged (tests/test_telemetry.py pins the telemetry-off StableHLO
        byte-identical); gate: min step time <= 1.01x baseline.
      * ``on`` — registry sink attached, per-step scalars recorded, and
        qhealth probes every 10 steps (a separate jitted executable on the
        host schedule, pre-warmed off the clock); gate: mean step time
        <= 1.05x baseline, the probe cost amortized over the window.
      * ``sent`` — the in-graph numerics sentinel compiled into the step
        (``OptimConfig.sentinel=True``, DESIGN.md §16): per-dispatch
        health counts reduced in VMEM and summed into the step metrics;
        gate: mean step time <= 1.05x baseline.

    A small absolute guard (0.2/0.5 ms) rides on each gate so timer
    granularity on the tiny CPU step can't flake the ratio.  Appends
    telemetry_overhead to BENCH_speed.json."""
    import numpy as np

    from benchmarks.common import small_lm
    from repro import telemetry as tel
    from repro.core.optim import make_optimizer
    from repro.telemetry import tracing
    from repro.train import loop as L

    steps = 20 if smoke else 40
    every = 10
    reps = 3

    def make_leg(trace: bool, probes: bool, sentinel: bool = False):
        """Compile one leg (off the clock) and return a window runner.
        The three runners are then INTERLEAVED window-by-window, so host
        drift (CPU frequency, cache state) hits every leg equally instead
        of biasing whichever ran last."""
        tracing.set_phase_tracing(trace)
        tracing.reset_trace_events()
        try:
            cfg, pipe = small_lm(d_model=64, n_layers=2, seq=32, batch=8)
            kw = {"telemetry_every": every} if (trace or probes) else {}
            if sentinel:
                kw["sentinel"] = True
            opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024, **kw)
            state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
            step = L.jit_train_step(cfg, opt)
            reg = probe = None
            if probes:
                reg = tel.MetricRegistry()
                reg.add_sink(tel.InMemorySink())
                probe = tel.QHealthProbe(opt)
            # compile warm-up: first step (and first probe) off the clock
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
            state, m = step(state, batch)
            jax.block_until_ready(m["loss"])
            if probe is not None:
                probe.probe(state.opt_state, step=0)
        finally:
            tracing.set_phase_tracing(False)
        box = {"state": state, "i": 1}

        def window():
            times = []
            st = box["state"]
            for k in range(steps):
                batch = {k2: jnp.asarray(v) for k2, v in
                         pipe.batch_at(box["i"]).items()}
                box["i"] += 1
                t0 = time.perf_counter()
                st, m = step(st, batch)
                if probes:
                    reg.record_scalars(k, m, prefix="train/")
                    if (k + 1) % every == 0:
                        for ev in probe.probe(st.opt_state, step=k):
                            reg.emit_event(ev)
                jax.block_until_ready(m["loss"])
                times.append(time.perf_counter() - t0)
            box["state"] = st
            return times

        return window

    legs = {"base": make_leg(trace=False, probes=False),
            "off": make_leg(trace=True, probes=False),
            "on": make_leg(trace=True, probes=True),
            "sent": make_leg(trace=False, probes=False, sentinel=True)}
    times: dict[str, list] = {k: [] for k in legs}
    for _ in range(reps):
        for name, w in legs.items():
            times[name] += w()
    base_mean, base_min = (float(np.mean(times["base"])) * 1e3,
                           float(np.min(times["base"])) * 1e3)
    off_mean, off_min = (float(np.mean(times["off"])) * 1e3,
                         float(np.min(times["off"])) * 1e3)
    on_mean, on_min = (float(np.mean(times["on"])) * 1e3,
                       float(np.min(times["on"])) * 1e3)
    sent_mean, sent_min = (float(np.mean(times["sent"])) * 1e3,
                           float(np.min(times["sent"])) * 1e3)
    off_ratio = off_min / max(base_min, 1e-9)
    on_ratio = on_mean / max(base_mean, 1e-9)
    sent_ratio = sent_mean / max(base_mean, 1e-9)
    emit("telemetry/baseline_ms_per_step", base_min * 1e3, "min, no telemetry")
    emit("telemetry/off_ms_per_step", off_min * 1e3,
         f"{off_ratio:.3f}x baseline (gate 1.01x): traced-in annotations")
    emit("telemetry/on_ms_per_step", on_mean * 1e3,
         f"{on_ratio:.3f}x baseline (gate 1.05x): probes every {every}")
    emit("telemetry/sentinel_ms_per_step", sent_mean * 1e3,
         f"{sent_ratio:.3f}x baseline (gate 1.05x): in-graph health counts")
    assert off_min <= base_min * 1.01 + 0.2, (off_min, base_min)
    assert on_mean <= base_mean * 1.05 + 0.5, (on_mean, base_mean)
    assert sent_mean <= base_mean * 1.05 + 0.5, (sent_mean, base_mean)
    _append_bench_json({
        "bench": "telemetry_overhead",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": smoke, "backend": jax.default_backend(),
        "telemetry_every": every, "steps_per_window": steps,
        "baseline_ms": {"mean": base_mean, "min": base_min},
        "off_ms": {"mean": off_mean, "min": off_min},
        "on_ms": {"mean": on_mean, "min": on_min},
        "sentinel_ms": {"mean": sent_mean, "min": sent_min},
        "off_ratio_min": off_ratio, "on_ratio_mean": on_ratio,
        "sentinel_ratio_mean": sent_ratio,
    }, label="telemetry/overhead_json")
    return {"off_ratio": off_ratio, "on_ratio": on_ratio,
            "sentinel_ratio": sent_ratio}


def bench_quantize_throughput():
    qs = jnp.asarray(qmap.get_qmap("dynamic", True))
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 2048))

    @jax.jit
    def q(x):
        return ref.quantize_ref(x, qs)

    us, _ = time_fn(q, x)
    n = x.size
    emit("table5/quantize_blockwise_jnp_us_per_1Melem", us * (1 << 20) / n,
         f"{n / us:.0f} elem/us")


def main(smoke: bool = False, bits: int | None = None,
         algo: str | None = None, partition: bool = False,
         telemetry: bool = False):
    if not smoke:
        bench_table5_update_speed()
        bench_quantize_throughput()
    bench_fused_update_sweep(smoke=smoke)
    bench_pooled_dispatch(smoke=smoke)
    if bits is not None:
        bench_kbit_fused(bits, smoke=smoke)
    if algo == "muon" or not smoke:
        bench_muon(smoke=smoke)
    if partition or not smoke:
        bench_partition(smoke=smoke)
    if telemetry or not smoke:
        bench_telemetry_overhead(smoke=smoke)


if __name__ == "__main__":
    main()
