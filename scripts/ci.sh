#!/usr/bin/env bash
# CI entry point: install, tier-1 tests, then a smoke run of the benchmark
# harness so the fused optimizer-update path (Pallas interpret mode) is
# exercised off-TPU on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -e '.[test]'

PYTHONPATH=src python -m pytest -x -q

# Smoke sweep plus the packed 4-bit leg: k-bit qmaps + PackedCodes through
# the fused registry (jnp + Pallas-interpret in-kernel unpack/pack),
# DESIGN.md §9.  `--bits 4` is a superset of the plain --smoke run.
PYTHONPATH=src python -m benchmarks.run --smoke --bits 4
