#!/usr/bin/env bash
# CI entry point: install, tier-1 tests, then a smoke run of the benchmark
# harness so the fused optimizer-update path (Pallas interpret mode) is
# exercised off-TPU on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -e '.[test]'

PYTHONPATH=src python -m pytest -x -q

# Smoke sweep plus the packed 4-bit leg (k-bit qmaps + PackedCodes through
# the fused registry's jnp + Pallas-interpret in-kernel unpack/pack,
# DESIGN.md §9) plus the muon leg (NS(5) fused update jnp vs interpret +
# the pooled-fallback dispatch count on a mixed 2-D/1-D model, DESIGN.md
# §11).  One invocation: both flags forward to the same suite mains, so
# this is a superset of the plain --smoke run at no repeated suites.
PYTHONPATH=src python -m benchmarks.run --smoke --bits 4 --algo muon
