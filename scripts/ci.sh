#!/usr/bin/env bash
# CI entry point: install, tier-1 tests, then a smoke run of the benchmark
# harness so the fused optimizer-update path (Pallas interpret mode) is
# exercised off-TPU on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."

# --analyze: the static-analysis gate only (DESIGN.md §15) — compile
# contracts over the config matrix, the Pallas VMEM/grid budget audit,
# and the repo lint baseline.  Runs as its own blocking CI job; no
# training step executes, so it needs no install beyond the base deps.
if [[ "${1:-}" == "--analyze" ]]; then
  python -m pip install -e .
  PYTHONPATH=src python -m repro.analysis
  PYTHONPATH=src python -m benchmarks.run --only analyze --analyze
  exit 0
fi

python -m pip install -e '.[test]'

# Tier-1 tests with a coverage gate (floor set conservatively below the
# suite's measured coverage when the gate landed, so refactors can't
# silently orphan whole code paths; ratchet it up as coverage grows).
# Falls back to plain pytest where pytest-cov isn't installed, so the
# tier-1 invocation stays runnable in minimal environments.
if python -c 'import pytest_cov' 2>/dev/null; then
  PYTHONPATH=src python -m pytest -x -q --cov=repro --cov-fail-under=75
else
  PYTHONPATH=src python -m pytest -x -q
fi

# Smoke sweep plus the packed 4-bit leg (k-bit qmaps + PackedCodes through
# the fused registry's jnp + Pallas-interpret in-kernel unpack/pack,
# DESIGN.md §9) plus the muon leg (NS(5) fused update jnp vs interpret +
# the pooled-fallback dispatch count on a mixed 2-D/1-D model, DESIGN.md
# §11) plus the partition leg (ZeRO-1 owned bytes + span launches vs shard
# count, DESIGN.md §12).  One invocation: the flags forward to the same
# suite mains, so this is a superset of the plain --smoke run at no
# repeated suites.  --telemetry adds the speed suite's telemetry-overhead
# gates (telemetry-off <= 1.01x, probes-on <= 1.05x of baseline ms/step)
# plus a single-device run of the telemetry JSONL suite (DESIGN.md §14).
PYTHONPATH=src python -m benchmarks.run --smoke --bits 4 --algo muon \
  --partition --telemetry

# Overlap leg (DESIGN.md §13): optimizer-exposed ms/step sequential vs
# the bucketed ZeRO-2 path, plus the peak-grad-bytes gate, on a forced
# 4-device host mesh (separate invocation: the device-count flag must be
# set before jax initializes).  Records opt_exposed_ms / peak_grad_bytes
# cells into BENCH_speed.json.
XLA_FLAGS="--xla_force_host_platform_device_count=4 ${XLA_FLAGS:-}" \
  PYTHONPATH=src python -m benchmarks.run --smoke --overlap --only step_overlap

# Telemetry leg (DESIGN.md §14), forced 4-device host mesh: 10 muon8
# steps on the ZeRO-1 partitioned arena with qhealth probes every 2
# steps; schema-validates the emitted JSONL and asserts saturation/
# utilization fields for both the pooled QuantArena and a muon matrix
# leaf.  The artifact dir is pinned so the run inspector (DESIGN.md §16)
# can triage it afterwards: the schema gate and the full render must both
# exit 0 on this clean run (nonzero exit = anomalies or schema errors,
# which fails CI here).
TELEMETRY_RUN_DIR="$(mktemp -d)"
XLA_FLAGS="--xla_force_host_platform_device_count=4 ${XLA_FLAGS:-}" \
  BENCH_TELEMETRY_DIR="$TELEMETRY_RUN_DIR" \
  PYTHONPATH=src python -m benchmarks.run --smoke --only telemetry
PYTHONPATH=src python -m repro.telemetry.inspect --validate "$TELEMETRY_RUN_DIR"
PYTHONPATH=src python -m repro.telemetry.inspect "$TELEMETRY_RUN_DIR"

# Serving leg (DESIGN.md §17): paged block-wise 8/4-bit KV cache +
# continuous batching.  Gates: 4-bit KV bytes/token <= 0.30x the fp16
# contiguous baseline, and continuous-batching tokens/s >= 1.5x the
# static-bucket engine on a mixed-length stream.  Cells (bytes/token,
# tokens/s for both engines, p50/p99 latency) land in BENCH_speed.json.
PYTHONPATH=src python -m benchmarks.run --smoke --serve --only serve
