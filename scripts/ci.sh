#!/usr/bin/env bash
# CI entry point: install, tier-1 tests, then a smoke run of the benchmark
# harness so the fused optimizer-update path (Pallas interpret mode) is
# exercised off-TPU on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -e '.[test]'

PYTHONPATH=src python -m pytest -x -q

PYTHONPATH=src python -m benchmarks.run --smoke
