"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts.  Usage:
    PYTHONPATH=src python scripts/render_experiments.py [artifacts/dryrun]
Prints markdown to stdout.
"""
import glob
import json
import os
import sys


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def main(art_dir="artifacts/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rows.append(json.load(open(f)))

    for mesh in ["pod", "multipod"]:
        sel = [a for a in rows if a["mesh"] == mesh]
        if not sel:
            continue
        print(f"\n### Mesh `{mesh}` "
              f"({'16x16=256 chips' if mesh == 'pod' else '2x16x16=512 chips'})\n")
        print("| arch | shape | status | compute s | memory s | collective s "
              "| bottleneck | MODEL/HLO flops | args GiB/dev | temp GiB/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for a in sel:
            if a["status"] != "ok":
                print(f"| {a['arch']} | {a['shape']} | {a['status'][:28]} "
                      f"| | | | | | | |")
                continue
            r = a["roofline"]
            m = a["memory"]
            print(f"| {a['arch']} | {a['shape']} | ok "
                  f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
                  f"| {r['collective_s']:.2e} | **{r['bottleneck']}** "
                  f"| {r['useful_flops_ratio']:.2f} "
                  f"| {fmt_bytes(m['argument_bytes'])} "
                  f"| {fmt_bytes(m['temp_bytes'])} |")
        ok = sum(1 for a in sel if a["status"] == "ok")
        sk = sum(1 for a in sel if a["status"].startswith("skip"))
        fa = len(sel) - ok - sk
        print(f"\n{ok} compiled, {sk} skipped (long_500k/full-attention), "
              f"{fa} failed.")


if __name__ == "__main__":
    main(*(sys.argv[1:2] or ["artifacts/dryrun"]))
