"""Collate artifacts/claims/*.jsonl into the EXPERIMENTS §claims table."""
import glob
import json
import os
import sys


def main(d="artifacts/claims"):
    print("| run | steps | final loss | min loss | diverged |")
    print("|---|---|---|---|---|")
    for f in sorted(glob.glob(os.path.join(d, "*.jsonl"))):
        name = os.path.basename(f)[:-6]
        losses = [json.loads(l)["loss"] for l in open(f) if l.strip()]
        if not losses:
            continue
        final = losses[-1]
        diverged = (final != final) or final > 10 * min(losses) or final > 50
        print(f"| {name} | {len(losses)} | {final:.3f} | {min(losses):.3f} "
              f"| {'YES' if diverged else 'no'} |")


if __name__ == "__main__":
    main(*(sys.argv[1:2] or ["artifacts/claims"]))
