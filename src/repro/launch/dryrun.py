import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax-importing module: jax locks the device count on
# first init.  (Override via DRYRUN_XLA_FLAGS for the small-mesh test mode.)

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and record memory/cost/collective artifacts.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-32b \
      --shape train_4k --mesh pod            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod

Per cell this emits artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis(), cost_analysis(), and per-collective byte counts parsed
from the post-SPMD HLO — the inputs to EXPERIMENTS.md §Dry-run/§Roofline.
Every compile failure here is a bug in the framework's sharding config.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.core.optim import make_optimizer
from repro.launch import mesh as mesh_lib
from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.models import model as M
from repro.roofline import analysis as roofline
from repro.sharding import rules as shard_rules
from repro.train import loop as train_loop

# per-arch microbatch count for train_4k (activation-memory knob; §Perf)
MICROBATCHES = {
    "xlstm-350m": 4,
    "kimi-k2-1t-a32b": 8, "mixtral-8x22b": 8, "command-r-35b": 4,
    "qwen1.5-32b": 4, "llava-next-34b": 4, "recurrentgemma-9b": 2,
    "granite-3-8b": 2,
}

# perf-tuned per-cell overrides filled in during §Perf hillclimbing:
# (arch, shape) -> dict(remat=..., microbatches=..., policy kwargs...)
PERF_OVERRIDES: dict = {}


def build_mesh(kind: str):
    if kind == "pod":
        return mesh_lib.make_production_mesh(multi_pod=False)
    if kind == "multipod":
        return mesh_lib.make_production_mesh(multi_pod=True)
    if kind == "smoke":   # 8 host devices (tests)
        return mesh_lib.make_mesh((2, 2, 2), ("pod", "data", "model"))
    raise ValueError(kind)


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               overrides: dict | None = None):
    """Lower+compile one cell; returns the artifact dict."""
    cfg = cfgs.get_config(arch)
    case = SHAPES[shape_name]
    ok, why = cell_supported(cfg, case)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": why}
    overrides = dict(overrides or {})
    overrides.update(PERF_OVERRIDES.get((arch, shape_name), {}))
    cfg_keys = ("remat", "attn_chunk", "scan_layers", "kv_cache_bits")
    if any(k in overrides for k in cfg_keys):
        import dataclasses
        cfg = dataclasses.replace(
            cfg, **{k: v for k, v in overrides.items() if k in cfg_keys})

    mesh = build_mesh(mesh_kind)
    n_chips = mesh.size
    policy = shard_rules.ShardingPolicy()
    t0 = time.time()

    from repro.models import constrain as constrain_lib
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    tp_size = mesh.shape.get("model", 1)
    constrain_lib.set_activation_axes(
        dp_axes=dp_axes, tp_axis="model" if tp_size > 1 else None,
        dp_size=dp_size, tp_size=tp_size)

    with mesh:
        key = jax.random.PRNGKey(0)
        box = {}

        def _init():
            p, s = M.init_model(cfg, key)
            box["specs"] = s       # static tree of logical-axis tuples
            return p

        abstract_params = jax.eval_shape(_init)
        specs = box["specs"]
        pshard = shard_rules.param_shardings(specs, abstract_params, mesh,
                                             policy)
        if "blocks" in pshard:
            constrain_lib.set_block_param_specs(pshard["blocks"])
        if case.kind == "train":
            micro = overrides.get("microbatches",
                                  MICROBATCHES.get(arch, 1))
            opt = make_optimizer(
                "adam8", lr=1e-4,
                master_dtype=("bfloat16" if cfg.param_dtype == "bfloat16"
                              else "float32"),
                shard_multiple=n_chips, weight_decay=0.1, impl="jnp",
                # ZeRO-1 span-structured update over the data-parallel
                # degree (DESIGN.md §12; unrolled spans — GSPMD places
                # them, so the lowering stays mesh-shape-agnostic)
                partition_shards=mesh_lib.data_parallel_degree(mesh))
            hyper = train_loop.TrainHyper(microbatches=micro)
            step_fn = train_loop.make_train_step(cfg, opt, hyper,
                                                 param_shardings=pshard)
            abstract_state = jax.eval_shape(
                lambda p: train_loop.TrainState(
                    opt_state=opt.init(p),
                    step=jnp.zeros((), jnp.int32)), abstract_params)
            st_shard = train_loop.TrainState(
                opt_state=shard_rules.opt_state_shardings(
                    abstract_state.opt_state, pshard, mesh, policy),
                step=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
            batch_specs = input_specs(cfg, case)
            bshard = {k: shard_rules.batch_sharding(mesh, policy, v.ndim,
                                                    v.shape[0])
                      for k, v in batch_specs.items()}
            # donate the train state: master/codes update in place (no
            # double-buffering of the 8-bit statistics or the master copy)
            jitted = jax.jit(step_fn, in_shardings=(st_shard, bshard),
                             out_shardings=(st_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(abstract_state, batch_specs)
        elif case.kind == "prefill":
            ins = input_specs(cfg, case)

            def prefill_fn(params, tokens, embeds=None):
                return M.prefill(cfg, params, tokens, max_len=case.seq_len,
                                 embeds=embeds)

            bshard = {k: shard_rules.batch_sharding(mesh, policy, v.ndim,
                                                    v.shape[0])
                      for k, v in ins.items()}
            args = [abstract_params, ins["tokens"]]
            in_sh = [pshard, bshard["tokens"]]
            if "embeds" in ins:
                args.append(ins["embeds"])
                in_sh.append(bshard["embeds"])
            jitted = jax.jit(prefill_fn, in_shardings=tuple(in_sh))
            lowered = jitted.lower(*args)
        else:  # decode
            ins = input_specs(cfg, case)
            cache_shard = shard_rules.cache_shardings(ins["caches"], cfg,
                                                      mesh, policy)

            def decode_fn(params, token, caches, pos):
                return M.decode_step(cfg, params, token, caches, pos)

            tok_shard = shard_rules.batch_sharding(
                mesh, policy, 2, ins["token"].shape[0])
            rep = jax.sharding.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec())
            # donate the KV cache: decode writes one row in place
            jitted = jax.jit(
                decode_fn,
                in_shardings=(pshard, tok_shard, cache_shard, rep),
                out_shardings=(None, cache_shard),
                donate_argnums=(2,))
            lowered = jitted.lower(abstract_params, ins["token"],
                                   ins["caches"], ins["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        constrain_lib.clear_activation_axes()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        rf = roofline.analyze(cost, hlo, n_chips=n_chips,
                              model_flops_global=roofline.model_flops(cfg, case))

    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_per_device": (mem.argument_size_in_bytes
                                 + mem.temp_size_in_bytes),
        },
        "cost": {k: cost[k] for k in ("flops", "bytes accessed")
                 if k in cost},
        "roofline": rf.to_dict(),
    }
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", type=str, default="pod",
                    choices=["pod", "multipod", "smoke"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv8", action="store_true",
                    help="int8 block-quantized KV cache (extension)")
    args = ap.parse_args()

    cells = []
    archs = cfgs.list_archs() if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}__{args.mesh}".replace("/", "_")
        if args.kv8:
            tag += "__kv8"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            art = lower_cell(
                arch, shape_name, args.mesh,
                overrides={"kv_cache_bits": 8} if args.kv8 else None)
        except Exception as e:  # a failure here is a framework bug
            failures += 1
            art = {"arch": arch, "shape": shape_name, "mesh": args.mesh,
                   "status": "FAILED", "error": repr(e),
                   "trace": traceback.format_exc()[-2000:]}
            print(f"  FAILED: {e!r}")
        with open(path, "w") as f:
            json.dump(art, f, indent=1)
        if art["status"] == "ok":
            r = art["roofline"]
            print(f"  ok: compute={r['compute_s']:.3e}s "
                  f"memory={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
                  f"bottleneck={r['bottleneck']} "
                  f"useful={r['useful_flops_ratio']:.2f} "
                  f"(compile {art['compile_s']}s)", flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
