"""The assigned input-shape set and ShapeDtypeStruct builders (no allocation).

LM shapes are seq_len x global_batch; decode_*/long_* lower ``serve_step``
(one token against a seq_len KV cache), train_* lower ``train_step``.
long_500k runs only for sub-quadratic archs (cfg.subquadratic).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg, case: ShapeCase) -> tuple[bool, str]:
    if case.name == "long_500k" and not cfg.subquadratic:
        return False, "skipped(full-attention)"
    return True, ""


def input_specs(cfg, case: ShapeCase) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = case.global_batch, case.seq_len
    ft = cfg.frontend_tokens
    if case.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S - ft + 1), jnp.int32)}
        if ft:
            specs["embeds"] = jax.ShapeDtypeStruct(
                (B, ft, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        return specs
    if case.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S - ft), jnp.int32)}
        if ft:
            specs["embeds"] = jax.ShapeDtypeStruct(
                (B, ft, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        return specs
    if case.kind == "decode":
        cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "caches": cache,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(case.kind)
