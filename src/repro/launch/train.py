"""End-to-end training driver (deliverable b): config-driven, fault-tolerant.

  PYTHONPATH=src python -m repro.launch.train --arch paper-lm-209m \
      --optimizer adam8 --steps 300 --seq-len 128 --batch 16 \
      --ckpt-dir artifacts/run1 --out artifacts/run1/metrics.jsonl

Fault tolerance: resumes from the latest checkpoint in --ckpt-dir
automatically; SIGTERM/SIGINT triggers checkpoint-and-exit (preemption
handling); per-step wall times are z-score-monitored and logged as straggler
warnings (on multi-host this feeds the restart policy; see
scripts/launch_with_retries.sh for the supervisor loop).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgs
from repro.core.optim import make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro import telemetry as tel
from repro.telemetry import tracing
from repro.train import checkpoint as ckpt
from repro.train import loop as train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lm-209m")
    ap.add_argument("--optimizer", default="adam8")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--qmap", default="dynamic")
    ap.add_argument("--state-bits", default=None,
                    help="per-slot storage bitwidth for quantized states: "
                         "'4' or '4,8' (m,r); default 8-bit (DESIGN.md §9)")
    ap.add_argument("--no-blockwise", action="store_true")
    ap.add_argument("--no-stable-embedding", action="store_true")
    ap.add_argument("--no-32bit-embed-override", action="store_true")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--out", default=None, help="metrics JSONL path")
    ap.add_argument("--shard-grads", action="store_true",
                    help="ZeRO-2: accumulate grads owned-span sharded "
                         "(DESIGN.md §13)")
    ap.add_argument("--overlap-buckets", type=int, default=1,
                    help="subdivide the partitioned arena update into N "
                         "buckets overlapping the reduce-scatter "
                         "(DESIGN.md §13)")
    ap.add_argument("--telemetry-dir", default=None,
                    help="emit telemetry JSONL (metrics, step phases, "
                         "qhealth probes) into this directory "
                         "(DESIGN.md §14)")
    ap.add_argument("--telemetry-every", type=int, default=0,
                    help="run quantization-health probes every N steps "
                         "(0 = off; requires --telemetry-dir)")
    ap.add_argument("--sentinel", action="store_true",
                    help="in-graph numerics sentinel: kernels count "
                         "nonfinite/overflow/saturation per dispatch and "
                         "host detectors escalate anomalies (DESIGN.md §16)")
    ap.add_argument("--flight-dir", default=None,
                    help="flight-recorder dump directory: on a fatal "
                         "anomaly or nonfinite loss, dump the metrics "
                         "ring + last healthy state bundle here "
                         "(DESIGN.md §16)")
    ap.add_argument("--flight-ring", type=int, default=64,
                    help="flight-recorder ring length (steps)")
    args = ap.parse_args(argv)

    cfg = cfgs.get_config(args.arch)
    over = {"param_dtype": "float32", "compute_dtype": "float32",
            "remat": "none"}
    if args.d_model:
        over.update(d_model=args.d_model, head_dim=args.d_model // cfg.n_heads)
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if args.vocab:
        over["vocab_size"] = args.vocab
    if args.no_stable_embedding:
        over["stable_embedding"] = False
    cfg = dataclasses.replace(cfg, **over)

    pipe = SyntheticLMPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=1234))

    opt_kw = {}
    if args.optimizer.endswith("8"):
        opt_kw.update(qmap_m=args.qmap if args.qmap != "dynamic" else "dynamic",
                      qmap_r=args.qmap if args.qmap != "dynamic" else "dynamic",
                      blockwise_norm=not args.no_blockwise)
        if args.state_bits:
            parts = [int(b) for b in args.state_bits.split(",")]
            opt_kw["state_bits"] = parts[0] if len(parts) == 1 else tuple(parts)
        if args.no_32bit_embed_override:
            opt_kw["override_32bit"] = lambda p: False
    if args.shard_grads:
        opt_kw["shard_grads"] = True
    if args.overlap_buckets > 1:
        opt_kw["overlap_buckets"] = args.overlap_buckets
    if args.telemetry_every:
        opt_kw["telemetry_every"] = args.telemetry_every
    if args.sentinel:
        opt_kw["sentinel"] = True
    opt = make_optimizer(args.optimizer, lr=args.lr, weight_decay=0.0,
                         **opt_kw)
    hyper = train_loop.TrainHyper(
        microbatches=args.microbatches,
        lr_schedule=train_loop.warmup_cosine(args.lr, args.warmup,
                                             args.steps))

    # Telemetry (DESIGN.md §14): a typed registry over a JSONL sink, with
    # trace-time phase annotations enabled BEFORE the step is traced so the
    # compiled executable carries the phase scopes.  Without --telemetry-dir
    # nothing is enabled and the step lowers exactly as before.
    reg = probe = None
    if args.telemetry_dir:
        reg = tel.MetricRegistry()
        reg.add_sink(tel.JsonlSink(
            os.path.join(args.telemetry_dir, "telemetry.jsonl")))
        tracing.set_phase_tracing(True)
        tracing.reset_trace_events()
        probe = tel.QHealthProbe(opt)

    # §16 observability: host-side anomaly detectors over the step metrics
    # (always cheap) + the flight recorder's crash-forensics ring/snapshot.
    detector = tel.AnomalyDetector() if (args.sentinel or args.flight_dir) \
        else None
    flight = tel.FlightRecorder(ring=args.flight_ring) if args.flight_dir \
        else None
    telemetry_jsonl = (os.path.join(args.telemetry_dir, "telemetry.jsonl")
                       if args.telemetry_dir else None)

    def _flight_dump(reason, step):
        if flight is None:
            return
        path = flight.dump(args.flight_dir, reason=reason, trigger_step=step,
                           config=cfg, telemetry_path=telemetry_jsonl)
        print(f"[flight] dumped {reason} forensics to {path} "
              f"(last healthy snapshot: step {flight.snapshot_step})")

    # donated state (DESIGN.md §13c); the loop below rebinds state
    step_fn = train_loop.jit_train_step(cfg, opt, hyper)
    state, _ = train_loop.init_train_state(cfg, opt, jax.random.PRNGKey(args.seed))

    start = 0
    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(args.ckpt_dir, latest,
                                 jax.eval_shape(lambda s: s, state))
            start = latest
            print(f"[resume] from step {latest}")

    stop = {"now": False}

    def _sig(_s, _f):   # preemption: checkpoint + clean exit
        stop["now"] = True
    signal.signal(signal.SIGTERM, _sig)
    signal.signal(signal.SIGINT, _sig)

    out_f = open(args.out, "a") if args.out else None
    # single ms/step + compile_s definition (telemetry.tracing.StepTimer,
    # DESIGN.md §14) — the first executed step is the compile step and is
    # excluded from steady-state times and straggler z-scores
    timer = tracing.StepTimer()
    for i in range(start, args.steps):
        with timer.step():
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
        dt = timer.last_dt
        if i == start:
            print(f"[compile] first step {dt:.2f}s (excluded from ms/step)")
            if reg is not None:
                # per-phase dispatch accounting recorded while tracing the
                # step (one "trace" event per compile; DESIGN.md §14)
                reg.emit_event(tracing.trace_event_dict(i))
                tracing.reset_trace_events()
        if timer.is_straggler:
            print(f"[straggler] step {i}: {dt:.3f}s z={timer.straggler_z:.1f}")
        rec = {"step": i, "loss": loss, "t": round(dt, 4),
               "grad_norm": float(metrics["grad_norm"])}
        if i == start:
            rec["compile_s"] = round(timer.compile_s, 4)
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
            out_f.flush()
        if reg is not None:
            reg.record_scalars(i, metrics, prefix="train/")
            reg.emit_event({"kind": "phase", "step": i, "phase": "step",
                            "wall_s": dt})
            if probe is not None and args.telemetry_every and \
                    (i + 1) % args.telemetry_every == 0:
                with tracing.host_phase("qhealth_probe", step=i):
                    qevs = list(probe.probe(state.opt_state, step=i))
                for ev in qevs:
                    reg.emit_event(ev)
                for ev in tracing.drain_phase_events():
                    reg.emit_event(ev)
                if detector is not None:
                    for ev in detector.observe_qhealth(qevs):
                        reg.emit_event(ev)
                        if flight is not None:
                            flight.note_anomaly(ev)
        # §16: escalate this step's metrics into anomaly events; a fatal
        # verdict aborts the run (after the flight dump).  The snapshot is
        # taken from the post-step state only when the step was healthy —
        # a poisoned state must never become the resume point.
        fatal_reason = None if np.isfinite(loss) else "nonfinite_loss"
        if detector is not None:
            for ev in detector.observe_step(i, metrics):
                if reg is not None:
                    reg.emit_event(ev)
                if flight is not None:
                    flight.note_anomaly(ev)
                print(f"[anomaly] step {i} [{ev['severity']}] "
                      f"{ev['reason']} value={ev['value']:.4g}")
                if ev["severity"] == "fatal" and fatal_reason is None:
                    fatal_reason = ev["reason"]
        if flight is not None:
            flight.record(i, metrics, wall_s=dt)
            if fatal_reason is None:
                flight.snapshot(i, state)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {loss:.4f} ({dt:.2f}s)", flush=True)
        if args.ckpt_dir and ((i + 1) % args.ckpt_every == 0 or stop["now"]):
            ckpt.save(args.ckpt_dir, i + 1, state)
        if stop["now"]:
            print(f"[preempted] checkpointed at {i + 1}; exiting")
            return 0
        if fatal_reason is not None:
            print("[diverged]" if fatal_reason == "nonfinite_loss"
                  else f"[fatal anomaly] {fatal_reason}")
            if reg is not None:
                reg.flush(step=i)
                reg.close()
                tracing.set_phase_tracing(False)
            _flight_dump(fatal_reason, i)
            return 2
    sb = opt.state_bytes(state.opt_state) if hasattr(opt, "state_bytes") else {}
    steady_ms = timer.steady_ms()
    if reg is not None:
        reg.gauge("train/steady_ms").set(steady_ms)
        reg.gauge("train/compile_s").set(timer.compile_s)
        reg.flush(step=args.steps - 1)
        reg.close()
        tracing.set_phase_tracing(False)
    print(f"done. final loss {loss:.4f}; entropy floor "
          f"{pipe.bigram_entropy():.4f}; compile {timer.compile_s:.2f}s; "
          f"steady {steady_ms:.1f} ms/step; optimizer state bytes {sb}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
