"""Serving driver: paged 8/4-bit KV cache + continuous batching (§17).

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --reduce --serve-kv-bits 4 --serve-page-size 16 --serve-slots 4 \
      --streams 8 --max-new 32 --out artifacts/serve_metrics.jsonl

Generates a synthetic mixed-length request stream (``--streams`` requests,
prompt lengths cycling over ``--prompt-lens``), serves it through
``ContinuousBatchingEngine``, and prints per-request completions plus the
tokens/s, p50/p99 latency and KV bytes/token summary.  ``--engine static``
falls back to the fixed-bucket ``ServeEngine`` (fp16 contiguous cache) for
an A/B on the same stream.  Telemetry lands as schema-valid JSONL when
``--out`` is given.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import base as cfgs
from repro.errors import ConfigError


def build_requests(args, vocab_size):
    from repro.serve.scheduler import Request
    rng = np.random.RandomState(args.seed)
    plens = [int(p) for p in args.prompt_lens.split(",")]
    reqs = []
    for i in range(args.streams):
        P = plens[i % len(plens)]
        n_new = args.max_new if args.uniform_new else \
            int(rng.randint(1, args.max_new + 1))
        prompt = tuple(rng.randint(0, vocab_size, P).tolist())
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=n_new))
    return reqs


def main(argv=None):
    import jax
    from repro.models import model as M
    from repro import telemetry as tel
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.kvcache import (PagedKVConfig, kv_bytes_per_token)
    from repro.serve.scheduler import (ContinuousBatchingEngine,
                                       SchedulerConfig)

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduce", action="store_true",
                    help="shrink the arch to a laptop-size config")
    ap.add_argument("--engine", choices=("paged", "static"), default="paged")
    ap.add_argument("--serve-kv-bits", type=int, default=8,
                    help="paged KV quantization bitwidth (8 or 4)")
    ap.add_argument("--serve-page-size", type=int, default=16,
                    help="token positions per KV page")
    ap.add_argument("--serve-pages", type=int, default=128,
                    help="physical pages in the pool (per layer)")
    ap.add_argument("--serve-slots", type=int, default=4,
                    help="concurrent decode slots (the decode batch)")
    ap.add_argument("--serve-max-pages-per-seq", type=int, default=16)
    ap.add_argument("--serve-impl", choices=("jnp", "interpret", "pallas"),
                    default="jnp", help="gather-dequant kernel impl")
    ap.add_argument("--streams", type=int, default=8,
                    help="number of concurrent request streams")
    ap.add_argument("--prompt-lens", default="8,16,24",
                    help="comma list the stream's prompt lengths cycle over")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--uniform-new", action="store_true",
                    help="every request generates exactly --max-new tokens "
                         "(default: uniform random in [1, --max-new])")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="telemetry JSONL path (schema repro.telemetry.v1)")
    args = ap.parse_args(argv)

    cfg = cfgs.get_config(args.arch)
    if args.reduce:
        cfg = cfgs.reduced(cfg, d_model=128, n_layers=2, vocab_size=512)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(args.seed))
    reqs = build_requests(args, cfg.vocab_size)

    reg = tel.MetricRegistry()
    if args.out:
        reg.add_sink(tel.JsonlSink(args.out))

    if args.engine == "static":
        eng = ServeEngine(cfg, params, ServeConfig(
            max_len=max(len(r.prompt) for r in reqs) + args.max_new,
            temperature=args.temperature, seed=args.seed), registry=reg)
        plens = {len(r.prompt) for r in reqs}
        if len(plens) != 1:
            raise ConfigError(
                "--engine static needs equal prompt lengths (one bucket); "
                f"got {sorted(plens)} — use --prompt-lens with one value")
        prompts = np.asarray([r.prompt for r in reqs], np.int32)
        out = eng.generate(prompts, args.max_new)
        results = {r.rid: out[i] for i, r in enumerate(reqs)}
        summary = {"engine": "static", "kv_bits": 16,
                   "kv_bytes_per_token": kv_bytes_per_token(cfg, 16)}
    else:
        kv = PagedKVConfig(page_size=args.serve_page_size,
                           n_pages=args.serve_pages,
                           n_slots=args.serve_slots,
                           max_pages_per_seq=args.serve_max_pages_per_seq,
                           kv_bits=args.serve_kv_bits)
        eng = ContinuousBatchingEngine(
            cfg, params, SchedulerConfig(kv=kv,
                                         temperature=args.temperature,
                                         seed=args.seed,
                                         impl=args.serve_impl),
            registry=reg)
        results = eng.serve(reqs)
        summary = {"engine": "paged", "kv_bits": kv.kv_bits,
                   "kv_bytes_per_token": kv_bytes_per_token(cfg, kv.kv_bits),
                   **eng.latency_percentiles(),
                   "tokens_per_s": reg.metrics().get("serve/tokens_per_s")}

    for r in reqs:
        toks = results[r.rid]
        print(f"request {r.rid}: P={len(r.prompt)} -> "
              f"{np.asarray(toks).tolist()[:12]}"
              f"{'...' if len(toks) > 12 else ''}")
    print(json.dumps(summary))
    reg.flush(step=0)
    return summary


if __name__ == "__main__":
    main()
