"""Production mesh construction (a FUNCTION, so importing never touches jax
device state — required by the dry-run protocol)."""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax < 0.5 has no jax.sharding.AxisType; Auto is the default there,
    # so simply omit the kwarg (passing it raises AttributeError and took
    # the whole dry-run harness down with it).
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e pod), or 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / smoke / single-host)."""
    return _mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU example runs."""
    return make_mesh((1, 1), ("data", "model"))


def data_parallel_degree(mesh, axes=("pod", "data")) -> int:
    """Product of the data-parallel axis sizes present on ``mesh`` — the
    shard count the partitioned optimizer dispatch owns spans over
    (``OptimConfig.partition_shards``; DESIGN.md §12)."""
    deg = 1
    for a in axes:
        if a in mesh.axis_names:
            deg *= int(mesh.shape[a])
    return deg
