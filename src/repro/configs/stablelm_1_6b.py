"""stablelm-1.6b [dense] — 24L d_model=2048 32H (kv=32) d_ff=5632
vocab=100352 [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352,
    norm_type="layernorm", gated_mlp=True, qkv_bias=False,
    rope_theta=10_000.0,
    param_dtype="float32", compute_dtype="bfloat16",
    subquadratic=False,
))
