"""Model/arch configuration and the arch registry.

One module per assigned architecture lives next to this file; each registers
a ``ModelConfig`` under its canonical arch id (ids contain '.'/'-', module
names use underscores).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

_REGISTRY: dict[str, "ModelConfig"] = {}

_ARCH_MODULES = [
    "qwen1_5_32b", "stablelm_1_6b", "granite_3_8b", "command_r_35b",
    "llava_next_34b", "recurrentgemma_9b", "musicgen_medium", "xlstm_350m",
    "mixtral_8x22b", "kimi_k2_1t_a32b", "paper_lm",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # block flavour
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    gated_mlp: bool = True
    qkv_bias: bool = False
    use_bias: bool = False
    parallel_block: bool = False     # command-r style attn ∥ mlp
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # attention
    attn_type: str = "full"          # full | swa
    window: int = 0
    attn_chunk: int = 1024
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dff: int = 0
    # hybrid / recurrent / xlstm: per-super-block layer pattern, cycled
    block_pattern: tuple = ("attn",)
    lru_width: int = 0
    conv_width: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    # embedding / frontends
    stable_embedding: bool = True
    frontend: str = "none"           # none | vision | audio
    frontend_tokens: int = 0         # prefix positions fed by the stub
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_cache_bits: int = 16          # 8 => block-wise int8 KV cache (ext.)
    # training-time structure
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True
    # sub-quadratic? (controls long_500k eligibility)
    subquadratic: bool = False
    # notes for DESIGN/EXPERIMENTS
    notes: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def n_remainder_layers(self) -> int:
        return self.n_layers % len(self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, H, KV, Dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        n = self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # output head
        per_block = {}
        per_block["attn"] = d * H * Dh + 2 * d * KV * Dh + H * Dh * d \
            + (H * Dh + 2 * KV * Dh if self.qkv_bias else 0) + 2 * d
        f = self.d_ff
        mlp = (3 if self.gated_mlp else 2) * d * f
        if self.is_moe:
            fe = self.moe_dff or f
            mlp = d * self.n_experts + self.n_experts * (3 if self.gated_mlp else 2) * d * fe
        per_block["attn"] += mlp
        W = self.lru_width or d
        per_block["rglru"] = 2 * d * W + self.conv_width * W + 2 * W * W + W * d + 3 * W + 2 * d \
            + ((3 if self.gated_mlp else 2) * d * f if f else 0)
        Wm = int(d * self.mlstm_proj_factor)
        Dm = Wm // H
        per_block["mlstm"] = 2 * d * Wm + 4 * H * Dm * Dm + Wm * 2 * H + Wm * d + 2 * d
        fs = int(d * self.slstm_proj_factor)
        per_block["slstm"] = 4 * d * d + 4 * d * (d // H) + 4 * d + d * fs + fs * d + 2 * d
        for i in range(self.n_layers):
            kind = self.block_pattern[i % len(self.block_pattern)]
            n += per_block[kind]
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        fe = self.moe_dff or self.d_ff
        dense_expert = self.n_experts * (3 if self.gated_mlp else 2) * self.d_model * fe
        active_expert = self.top_k * (3 if self.gated_mlp else 2) * self.d_model * fe
        return int(self.param_count() - self.n_layers * (dense_expert - active_expert))


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    if arch_id not in _REGISTRY:
        raise ValueError(f"unknown arch '{arch_id}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    if not _REGISTRY:
        load_all()
    return sorted(_REGISTRY)


def load_all():
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test-size variant of an arch config (same family/flavour)."""
    base_changes = dict(
        n_layers=max(2, len(cfg.block_pattern)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        attn_chunk=32,
        window=min(cfg.window, 32) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_dff=32 if cfg.moe_dff else 0,
        lru_width=64 if cfg.lru_width else 0,
        frontend_tokens=4 if cfg.frontend_tokens else 0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        scan_layers=cfg.scan_layers,
    )
    base_changes.update(overrides)
    return dataclasses.replace(cfg, **base_changes)
