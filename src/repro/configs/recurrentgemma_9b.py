"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 [arXiv:2402.19427; unverified]. Griffin pattern 1 local-attn :
2 RG-LRU => block_pattern (rglru, rglru, attn), 12 super-blocks + 2 remainder
rglru layers. Local attention window 2048. Sub-quadratic (O(1) recurrent
state + ring KV) -> runs long_500k. Deviation: RG-LRU gate projections are
full matrices vs Griffin's block-diagonal (DESIGN.md §8); MLP gate uses SiLU
vs GeGLU."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=4096, conv_width=4,
    attn_type="swa", window=2048,
    norm_type="rmsnorm", gated_mlp=True,
    rope_theta=10_000.0, tie_embeddings=True,
    param_dtype="float32", compute_dtype="bfloat16",
    subquadratic=True,
))
