"""qwen1.5-32b [dense] — 64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064.
QKV bias per the assignment table [hf:Qwen/Qwen1.5-0.5B; hf].
Full attention -> long_500k skipped (DESIGN.md §5)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152064,
    norm_type="rmsnorm", gated_mlp=True, qkv_bias=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    subquadratic=False,
    notes="40 heads not divisible by the 16-way model axis: attention weights "
          "fall back to fully-sharded (FSDP) placement; MLP stays TP "
          "(27392 % 16 == 0). See sharding rules resolver.",
))
