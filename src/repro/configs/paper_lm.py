"""The paper's own language models (§4 experimental setup):

paper-lm-209m — 10L d_model=1024 16H d_ff=8192, 512-token sequences, 50k BPE
vocab (the 2-GPU-day ablation baseline behind Table 3 / Fig 3).
paper-lm-1.5b — the large-scale model of Table 1/3 (layer count chosen to hit
1.5B params at d_model=2048; the paper does not publish the exact depth).
"""
from repro.configs.base import ModelConfig, register

CONFIG_209M = register(ModelConfig(
    arch_id="paper-lm-209m", family="dense",
    n_layers=10, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=50264,
    norm_type="layernorm", gated_mlp=False, qkv_bias=False,
    stable_embedding=True,
    param_dtype="float32", compute_dtype="bfloat16",
    subquadratic=False,
))

CONFIG_1_5B = register(ModelConfig(
    arch_id="paper-lm-1.5b", family="dense",
    n_layers=25, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=50264,
    norm_type="layernorm", gated_mlp=False, qkv_bias=False,
    stable_embedding=True,
    param_dtype="float32", compute_dtype="bfloat16",
    subquadratic=False,
))
