"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 [arXiv:2306.05284; hf]. Decoder-only over EnCodec tokens; the
EnCodec encoder + text conditioner are STUBS: input_specs() provides 64
precomputed conditioning-frame embeddings prepended to the code tokens.
Deviations: rotary positions instead of sinusoidal; single codebook stream
(the 4-codebook delay pattern is out of backbone scope) — DESIGN.md §8."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    norm_type="layernorm", gated_mlp=False, qkv_bias=False,
    rope_theta=10_000.0,
    frontend="audio", frontend_tokens=64,
    param_dtype="float32", compute_dtype="bfloat16",
    subquadratic=False,
))
