"""xlstm-350m [ssm] — 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304
[arXiv:2405.04517; unverified]. xLSTM[7:1]: block_pattern = 7x mLSTM + 1x
sLSTM, 3 super-blocks. Blocks carry their own up/down projections (mLSTM
pf=2, sLSTM MLP pf=4/3). O(1) recurrent state -> runs long_500k."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0,
    norm_type="layernorm",
    param_dtype="float32", compute_dtype="bfloat16",
    subquadratic=True,
))
