from repro.configs.base import (ModelConfig, get_config, list_archs,  # noqa: F401
                                load_all, reduced, register)
