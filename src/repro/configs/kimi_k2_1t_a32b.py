"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (paper-table) [arXiv:2501.kimi2;
unverified]. ~1.03T params, ~32B active. We follow the assignment table
exactly (no shared expert, no MLA, all layers MoE — the released K2 differs;
DESIGN.md §8). 384 % 16 == 0 -> true expert parallelism on the model axis."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    n_experts=384, top_k=8, moe_dff=2048, capacity_factor=1.25,
    norm_type="rmsnorm", gated_mlp=True,
    rope_theta=50_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    subquadratic=False,
))
