"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].
Backbone only (Yi-34B-flavoured); the anyres vision tower is a STUB:
input_specs() provides 576 precomputed patch embeddings per example,
projected and prepended to token embeddings (assignment rule)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    norm_type="rmsnorm", gated_mlp=True, qkv_bias=False,
    rope_theta=5_000_000.0,
    frontend="vision", frontend_tokens=576,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    subquadratic=False,
))
