"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, SWA [arXiv:2401.04088; hf]. Sliding-window
attention window 4096 per the assignment -> bounded ring KV cache makes it
sub-quadratic and long_500k-eligible. 8 experts < 16-way model axis: expert
hidden dim is TP-sharded instead of expert-parallel (DESIGN.md §4)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    n_experts=8, top_k=2, moe_dff=16384, capacity_factor=1.25,
    attn_type="swa", window=4096,
    norm_type="rmsnorm", gated_mlp=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    subquadratic=True,
))
