"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 [hf:CohereForAI/c4ai-command-r-v01; unverified].
Parallel attention+FFN block, LayerNorm. Deviation note: the assignment says
no-bias; our LayerNorm keeps a zero-init bias param (DESIGN.md §8)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
    norm_type="layernorm", gated_mlp=True, qkv_bias=False,
    parallel_block=True, rope_theta=8_000_000.0, tie_embeddings=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    subquadratic=False,
))
