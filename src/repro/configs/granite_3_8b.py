"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 [hf:ibm-granite/granite-3.0-2b-base; hf]. Tied embeddings
(granite-3 family convention)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab_size=49155,
    norm_type="rmsnorm", gated_mlp=True, qkv_bias=False,
    rope_theta=10_000.0, tie_embeddings=True,
    param_dtype="float32", compute_dtype="bfloat16",
    subquadratic=False,
))
