"""Mixture-of-Experts FFN with token-choice top-k routing and sort/scatter
dispatch (DESIGN.md §4: dispatch memory is O(T·k·capacity_factor·d) — no
(T, E, C) one-hot tensor, which is infeasible at E=384).

Expert compute is a dense grouped einsum ``(E, C, d) x (E, d, f)`` which maps
onto the MXU and shards cleanly: E over 'model' when divisible (kimi-k2:
384 % 16 == 0, true expert parallelism), otherwise the expert hidden dim is
TP-sharded (mixtral: 8 experts on a 16-way axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.constrain import constrain


def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.moe_dff or cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": layers.dense_init(ks[0], (d, E), scale=0.02),
        "w_gate": layers.dense_init(ks[1], (E, d, f)),
        "w_in": layers.dense_init(ks[2], (E, d, f)),
        "w_out": layers.dense_init(ks[3], (E, f, d), scale=1.0 / np.sqrt(f)),
    }
    s = {
        "router": ("embed", "unsharded"),
        "w_gate": ("expert", "embed", "mlp"),
        "w_in": ("expert", "embed", "mlp"),
        "w_out": ("expert", "mlp", "embed"),
    }
    return p, s


def apply_moe(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_metrics dict)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    T = B * S
    xf = x.reshape(T, d)

    # ---- routing (f32) ----
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch): E * mean(f_e * p_e)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- sort/scatter dispatch with capacity ----
    capacity = int(max(1, int(T * k * cfg.capacity_factor // E)))
    flat_expert = expert_ids.reshape(-1)                         # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_tok[order], flat_gate[order]
    counts = jnp.bincount(flat_expert, length=E)                 # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[se]                         # slot in expert
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)

    # dispatch buffer (E, C, d): experts on 'tp' when divisible (kimi),
    # else capacity rows on 'dp'
    buf = jnp.zeros((E, capacity, d), dt)
    src = jnp.where(keep[:, None], xf[st], 0).astype(dt)
    buf = constrain(buf.at[se, pos_c].add(src), "tp", "dp", None)

    # ---- expert FFN (grouped einsum) ----
    if cfg.gated_mlp:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt))) \
            * jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(dt))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(dt)))
    h = constrain(h, "tp", "dp", None)
    out_buf = constrain(jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(dt)),
                        "tp", "dp", None)

    # ---- combine ----
    gathered = out_buf[se, pos_c]                                # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered.astype(jnp.float32) * sg[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[st].add(contrib)

    metrics = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss,
               "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return out.reshape(B, S, d).astype(dt), metrics
