"""Transformer building blocks: norms, rotary, GQA attention (chunked
online-softmax for train/prefill; plain cache attention for decode), MLPs.

Everything is pure-functional: ``init_*`` returns ``(params, logical_specs)``
where specs mirror the param tree with tuples of logical axis names
(resolved to mesh PartitionSpecs by repro.sharding.rules).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.constrain import attn_score_dims, constrain

# --------------------------------------------------------------------- utils

def dense_init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * scale


def xavier_uniform(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    lim = np.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


# --------------------------------------------------------------------- norms

def init_norm(d: int, norm_type: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    s = {"scale": ("embed",)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
        s["bias"] = ("embed",)
    return p, s


def apply_norm(p, x, norm_type: str, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        out = x32 * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# -------------------------------------------------------------------- rotary

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------- int8 KV cache (extension)
# Beyond-paper: the paper's block-wise 8-bit quantizer applied to the KV
# cache (block = one head row of Dh values, absmax per (position, head)).
# Halves decode-cache HBM residency; enabled per-arch via
# cfg.kv_cache_bits == 8.  DESIGN.md §4, EXPERIMENTS.md §Perf D.
# The k-bit row quantizer itself lives in kernels/paged_kv.py (shared with
# the paged serving cache, DESIGN.md §17); these are the 8-bit-default
# wrappers the contiguous cache path keeps using.

def kv_quantize(x, bits: int = 8):
    """x: (..., Dh) -> (codes uint8 (..., Dh*bits/8), absmax f32 (...,))."""
    from repro.kernels import paged_kv
    return paged_kv.quantize_rows(x, bits)


def kv_dequantize(codes, absmax, dtype, bits: int = 8):
    from repro.kernels import paged_kv
    return paged_kv.dequantize_rows(codes, absmax, dtype, bits)


# ------------------------------------------------ paged KV serving context

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedContext:
    """Per-decode-step paged-cache context (DESIGN.md §17).

    page_table: (n_slots, max_pages_per_seq) int32 — physical page per
                logical page; -1 = unallocated (gathered-but-masked).
    positions : (n_slots,) int32 — index of the token being decoded this
                step per slot; -1 = inactive slot (its append is dropped
                and its attention masks every key).
    impl      : gather-dequant kernel implementation (static; "jnp" XLA
                oracle, "interpret"/"pallas" the Pallas kernel).
    """

    page_table: jax.Array
    positions: jax.Array
    impl: str = "jnp"

    def tree_flatten(self):
        return (self.page_table, self.positions), (self.impl,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


# ----------------------------------------------------------------- attention

def init_attention(key, cfg):
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * Dh)),
        "wk": dense_init(ks[1], (d, KV * Dh)),
        "wv": dense_init(ks[2], (d, KV * Dh)),
        "wo": dense_init(ks[3], (H * Dh, d), scale=1.0 / np.sqrt(H * Dh)),
    }
    s = {
        "wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"), "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), jnp.float32)
        p["bk"] = jnp.zeros((KV * Dh,), jnp.float32)
        p["bv"] = jnp.zeros((KV * Dh,), jnp.float32)
        s["bq"], s["bk"], s["bv"] = ("heads",), ("kv_heads",), ("kv_heads",)
    return p, s


def _chunked_causal_attention(q, k, v, *, window: int, chunk: int):
    """Online-softmax attention, scanned over KV chunks (memory-bounded).

    q: (B, S, H, D), k/v: (B, S, KV, D) with KV | H (GQA). Causal; if
    ``window > 0`` additionally restricts to a sliding window (SWA) and only
    iterates KV chunks that can intersect the window of some query.
    Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV                                   # query heads per kv head
    chunk = int(min(chunk, S))
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    score_dims = attn_score_dims(KV, G, S)
    qh = (q.reshape(B, S, KV, G, D) * (D ** -0.5)).astype(jnp.float32)
    qh = qh.transpose(0, 2, 3, 1, 4)                  # (B, KV, G, S, D)
    qh = constrain(qh, *score_dims)
    q_pos = jnp.arange(S)

    def body(carry, idx):
        m_run, d_run, acc = carry
        k_c = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, axis=1)
        v_c = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=1)
        kv_pos = idx * chunk + jnp.arange(chunk)
        # scores: (B, KV, G, S, C)
        scores = jnp.einsum("bkgsd,bckd->bkgsc", qh, k_c.astype(jnp.float32))
        scores = constrain(scores, *score_dims)
        mask = q_pos[:, None] >= kv_pos[None, :]                   # causal
        if window > 0:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window    # SWA
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        m_new = jnp.maximum(m_run, jnp.max(scores, axis=-1))
        # guard: rows with no valid key yet keep m=-inf; exp(-inf - -inf)
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p_ = jnp.exp(scores - m_safe[..., None])
        p_ = jnp.where(mask[None, None, None], p_, 0.0)
        corr = jnp.where(jnp.isinf(m_run), 0.0, jnp.exp(m_run - m_safe))
        d_new = d_run * corr + jnp.sum(p_, axis=-1)
        pv = jnp.einsum("bkgsc,bckd->bkgsd", p_, v_c.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, d_new, acc_new), None

    m0 = constrain(jnp.full((B, KV, G, S), -jnp.inf, jnp.float32), *score_dims[:4])
    d0 = constrain(jnp.zeros((B, KV, G, S), jnp.float32), *score_dims[:4])
    a0 = constrain(jnp.zeros((B, KV, G, S, D), jnp.float32), *score_dims[:4])
    # Recompute chunk scores in the backward instead of storing them: the
    # scan otherwise stacks (B,KV,G,S,C) f32 residuals per chunk via
    # dynamic-update-slice — measured as the dominant HBM traffic of every
    # train/prefill cell (EXPERIMENTS.md §Perf C1).
    body = jax.checkpoint(body)
    # SWA: only the last (window//chunk + 1) chunks can intersect any query's
    # window *relative to the final chunk*… queries span all positions, so all
    # chunks are needed; per-chunk masking already zeroes dead work. True
    # chunk-skipping needs q-blocking (see EXPERIMENTS.md §Perf).
    (m_f, d_f, acc), _ = jax.lax.scan(body, (m0, d0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(d_f[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)


def _decode_attention(q, k_cache, v_cache, cache_len):
    """Single-position attention over a (possibly ring) cache.

    q: (B, 1, H, D); k/v_cache: (B, eff, KV, D).  Slot validity: the ring
    holds exactly the last min(cache_len, eff) positions, all causally
    visible (the current token's kv is already written).  For full-attention
    caches eff == max_len and this reduces to ``slot < cache_len``.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    eff = k_cache.shape[1]
    qh = (q.reshape(B, KV, G, D) * (D ** -0.5)).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bckd->bkgc", qh, k_cache.astype(jnp.float32))
    mask = jnp.arange(eff) < jnp.minimum(cache_len, eff)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D)


def _masked_decode_attention(q, k, v, valid):
    """Single-position attention with an explicit per-slot validity mask.

    q: (B, 1, H, D); k/v: (B, L, KV, D); valid: (B, L) bool.  Unlike
    ``_decode_attention`` the mask is 2-D (per-slot lengths differ under
    continuous batching) and an all-False row (inactive slot) yields zeros
    instead of NaN — the scheduler discards those logits, but they must not
    poison debug NaN-checks.
    """
    B, _, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = (q.reshape(B, KV, G, D) * (D ** -0.5)).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bckd->bkgc", qh, k.astype(jnp.float32))
    m = valid[:, None, None, :]
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(m, scores, neg)
    smax = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(m, jnp.exp(scores - smax), 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgc,bckd->bkgd", p / denom, v.astype(jnp.float32))
    return out.reshape(B, 1, H, D)


def _paged_decode_attention(q, k, v, cfg, cache, paged):
    """Paged-KV decode (DESIGN.md §17): quantize-on-append the new k/v rows
    into the slot's current page, then gather-dequant every table page and
    attend under the per-slot length (and SWA window) mask.

    cache: {"k_codes": (n_pages, page, KV, W), "k_absmax": (n_pages, page,
    KV), "v_codes", "v_absmax"}; q/k/v: (B, 1, {H|KV}, Dh).
    Returns (out (B, 1, H, Dh), new_cache).
    """
    from repro.kernels import paged_kv

    n_pages, page = cache["k_codes"].shape[:2]
    bits = paged_kv.bits_of(cfg.head_dim, cache["k_codes"].shape[-1])
    pos = paged.positions                                # (B,) int32
    active = pos >= 0
    pos_c = jnp.maximum(pos, 0)
    B = pos.shape[0]
    # Destination (physical page, offset) of this step's row per slot;
    # inactive slots are pointed out of range so the scatter drops them.
    logical = pos_c // page
    ppage = paged.page_table[jnp.arange(B), logical]
    ppage = jnp.where(active & (ppage >= 0), ppage, n_pages)
    off = pos_c % page
    new_cache = dict(cache)
    for name, row in (("k", k), ("v", v)):
        new_cache[f"{name}_codes"], new_cache[f"{name}_absmax"] = \
            paged_kv.append_rows(cache[f"{name}_codes"],
                                 cache[f"{name}_absmax"],
                                 row[:, 0], ppage, off, bits)
    dt = q.dtype
    k_all = paged_kv.gather_pages(new_cache["k_codes"],
                                  new_cache["k_absmax"], paged.page_table,
                                  bits=bits, dtype=dt, impl=paged.impl)
    v_all = paged_kv.gather_pages(new_cache["v_codes"],
                                  new_cache["v_absmax"], paged.page_table,
                                  bits=bits, dtype=dt, impl=paged.impl)
    L = k_all.shape[1]
    idx = jnp.arange(L)[None, :]
    valid = active[:, None] & (idx <= pos_c[:, None])
    if cfg.attn_type == "swa" and cfg.window:
        valid &= idx > (pos_c[:, None] - cfg.window)
    out = _masked_decode_attention(q, k_all, v_all, valid)
    return out, new_cache


def _write_prefill_cache(buf, new):
    """Store S new kv rows into a ring buffer of physical size eff, such that
    position p lives in slot p % eff (static S)."""
    S = new.shape[1]
    eff = buf.shape[1]
    new = new.astype(buf.dtype)
    if S >= eff:
        last = new[:, S - eff:]
        return jnp.roll(last, (S - eff) % eff, axis=1)
    return jax.lax.dynamic_update_slice_in_dim(buf, new, 0, axis=1)


def apply_attention(p, x, cfg, *, positions, cache=None, cache_len=None,
                    paged=None):
    """x: (B, S, d).

    cache=None            -> train forward, no state io.
    cache given, S == 1   -> decode: write kv at slot (cache_len-1) % eff;
                             with ``paged`` (a PagedContext) the cache is
                             the shared quantized page pool and per-slot
                             positions/page tables drive append + attend.
    cache given, S > 1    -> prefill: full chunked attention + bulk cache fill.
    Returns (out, new_cache)."""
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KV, Dh)
    v = v.reshape(B, S, KV, Dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    window = cfg.window if cfg.attn_type == "swa" else 0
    quant_cache = cache is not None and "k_codes" in cache
    if paged is not None and cache is not None and S == 1:
        out, new_cache = _paged_decode_attention(q, k, v, cfg, cache, paged)
        out = constrain(out.reshape(B, S, H * Dh).astype(dt), "dp", None, "tp")
        return out @ p["wo"].astype(dt), new_cache
    if cache is None:
        out = _chunked_causal_attention(q, k, v, window=window,
                                        chunk=cfg.attn_chunk)
        new_cache = None
    elif S == 1 and quant_cache:
        eff = cache["k_codes"].shape[1]
        idx = (cache_len - 1) % eff
        new_cache = dict(cache)
        for name, row in (("k", k), ("v", v)):
            codes, absmax = kv_quantize(row)        # (B,1,KV,D)/(B,1,KV)
            new_cache[f"{name}_codes"] = jax.lax.dynamic_update_slice_in_dim(
                cache[f"{name}_codes"], codes, idx, axis=1)
            new_cache[f"{name}_absmax"] = jax.lax.dynamic_update_slice_in_dim(
                cache[f"{name}_absmax"], absmax, idx, axis=1)
        k_cache = kv_dequantize(new_cache["k_codes"], new_cache["k_absmax"], dt)
        v_cache = kv_dequantize(new_cache["v_codes"], new_cache["v_absmax"], dt)
        out = _decode_attention(q, k_cache, v_cache, cache_len)
    elif S == 1:
        eff = cache["k"].shape[1]
        idx = (cache_len - 1) % eff
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        out = _decode_attention(q, k_cache, v_cache, cache_len)
        new_cache = {"k": k_cache, "v": v_cache}
    elif quant_cache:
        out = _chunked_causal_attention(q, k, v, window=window,
                                        chunk=cfg.attn_chunk)
        new_cache = {}
        for name, row in (("k", k), ("v", v)):
            codes, absmax = kv_quantize(row)
            new_cache[f"{name}_codes"] = _write_prefill_cache(
                cache[f"{name}_codes"], codes)
            new_cache[f"{name}_absmax"] = _write_prefill_cache(
                cache[f"{name}_absmax"], absmax)
    else:
        out = _chunked_causal_attention(q, k, v, window=window,
                                        chunk=cfg.attn_chunk)
        new_cache = {"k": _write_prefill_cache(cache["k"], k),
                     "v": _write_prefill_cache(cache["v"], v)}
    out = constrain(out.reshape(B, S, H * Dh).astype(dt), "dp", None, "tp")
    return out @ p["wo"].astype(dt), new_cache


# ------------------------------------------------------------------------ MLP

def init_mlp(key, cfg, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.gated_mlp:
        p = {"w_gate": dense_init(ks[0], (d, f)),
             "w_in": dense_init(ks[1], (d, f)),
             "w_out": dense_init(ks[2], (f, d), scale=1.0 / np.sqrt(f))}
        s = {"w_gate": ("embed", "mlp"), "w_in": ("embed", "mlp"),
             "w_out": ("mlp", "embed")}
    else:
        p = {"w_in": dense_init(ks[1], (d, f)),
             "w_out": dense_init(ks[2], (f, d), scale=1.0 / np.sqrt(f))}
        s = {"w_in": ("embed", "mlp"), "w_out": ("mlp", "embed")}
    return p, s


def apply_mlp(p, x, cfg):
    dt = x.dtype
    if cfg.gated_mlp:
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_in"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w_in"].astype(dt))
    h = constrain(h, "dp", None, "tp")
    return h @ p["w_out"].astype(dt)
