"""Checkpointed (sqrt-N) time scan for recurrent layers.

A plain ``lax.scan`` over T timesteps stores every per-step carry for the
backward pass — for mLSTM's matrix memory that is T x (B,H,D,D) f32, i.e.
~350 GiB/device for xlstm-350m train_4k (measured in the dry-run baseline;
EXPERIMENTS.md §Perf iteration B).  ``checkpointed_scan`` nests two scans:
the outer saves one carry per chunk, the inner is wrapped in
``jax.checkpoint`` so its carries are recomputed during backward.  Memory
drops from O(T) to O(T/K + K) carries; K ~ sqrt(T) minimizes it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def checkpointed_scan(f, init, xs, *, chunk: int = 64):
    """Semantics of ``jax.lax.scan(f, init, xs)`` with sqrt-N remat.

    xs leaves must share leading dim T; T is padded to a multiple of
    ``chunk`` internally (f must tolerate processing padded steps only if
    T % chunk != 0 — we instead require divisibility and fall back to plain
    scan otherwise)."""
    leaves = jax.tree_util.tree_leaves(xs)
    T = leaves[0].shape[0]
    if T <= chunk or T % chunk != 0:
        return jax.lax.scan(f, init, xs)
    n_chunks = T // chunk

    def reshape(x):
        return x.reshape((n_chunks, chunk) + x.shape[1:])

    xs_c = jax.tree_util.tree_map(reshape, xs)

    @jax.checkpoint
    def inner(carry, xc):
        return jax.lax.scan(f, carry, xc)

    carry, ys_c = jax.lax.scan(inner, init, xs_c)

    def unshape(y):
        return y.reshape((T,) + y.shape[2:])

    return carry, jax.tree_util.tree_map(unshape, ys_c)
