"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, exponential gating)
and sLSTM (scalar memory, recurrent gates), both with the paper's stabilizer
state m to keep exponential gates bounded.

Decode state is O(1) per layer (mLSTM: C (B,H,D,D), n (B,H,D), m (B,H);
sLSTM: c/n/h (B,W), m (B,W)) — xlstm-350m therefore runs the long_500k cell.

d_ff = 0 in the assigned config: blocks carry their own up/down projections
(mLSTM: proj factor 2 with SiLU gate branch; sLSTM: GeLU MLP factor 4/3),
matching the xLSTM block layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.scan_utils import checkpointed_scan


# ------------------------------------------------------------------- mLSTM

def init_mlstm_block(key, cfg):
    d = cfg.d_model
    W = int(d * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    D = W // H
    # q/k/v/output-gate are per-head block-diagonal (xLSTM head structure;
    # also what keeps the 350m config at its nominal parameter budget).
    p = {
        "w_up": layers.dense_init(ks[0], (d, W)),
        "w_gate": layers.dense_init(ks[1], (d, W)),
        "wq": layers.dense_init(ks[2], (H, D, D), scale=1.0 / np.sqrt(D)),
        "wk": layers.dense_init(ks[3], (H, D, D), scale=1.0 / np.sqrt(D)),
        "wv": layers.dense_init(ks[4], (H, D, D), scale=1.0 / np.sqrt(D)),
        "w_if": layers.dense_init(ks[5], (W, 2 * H), scale=0.02),
        "b_i": jnp.full((H,), -10.0, jnp.float32),   # input gate starts closed
        "b_f": jnp.full((H,), 3.0, jnp.float32),     # forget gate starts open
        "wo_gate": layers.dense_init(ks[6], (H, D, D), scale=1.0 / np.sqrt(D)),
        "w_down": layers.dense_init(ks[7], (W, d), scale=1.0 / np.sqrt(W)),
    }
    s = {
        "w_up": ("embed", "mlp"), "w_gate": ("embed", "mlp"),
        "wq": ("heads", "unsharded", "head_out"), "wk": ("heads", "unsharded", "head_out"),
        "wv": ("heads", "unsharded", "head_out"),
        "w_if": ("mlp", "unsharded"), "b_i": ("unsharded",), "b_f": ("unsharded",),
        "wo_gate": ("heads", "unsharded", "head_out"), "w_down": ("mlp", "embed"),
    }
    return p, s


def _mlstm_scan(q, k, v, log_i, log_f, state):
    """q/k/v: (B, S, H, D) f32; log_i/log_f: (B, S, H).
    state: (C (B,H,D,D), n (B,H,D), m (B,H)). Returns (h (B,S,H,D), state)."""
    D = q.shape[-1]
    k = k / np.sqrt(D)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, li, lf = inp                   # (B,H,D) / (B,H)
        m_new = jnp.maximum(lf + m, li)
        i_p = jnp.exp(li - m_new)[..., None]          # (B,H,1)
        f_p = jnp.exp(lf + m - m_new)[..., None]
        C = f_p[..., None] * C + i_p[..., None] * (v_t[..., :, None] * k_t[..., None, :])
        n = f_p * n + i_p * k_t
        denom = jnp.maximum(jnp.abs(jnp.sum(n * q_t, axis=-1, keepdims=True)), 1.0)
        h = jnp.einsum("bhvk,bhk->bhv", C, q_t) / denom
        return (C, n, m_new), h

    inps = tuple(x.swapaxes(0, 1) for x in (q, k, v, log_i, log_f))
    (state, hs) = checkpointed_scan(step, state, inps)
    return hs.swapaxes(0, 1), state


def apply_mlstm_block(p, x, cfg, *, state=None):
    dt = x.dtype
    B, S, d = x.shape
    H = cfg.n_heads
    W = p["w_up"].shape[1]
    D = W // H
    u = (x @ p["w_up"].astype(dt)).astype(jnp.float32)
    gate = jax.nn.silu((x @ p["w_gate"].astype(dt)).astype(jnp.float32))
    uh = u.reshape(B, S, H, D)
    q = jnp.einsum("bshd,hde->bshe", uh, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", uh, p["wk"])
    v = jnp.einsum("bshd,hde->bshe", uh, p["wv"])
    if_ = u @ p["w_if"]                                # (B,S,2H)
    log_i = jax.nn.log_sigmoid(if_[..., :H] + p["b_i"])
    log_f = jax.nn.log_sigmoid(if_[..., H:] + p["b_f"])
    if state is None:
        state = (jnp.zeros((B, H, D, D), jnp.float32),
                 jnp.zeros((B, H, D), jnp.float32),
                 jnp.zeros((B, H), jnp.float32))
    h, state = _mlstm_scan(q, k, v, log_i, log_f, state)
    o = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", uh, p["wo_gate"]))
    out = (o * h).reshape(B, S, W) * gate
    return (out.astype(dt) @ p["w_down"].astype(dt)), state


# ------------------------------------------------------------------- sLSTM

def init_slstm_block(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(key, 4)
    f = int(d * cfg.slstm_proj_factor)
    p = {
        # input projections for z, i, f, o (fused)
        "w_zifo": layers.dense_init(ks[0], (d, 4 * d)),
        # recurrent block-diagonal weights per head: (H, 4, Dh, Dh)
        "r_zifo": layers.dense_init(ks[1], (H, 4, d // H, d // H),
                                    scale=1.0 / np.sqrt(d // H)),
        "b_zifo": jnp.concatenate([
            jnp.zeros((d,)), jnp.full((d,), -5.0),   # i starts mostly closed
            jnp.full((d,), 3.0), jnp.zeros((d,))]).astype(jnp.float32),
        "w_up": layers.dense_init(ks[2], (d, f)),
        "w_down": layers.dense_init(ks[3], (f, d), scale=1.0 / np.sqrt(f)),
    }
    s = {
        "w_zifo": ("embed", "mlp"), "r_zifo": ("heads", "unsharded", "unsharded", "unsharded"),
        "b_zifo": ("mlp",), "w_up": ("embed", "mlp"), "w_down": ("mlp", "embed"),
    }
    return p, s


def apply_slstm_block(p, x, cfg, *, state=None):
    """sLSTM with exponential input gate + stabilizer (xLSTM eqs. 18-27)."""
    dt = x.dtype
    B, S, d = x.shape
    H = cfg.n_heads
    Dh = d // H
    zifo_in = (x @ p["w_zifo"].astype(dt)).astype(jnp.float32) + p["b_zifo"]
    zifo_in = zifo_in.reshape(B, S, 4, d)
    if state is None:
        z0 = jnp.zeros((B, d), jnp.float32)
        state = (z0, z0, z0, jnp.full((B, d), -jnp.inf, jnp.float32))

    r = p["r_zifo"]                                   # (H,4,Dh,Dh)

    def step(carry, inp):
        c, n, h, m = carry
        pre = inp                                      # (B,4,d)
        hh = h.reshape(B, H, Dh)
        rec = jnp.einsum("bhk,hgkj->bghj", hh, r).reshape(B, 4, d)
        z_t = jnp.tanh(pre[:, 0] + rec[:, 0])
        log_i = pre[:, 1] + rec[:, 1]                  # exponential input gate
        log_f = jax.nn.log_sigmoid(pre[:, 2] + rec[:, 2])
        o_t = jax.nn.sigmoid(pre[:, 3] + rec[:, 3])
        m_new = jnp.maximum(log_f + m, log_i)
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        i_p = jnp.exp(log_i - m_safe)
        f_p = jnp.where(jnp.isinf(m), 0.0, jnp.exp(log_f + m - m_safe))
        c = f_p * c + i_p * z_t
        n = f_p * n + i_p
        h_new = o_t * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new

    (state, hs) = checkpointed_scan(step, state, zifo_in.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(dt)                  # (B,S,d)
    out = jax.nn.gelu(y @ p["w_up"].astype(dt)) @ p["w_down"].astype(dt)
    return out, state
