"""Activation sharding constraints (divisibility-safe, context-driven).

The launcher configures the mesh axis groups once
(``set_activation_axes(dp_axes, tp_axis, sizes)``); model code then calls
``constrain(x, "dp", None, "tp")`` at fusion boundaries.  Every axis is
dropped silently if it does not divide the corresponding dim — the same
fallback philosophy as the param-sharding resolver, which is what lets one
model codebase serve all 10 archs (kv=1 MQA through 384-expert MoE) on a
fixed (pod, data, model) mesh.

On single-device runs (CPU tests/examples) no axes are configured and every
call is a no-op.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_CTX = {"dp": None, "tp": None, "dp_size": 1, "tp_size": 1,
        "block_specs": None}


def set_activation_axes(dp_axes=None, tp_axis=None, dp_size=1, tp_size=1):
    _CTX.update(dp=dp_axes, tp=tp_axis,
                dp_size=dp_size, tp_size=tp_size)


def clear_activation_axes():
    set_activation_axes(None, None, 1, 1)
    _CTX["block_specs"] = None


def active() -> bool:
    return _CTX["dp"] is not None or _CTX["tp"] is not None


def _axes_and_size(kind):
    if kind == "all":
        dp, tp = _CTX["dp"], _CTX["tp"]
        axes = tuple(dp or ()) + ((tp,) if tp else ())
        return (axes or None), _CTX["dp_size"] * _CTX["tp_size"]
    return _CTX[kind], _CTX[f"{kind}_size"]


def constrain(x: jax.Array, *dims):
    """dims: one of None | 'dp' | 'tp' | 'all' per array dim (trailing dims
    may be omitted).  Axes that don't divide the dim are dropped."""
    if not active():
        return x
    spec = []
    used = set()
    for i in range(x.ndim):
        want = dims[i] if i < len(dims) else None
        if want is None or want in used:
            spec.append(None)
            continue
        axes, size = _axes_and_size(want)
        if axes is None or size <= 1 or x.shape[i] % size != 0:
            spec.append(None)
            continue
        spec.append(axes)
        used.add(want)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def attn_score_dims(KV: int, G: int, S: int):
    """Constraint dims for (B, KV, G, S, C) attention tensors: prefer kv-head
    TP, then q-group TP, then sequence TP (always divides for 4k+ seqs)."""
    tp_size = _CTX["tp_size"]
    if tp_size > 1 and KV % tp_size == 0:
        return ("dp", "tp", None, None, None)
    if tp_size > 1 and G % tp_size == 0:
        return ("dp", None, "tp", None, None)
    if tp_size > 1 and S % tp_size == 0:
        return ("dp", None, None, "tp", None)
    return ("dp", None, None, None, None)


def set_block_param_specs(specs_tree):
    """Per-leaf PartitionSpecs for the stacked scan params (leading 'layers'
    dim included).  Inside the scan body each per-layer slice is constrained
    to spec[1:], which is what lets SPMD keep the backward xs-grad carry
    sharded instead of replicated (MaxText's scanned-FSDP pattern;
    EXPERIMENTS.md §Perf A4)."""
    _CTX["block_specs"] = specs_tree


def constrain_block_params(bp):
    specs = _CTX["block_specs"]
    if specs is None:
        return bp

    def one(x, sh):
        spec = tuple(sh.spec)[1:] if len(sh.spec) else ()
        spec = spec + (None,) * (x.ndim - len(spec))
        if all(e is None for e in spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))

    return jax.tree_util.tree_map(one, bp, specs)
