"""Config-driven decoder LM assembly covering all assigned families.

A model is a stack of blocks cycled from ``cfg.block_pattern``
(attn | rglru | mlstm | slstm).  Layers are grouped into *super-blocks* (one
full pattern cycle) whose params are stacked and iterated with
``jax.lax.scan`` — bounded HLO size for the 512-device dry-run; remainder
layers (n_layers % len(pattern)) are applied unscanned.

Pure functional API:
  init_model(cfg, key)                       -> (params, logical_specs)
  forward(cfg, params, tokens, embeds=None)  -> (logits, metrics)     # train
  prefill(cfg, params, tokens, ...)          -> (logits, cache)
  decode_step(cfg, params, token, cache, pos)-> (logits, cache)
  init_cache(cfg, batch, max_len)            -> cache pytree
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import embedding as emb
from repro.models import layers, moe, recurrent, xlstm
from repro.models.constrain import constrain, constrain_block_params

Pytree = Any


# ------------------------------------------------------------------ blocks

def _init_block(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "attn":
        attn_p, attn_s = layers.init_attention(ks[0], cfg)
        n1p, n1s = layers.init_norm(cfg.d_model, cfg.norm_type)
        p = {"norm1": n1p, "attn": attn_p}
        s = {"norm1": n1s, "attn": attn_s}
        if cfg.is_moe:
            mp, ms = moe.init_moe(ks[1], cfg)
            p["moe"], s["moe"] = mp, ms
        else:
            mp, ms = layers.init_mlp(ks[1], cfg)
            p["mlp"], s["mlp"] = mp, ms
        if not cfg.parallel_block:
            n2p, n2s = layers.init_norm(cfg.d_model, cfg.norm_type)
            p["norm2"], s["norm2"] = n2p, n2s
        return p, s
    if kind == "rglru":
        rp, rs = recurrent.init_rglru_block(ks[0], cfg)
        n1p, n1s = layers.init_norm(cfg.d_model, cfg.norm_type)
        p = {"norm1": n1p, "rec": rp}
        s = {"norm1": n1s, "rec": rs}
        if cfg.d_ff:
            n2p, n2s = layers.init_norm(cfg.d_model, cfg.norm_type)
            mp, ms = layers.init_mlp(ks[1], cfg)
            p.update(norm2=n2p, mlp=mp)
            s.update(norm2=n2s, mlp=ms)
        return p, s
    if kind == "mlstm":
        cp, cs = xlstm.init_mlstm_block(ks[0], cfg)
        n1p, n1s = layers.init_norm(cfg.d_model, cfg.norm_type)
        return {"norm1": n1p, "cell": cp}, {"norm1": n1s, "cell": cs}
    if kind == "slstm":
        cp, cs = xlstm.init_slstm_block(ks[0], cfg)
        n1p, n1s = layers.init_norm(cfg.d_model, cfg.norm_type)
        return {"norm1": n1p, "cell": cp}, {"norm1": n1s, "cell": cs}
    raise ValueError(kind)


def _apply_block(p, x, cfg, kind: str, *, positions, state=None,
                 cache_len=None, paged=None):
    """Returns (x_out, new_state, metrics). ``state``: layer cache for
    decode (attn: {k,v}; recurrent kinds: cell state), or None.  ``paged``:
    layers.PagedContext during paged-KV decode (DESIGN.md §17) — only attn
    blocks consume it; recurrent kinds keep their per-slot dense state."""
    metrics = {}
    if kind == "attn":
        h = layers.apply_norm(p["norm1"], x, cfg.norm_type)
        a_out, new_cache = layers.apply_attention(
            p["attn"], h, cfg, positions=positions, cache=state,
            cache_len=cache_len, paged=paged)
        if cfg.parallel_block:
            if cfg.is_moe:
                f_out, metrics = moe.apply_moe(p["moe"], h, cfg)
            else:
                f_out = layers.apply_mlp(p["mlp"], h, cfg)
            x = x + a_out + f_out
        else:
            x = x + a_out
            h2 = layers.apply_norm(p["norm2"], x, cfg.norm_type)
            if cfg.is_moe:
                f_out, metrics = moe.apply_moe(p["moe"], h2, cfg)
            else:
                f_out = layers.apply_mlp(p["mlp"], h2, cfg)
            x = x + f_out
        return x, new_cache, metrics
    if kind == "rglru":
        h = layers.apply_norm(p["norm1"], x, cfg.norm_type)
        r_out, new_state = recurrent.apply_rglru_block(p["rec"], h, cfg, state=state)
        x = x + r_out
        if cfg.d_ff:
            h2 = layers.apply_norm(p["norm2"], x, cfg.norm_type)
            x = x + layers.apply_mlp(p["mlp"], h2, cfg)
        return x, new_state, metrics
    if kind in ("mlstm", "slstm"):
        h = layers.apply_norm(p["norm1"], x, cfg.norm_type)
        fn = xlstm.apply_mlstm_block if kind == "mlstm" else xlstm.apply_slstm_block
        c_out, new_state = fn(p["cell"], h, cfg, state=state)
        return x + c_out, new_state, metrics
    raise ValueError(kind)


# ----------------------------------------------------------- init_model

def init_model(cfg, key) -> tuple[Pytree, Pytree]:
    keys = jax.random.split(key, 6)
    params, specs = {}, {}
    params["embed"], specs["embed"] = emb.init_embedding(keys[0], cfg)
    fp, fs = emb.init_frontend(keys[1], cfg)
    if fp:
        params["frontend"], specs["frontend"] = fp, fs
    pattern = cfg.block_pattern

    def init_super(k):
        ks = jax.random.split(k, len(pattern))
        ps, ss = {}, {}
        for i, kind in enumerate(pattern):
            bp, bs = _init_block(ks[i], cfg, kind)
            ps[f"b{i}_{kind}"] = bp
            ss[f"b{i}_{kind}"] = bs
        return ps, ss

    n_super = cfg.n_superblocks
    if cfg.scan_layers and n_super > 0:
        sk = jax.random.split(keys[2], n_super)
        stacked = jax.vmap(lambda k: init_super(k)[0])(sk)
        _, sspec = init_super(keys[2])
        # prepend the scan ("layers") logical axis to every spec tuple
        sspec = jax.tree_util.tree_map(
            lambda t: ("layers",) + t, sspec,
            is_leaf=lambda t: isinstance(t, tuple) and all(isinstance(e, str) for e in t))
        params["blocks"], specs["blocks"] = stacked, sspec
    elif n_super > 0:
        blocks, bspecs = [], []
        sk = jax.random.split(keys[2], n_super)
        for i in range(n_super):
            bp, bs = init_super(sk[i])
            blocks.append(bp)
            bspecs.append(bs)
        params["blocks_list"], specs["blocks_list"] = blocks, bspecs
    rem = cfg.n_remainder_layers
    if rem:
        rk = jax.random.split(keys[3], rem)
        rp, rs = [], []
        for i in range(rem):
            kind = pattern[i % len(pattern)]
            bp, bs = _init_block(rk[i], cfg, kind)
            rp.append({f"{kind}": bp})
            rs.append({f"{kind}": bs})
        params["rem_blocks"], specs["rem_blocks"] = rp, rs
    nf, nfs = layers.init_norm(cfg.d_model, cfg.norm_type)
    params["final_norm"], specs["final_norm"] = nf, nfs
    hp, hs = emb.init_head(keys[4], cfg)
    if hp:
        params["head"], specs["head"] = hp, hs
    return params, specs


# ------------------------------------------------------------- forward

def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _superblock_fwd(bp, x, cfg, positions, states=None, cache_len=None,
                    paged=None):
    """Apply one super-block. states: dict keyed like bp or None."""
    bp = constrain_block_params(bp)
    new_states, metrics_acc = {}, []
    for i, kind in enumerate(cfg.block_pattern):
        name = f"b{i}_{kind}"
        st = states[name] if states is not None else None
        # Sequence parallelism: the residual stream between TP regions is
        # sharded on (batch->dp, seq->tp).  Norm/residual work shrinks by
        # tp_size and the Megatron f32 dL/dx all-reduces become bf16
        # gathers/reduce-scatters (EXPERIMENTS.md §Perf C4).
        x = constrain(x, "dp", "tp", None)
        x, ns, mt = _apply_block(bp[name], x, cfg, kind, positions=positions,
                                 state=st, cache_len=cache_len, paged=paged)
        new_states[name] = ns
        if mt:
            metrics_acc.append(mt)
    agg = {}
    if metrics_acc:
        for k in metrics_acc[0]:
            agg[k] = jnp.mean(jnp.stack([m[k] for m in metrics_acc]))
    return x, new_states, agg


def _run_blocks(params, x, cfg, positions, caches=None, cache_len=None,
                paged=None):
    """Run all layers. caches: None (no state io) or pytree with leading
    n_super dim for the scanned part + list for remainder.  ``paged`` (a
    layers.PagedContext) rides into the scan body as a loop constant — the
    page table and per-slot positions are layer-invariant."""
    metrics = {}
    decode_mode = caches is not None

    if cfg.scan_layers and cfg.n_superblocks > 0:
        if decode_mode:
            def body(h, xs):
                bp, st = xs
                h, ns, mt = _superblock_fwd(bp, h, cfg, positions, st,
                                            cache_len, paged)
                return h, (ns, mt)
            x, (new_scan_cache, mts) = jax.lax.scan(
                body, x, (params["blocks"], caches["scan"]))
        else:
            def body(h, bp):
                h, _, mt = _superblock_fwd(bp, h, cfg, positions, None, None)
                return h, mt
            body = _remat_wrap(body, cfg)
            x, mts = jax.lax.scan(body, x, params["blocks"])
            new_scan_cache = None
        if mts:
            metrics = {k: jnp.mean(v) for k, v in mts.items()}
    elif "blocks_list" in params:
        new_scan_cache = []
        for i, bp in enumerate(params["blocks_list"]):
            st = caches["scan"][i] if decode_mode else None
            x, ns, mt = _superblock_fwd(bp, x, cfg, positions, st, cache_len,
                                        paged)
            new_scan_cache.append(ns)
            metrics.update(mt)
        if not decode_mode:
            new_scan_cache = None
    else:
        new_scan_cache = None

    new_rem = []
    if "rem_blocks" in params:
        for i, bp in enumerate(params["rem_blocks"]):
            kind = cfg.block_pattern[i % len(cfg.block_pattern)]
            st = caches["rem"][i] if decode_mode else None
            x, ns, mt = _apply_block(bp[kind], x, cfg, kind,
                                     positions=positions, state=st,
                                     cache_len=cache_len, paged=paged)
            new_rem.append(ns)
            metrics.update(mt)

    new_caches = {"scan": new_scan_cache, "rem": new_rem} if decode_mode else None
    return x, new_caches, metrics


def forward(cfg, params, tokens, embeds=None):
    """Training/eval forward. tokens: (B, S_tok) int32; embeds: optional
    (B, frontend_tokens, d) stub features. Returns (logits (B,S,V), metrics)."""
    x = emb.apply_embedding(params["embed"], tokens, cfg)
    if embeds is not None and "frontend" in params:
        fx = emb.apply_frontend(params["frontend"], embeds, cfg)
        x = jnp.concatenate([fx.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    x, _, metrics = _run_blocks(params, x, cfg, positions)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = emb.apply_head(params.get("head", {}), x, params["embed"], cfg)
    return logits, metrics


# --------------------------------------------------------------- serving

def _init_layer_cache(cfg, kind, batch, max_len, n_super=None):
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.compute_dtype)
    lead = (n_super,) if n_super else ()
    if kind == "attn":
        eff = min(max_len, cfg.window) if cfg.attn_type == "swa" and cfg.window else max_len
        # SWA caches are ring buffers of size window (long_500k: bounded
        # cache is the point).
        if cfg.kv_cache_bits == 8:
            # beyond-paper: block-wise int8 KV cache (layers.kv_quantize)
            return {"k_codes": jnp.zeros(lead + (batch, eff, KV, Dh), jnp.uint8),
                    "k_absmax": jnp.zeros(lead + (batch, eff, KV), jnp.float32),
                    "v_codes": jnp.zeros(lead + (batch, eff, KV, Dh), jnp.uint8),
                    "v_absmax": jnp.zeros(lead + (batch, eff, KV), jnp.float32)}
        return {"k": jnp.zeros(lead + (batch, eff, KV, Dh), dt),
                "v": jnp.zeros(lead + (batch, eff, KV, Dh), dt)}
    if kind == "rglru":
        W = cfg.lru_width or cfg.d_model
        return {"h": jnp.zeros(lead + (batch, W), jnp.float32),
                "conv": jnp.zeros(lead + (batch, cfg.conv_width - 1, W), jnp.float32)}
    if kind == "mlstm":
        H = cfg.n_heads
        D = int(cfg.d_model * cfg.mlstm_proj_factor) // H
        return (jnp.zeros(lead + (batch, H, D, D), jnp.float32),
                jnp.zeros(lead + (batch, H, D), jnp.float32),
                jnp.zeros(lead + (batch, H), jnp.float32))
    if kind == "slstm":
        d = cfg.d_model
        z = jnp.zeros(lead + (batch, d), jnp.float32)
        return (z, z, z, jnp.full(lead + (batch, d), -jnp.inf, jnp.float32))
    raise ValueError(kind)


def init_cache(cfg, batch, max_len):
    if cfg.scan_layers and cfg.n_superblocks > 0:
        scan_cache = {
            f"b{i}_{kind}": _init_layer_cache(cfg, kind, batch, max_len,
                                              n_super=cfg.n_superblocks)
            for i, kind in enumerate(cfg.block_pattern)}
    else:
        scan_cache = [
            {f"b{i}_{kind}": _init_layer_cache(cfg, kind, batch, max_len)
             for i, kind in enumerate(cfg.block_pattern)}
            for _ in range(cfg.n_superblocks)]
    rem = [
        _init_layer_cache(cfg, cfg.block_pattern[i % len(cfg.block_pattern)],
                          batch, max_len)
        for i in range(cfg.n_remainder_layers)]
    return {"scan": scan_cache, "rem": rem}


def decode_step(cfg, params, token, caches, pos):
    """token: (B, 1) int32; pos: scalar int32 — 0-based index of this token.
    Returns (logits (B, 1, V), new_caches)."""
    x = emb.apply_embedding(params["embed"], token, cfg)
    positions = jnp.full((1, 1), pos, jnp.int32)
    x, new_caches, _ = _run_blocks(params, x, cfg, positions, caches=caches,
                                   cache_len=pos + 1)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = emb.apply_head(params.get("head", {}), x, params["embed"], cfg)
    return logits, new_caches


# ------------------------------------------------ paged serving (§17)

def _init_paged_layer_cache(cfg, kind, n_slots, n_pages, page_size, kv_bits,
                            n_super=None):
    """Layer cache for the paged serving path: attn layers share one
    quantized page pool (no batch dim — the page table maps slots to
    pages); recurrent kinds keep per-slot dense state exactly as the
    contiguous cache does."""
    from repro.kernels import paged_kv
    lead = (n_super,) if n_super else ()
    if kind == "attn":
        KV, Dh = cfg.n_kv_heads, cfg.head_dim
        W = paged_kv.packed_row_width(Dh, kv_bits)
        return {"k_codes": jnp.zeros(lead + (n_pages, page_size, KV, W),
                                     jnp.uint8),
                "k_absmax": jnp.zeros(lead + (n_pages, page_size, KV),
                                      jnp.float32),
                "v_codes": jnp.zeros(lead + (n_pages, page_size, KV, W),
                                     jnp.uint8),
                "v_absmax": jnp.zeros(lead + (n_pages, page_size, KV),
                                      jnp.float32)}
    return _init_layer_cache(cfg, kind, n_slots, page_size, n_super=n_super)


def init_paged_cache(cfg, n_slots, n_pages, page_size, kv_bits=8):
    """Paged serving cache pytree (same {"scan","rem"} structure as
    ``init_cache``): per attn layer a pool of ``n_pages`` pages of
    ``page_size`` positions, block-wise quantized to ``kv_bits`` (8-bit
    plain / 4-bit packed codes, DESIGN.md §17)."""
    if cfg.scan_layers and cfg.n_superblocks > 0:
        scan_cache = {
            f"b{i}_{kind}": _init_paged_layer_cache(
                cfg, kind, n_slots, n_pages, page_size, kv_bits,
                n_super=cfg.n_superblocks)
            for i, kind in enumerate(cfg.block_pattern)}
    else:
        scan_cache = [
            {f"b{i}_{kind}": _init_paged_layer_cache(
                cfg, kind, n_slots, n_pages, page_size, kv_bits)
             for i, kind in enumerate(cfg.block_pattern)}
            for _ in range(cfg.n_superblocks)]
    rem = [
        _init_paged_layer_cache(
            cfg, cfg.block_pattern[i % len(cfg.block_pattern)],
            n_slots, n_pages, page_size, kv_bits)
        for i in range(cfg.n_remainder_layers)]
    return {"scan": scan_cache, "rem": rem}


def paged_decode_step(cfg, params, token, caches, paged):
    """One continuous-batching decode step over every slot.

    token: (n_slots, 1) int32 (the last sampled token per slot; inactive
    slots carry a dummy).  ``paged``: layers.PagedContext with per-slot
    positions and the page table.  Returns (logits (n_slots, 1, V),
    new_caches) — pages are appended in place (donate ``caches`` when
    jitting; the serve contract audits this, DESIGN.md §17).
    """
    x = emb.apply_embedding(params["embed"], token, cfg)
    positions = jnp.maximum(paged.positions, 0)[:, None]     # (B, 1)
    x, new_caches, _ = _run_blocks(params, x, cfg, positions, caches=caches,
                                   cache_len=None, paged=paged)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = emb.apply_head(params.get("head", {}), x, params["embed"], cfg)
    return logits, new_caches


def _commit_attn_pages(cfg, paged_layer, dense_layer, table_row,
                       prompt_len, kv_bits, lead):
    """Quantize a batch-1 dense prefill cache's k/v rows into the slot's
    allocated pages.  SWA dense caches are rings holding only the last
    ``eff`` positions; exactly those rows are committed (older positions
    are outside every future window, their pages stay zero and masked)."""
    from repro.kernels import paged_kv
    if "k" not in dense_layer:
        raise ValueError("paged commit needs a 16-bit dense prefill cache "
                         "(cfg.kv_cache_bits == 16 for the prefill config)")
    page = paged_layer["k_codes"].shape[2 if lead else 1]
    eff = dense_layer["k"].shape[2 if lead else 1]
    pos = np.arange(prompt_len - min(prompt_len, eff), prompt_len)
    ring_idx = jnp.asarray(pos % eff)
    pids = table_row[jnp.asarray(pos // page)]
    offs = jnp.asarray(pos % page)
    out = dict(paged_layer)
    for name in ("k", "v"):
        dense = dense_layer[name]
        rows = dense[:, 0][:, ring_idx] if lead else dense[0][ring_idx]
        codes, absmax = paged_kv.quantize_rows(rows, kv_bits)
        if lead:
            out[f"{name}_codes"] = paged_layer[f"{name}_codes"].at[
                :, pids, offs].set(codes)
            out[f"{name}_absmax"] = paged_layer[f"{name}_absmax"].at[
                :, pids, offs].set(absmax)
        else:
            out[f"{name}_codes"] = paged_layer[f"{name}_codes"].at[
                pids, offs].set(codes)
            out[f"{name}_absmax"] = paged_layer[f"{name}_absmax"].at[
                pids, offs].set(absmax)
    return out


def commit_prefill_to_paged(cfg, paged_caches, dense_caches, slot,
                            table_row, prompt_len, kv_bits=8):
    """Admit one prefetched request into the paged cache (DESIGN.md §17).

    ``dense_caches`` is a batch-1 ``prefill`` cache built with a 16-bit
    contiguous config (max_len == prompt_len); its attn k/v rows are
    quantized into the pages named by ``table_row`` ((max_pages_per_seq,)
    int32) with the SAME row quantizer the decode append uses, and every
    recurrent layer's state is inserted at batch row ``slot``.  Returns the
    updated paged cache pytree (donate ``paged_caches`` when jitting).
    """
    def insert_slot(pg, dn, lead):
        if lead:
            return pg.at[:, slot].set(dn[:, 0].astype(pg.dtype))
        return pg.at[slot].set(dn[0].astype(pg.dtype))

    def commit_layer(kind, pg_layer, dn_layer, lead):
        if kind == "attn":
            return _commit_attn_pages(cfg, pg_layer, dn_layer, table_row,
                                      prompt_len, kv_bits, lead)
        return jax.tree_util.tree_map(
            lambda pg, dn: insert_slot(pg, dn, lead), pg_layer, dn_layer)

    out = {"rem": [], "scan": None}
    if cfg.scan_layers and cfg.n_superblocks > 0:
        out["scan"] = {
            name: commit_layer(name.split("_", 1)[1], paged_caches["scan"][name],
                               dense_caches["scan"][name], True)
            for name in paged_caches["scan"]}
    else:
        out["scan"] = [
            {name: commit_layer(name.split("_", 1)[1], sb[name],
                                dense_caches["scan"][i][name], False)
             for name in sb}
            for i, sb in enumerate(paged_caches["scan"])]
    for i, layer in enumerate(paged_caches["rem"]):
        kind = cfg.block_pattern[i % len(cfg.block_pattern)]
        out["rem"].append(commit_layer(kind, layer,
                                       dense_caches["rem"][i], False))
    return out


def prefill(cfg, params, tokens, max_len, embeds=None):
    """Run the full prompt, return (logits, caches ready for decode at
    pos=len(prompt))."""
    x = emb.apply_embedding(params["embed"], tokens, cfg)
    if embeds is not None and "frontend" in params:
        fx = emb.apply_frontend(params["frontend"], embeds, cfg)
        x = jnp.concatenate([fx.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    caches = init_cache(cfg, B, max_len)
    x, new_caches, _ = _run_blocks(params, x, cfg, positions, caches=caches,
                                   cache_len=S)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = emb.apply_head(params.get("head", {}), x, params["embed"], cfg)
    return logits, new_caches
