"""Embedding layers: the paper's Stable Embedding Layer (§2.3) and the
standard fairseq-style baseline (App C), plus modality-frontend stubs.

Stable Embedding = Xavier-uniform init + LayerNorm after lookup (before any
position information) + 32-bit optimizer states for this layer (enforced by
the optimizer's override predicate matching 'embed' in the param path).

Baseline embedding = N(0, 1/sqrt(d)) init, outputs scaled by sqrt(d) — the
recipe App C identifies as a source of instability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


def init_embedding(key, cfg):
    v, d = cfg.vocab_size, cfg.d_model
    if cfg.stable_embedding:
        table = layers.xavier_uniform(key, (v, d))
        norm, norm_s = layers.init_norm(d, "layernorm")
        p = {"table": table, "norm": norm}
        s = {"table": ("vocab", "embed"), "norm": norm_s}
    else:
        table = jax.random.normal(key, (v, d)) / np.sqrt(d)
        p = {"table": table}
        s = {"table": ("vocab", "embed")}
    return p, s


def apply_embedding(p, tokens, cfg):
    dt = jnp.dtype(cfg.compute_dtype)
    x = p["table"].astype(dt)[tokens]
    if cfg.stable_embedding:
        x = layers.apply_norm(p["norm"], x, "layernorm")
    else:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    return x.astype(dt)


def init_head(key, cfg):
    """Output projection (untied unless cfg.tie_embeddings)."""
    if cfg.tie_embeddings:
        return {}, {}
    p = {"w": layers.dense_init(key, (cfg.d_model, cfg.vocab_size))}
    s = {"w": ("embed", "vocab")}
    return p, s


def apply_head(p, x, embed_params, cfg):
    """Logits matmul in compute dtype with f32 accumulation: a full-f32
    head makes the backward gather f32 logit grads (24.5 GiB/device on
    stablelm train_4k — EXPERIMENTS.md §Perf C2); bf16xbf16->f32 is the
    standard accounting and halves that traffic."""
    from repro.models.constrain import constrain
    dt = x.dtype
    w = (embed_params["table"].astype(dt).T if cfg.tie_embeddings
         else p["w"].astype(dt))
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    return constrain(logits, "dp", None, "tp")


# ----------------------------------------------------------- frontend stubs
# Per the assignment, [vlm]/[audio] archs specify the transformer BACKBONE;
# the modality frontend is a stub: input_specs() provides precomputed
# patch/frame embeddings of shape (batch, frontend_tokens, d_model) which are
# projected and prepended to the token embeddings.

def init_frontend(key, cfg):
    if cfg.frontend == "none" or cfg.frontend_tokens == 0:
        return {}, {}
    p = {"proj": layers.dense_init(key, (cfg.d_model, cfg.d_model))}
    s = {"proj": ("embed", "embed_out")}
    return p, s


def apply_frontend(p, embeds, cfg):
    """embeds: (B, frontend_tokens, d_model) precomputed stub features."""
    dt = jnp.dtype(cfg.compute_dtype)
    return (embeds.astype(dt) @ p["proj"].astype(dt))
