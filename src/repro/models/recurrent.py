"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> [linear -> causal depthwise conv1d -> RG-LRU] ⊙ gelu(linear) ->
linear.  The RG-LRU recurrence:

    r_t = σ(W_a u_t + b_a)              (recurrence gate)
    i_t = σ(W_x u_t + b_x)              (input gate)
    a_t = exp(-c · softplus(Λ) · r_t)   (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

O(1) decode state: (h, conv tail) — this is why recurrentgemma runs the
long_500k cell.  Deviation note (DESIGN.md §8): gate projections are full
matrices (Griffin uses block-diagonal); parameter count noted in configs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.scan_utils import checkpointed_scan

_C = 8.0


def init_rglru_block(key, cfg):
    d, W = cfg.d_model, cfg.lru_width or cfg.d_model
    cw = cfg.conv_width
    ks = jax.random.split(key, 7)
    # Λ init so that a = exp(-c softplus(Λ)) is uniform in [0.9, 0.999]
    a0 = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    lam_raw = jnp.log(jnp.expm1(-jnp.log(a0) / _C))  # softplus^-1
    p = {
        "w_rec_in": layers.dense_init(ks[1], (d, W)),
        "w_gate_in": layers.dense_init(ks[2], (d, W)),
        "conv_w": layers.dense_init(ks[3], (cw, W), scale=1.0 / np.sqrt(cw)),
        "w_a": layers.dense_init(ks[4], (W, W)),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_x": layers.dense_init(ks[5], (W, W)),
        "b_x": jnp.zeros((W,), jnp.float32),
        "lam": lam_raw,
        "w_out": layers.dense_init(ks[6], (W, d), scale=1.0 / np.sqrt(W)),
    }
    s = {
        "w_rec_in": ("embed", "lru"), "w_gate_in": ("embed", "lru"),
        "conv_w": ("unsharded", "lru"),
        "w_a": ("lru", "lru_out"), "b_a": ("lru",),
        "w_x": ("lru", "lru_out"), "b_x": ("lru",),
        "lam": ("lru",),
        "w_out": ("lru", "embed"),
    }
    return p, s


def _conv1d_causal(u, w, tail=None):
    """Depthwise causal conv. u: (B, S, W), w: (cw, W).
    ``tail``: (B, cw-1, W) previous inputs for decode. Returns (out, new_tail)."""
    cw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)            # (B, S+cw-1, W)
    out = sum(ext[:, i:i + u.shape[1]] * w[i] for i in range(cw))
    new_tail = ext[:, -(cw - 1):] if cw > 1 else tail
    return out, new_tail


def _rglru_scan(p, u, h0):
    """u: (B, S, W) f32; h0: (B, W). Returns (y (B,S,W), h_final)."""
    log_a_coef = -_C * jax.nn.softplus(p["lam"])        # (W,), negative

    r = jax.nn.sigmoid(u @ p["w_a"] + p["b_a"])         # (B,S,W)
    i = jax.nn.sigmoid(u @ p["w_x"] + p["b_x"])
    log_a = log_a_coef * r                               # (B,S,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * u)

    def step(h, inp):
        a_t, x_t = inp
        h = a_t * h + x_t
        return h, h

    (hT, ys) = checkpointed_scan(step, h0,
                                 (a.swapaxes(0, 1), gated.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), hT


def apply_rglru_block(p, x, cfg, *, state=None):
    """x: (B, S, d). state: None (train/prefill from scratch) or dict
    {h: (B, W), conv: (B, cw-1, W)}. Returns (out, new_state)."""
    dt = x.dtype
    B = x.shape[0]
    W = cfg.lru_width or cfg.d_model
    u = (x @ p["w_rec_in"].astype(dt)).astype(jnp.float32)
    gate = x @ p["w_gate_in"].astype(dt)
    tail = state["conv"] if state is not None else None
    u, new_tail = _conv1d_causal(u, p["conv_w"], tail)
    h0 = state["h"] if state is not None else jnp.zeros((B, W), jnp.float32)
    y, hT = _rglru_scan(p, u, h0)
    out = (jax.nn.gelu(gate.astype(jnp.float32)) * y).astype(dt)
    out = out @ p["w_out"].astype(dt)
    return out, {"h": hT, "conv": new_tail}
