"""Pallas resource analyzer: per-tile VMEM bytes and grid alignment
(DESIGN.md §15b).

Every Pallas kernel in the repo tiles the flat block domain with static
BlockSpecs, so its per-grid-step VMEM footprint is a closed-form function
of ``(rows, block_size, bits, algo)`` — no compiler in the loop.  This
module mirrors those BlockSpec layouts byte-for-byte (the layouts are
quoted from kernels/fused_update.py, blockwise_quant.py,
blockwise_dequant.py, newton_schulz.py; a test pins the mirror against
the real specs), adds a scratch model for the in-kernel intermediates,
and checks the pipelined total against the backend VMEM budget.  A
second family of checks pins the grid alignment the partitioned
dispatch relies on: ``ArenaPartition.span_pad`` and every ``BucketPlan``
range must stay multiples of the kernel block grid (``rows``), or the
shard_map spans would split a Pallas tile across owners.

The table built by :func:`budget_table` is what ``benchmarks/run.py
--analyze`` records into BENCH_speed.json (VMEM headroom per kernel
config), and what ``python -m repro.analysis kernels`` gates CI on.

Unlike :mod:`repro.analysis.contracts` this module may import the kernel
modules (it needs ALGO_SPECS and the packing arithmetic); it is imported
explicitly by the CLI/tests, never by production modules.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.contracts import AnalysisError
from repro.core.lowbit.packing import packed_width
from repro.kernels import ops as _ops  # noqa: F401 — anchors the kernels
# package import cycle (ops -> ref -> newton_schulz) at its usual root
# before the leaf modules are bound directly.
from repro.kernels import common as _kc
from repro.kernels import fused_update as _fu
from repro.kernels import newton_schulz as _ns

# Per-backend VMEM budget for one core's kernel working set.  TPU VMEM is
# ~16 MiB/core (accelerator guide); "interpret"/"jnp" paths have no real
# budget but are checked against the TPU number anyway — a tile that can
# never fit on the perf backend is a bug regardless of where CI runs.
VMEM_BUDGET_BYTES = {
    "tpu": 16 << 20,
}
DEFAULT_BACKEND = "tpu"

# Pallas double-buffers the HBM<->VMEM streams: while the compute units
# chew grid step i, the DMA engine prefetches step i+1's inputs and
# drains step i-1's outputs, so streamed blocks cost ~2x their size.
# Grid-invariant blocks (codebooks, the scalars vector) are fetched once.
PIPELINE_FACTOR = 2

_F32 = 4
_I32 = 4
_U8 = 1


@dataclasses.dataclass(frozen=True)
class TileBudget:
    """Per-grid-step VMEM bytes of one kernel configuration."""
    kernel: str
    config: dict
    streamed_in: int      # per-step input blocks (double-buffered)
    streamed_out: int     # per-step output blocks (double-buffered)
    invariant: int        # grid-invariant blocks (fetched once)
    scratch: int          # in-kernel intermediates (registers/VMEM temps)

    @property
    def total(self) -> int:
        return (PIPELINE_FACTOR * (self.streamed_in + self.streamed_out)
                + self.invariant + self.scratch)

    def fits(self, budget: int = VMEM_BUDGET_BYTES[DEFAULT_BACKEND]) -> bool:
        return self.total <= budget

    def headroom(self, budget: int = VMEM_BUDGET_BYTES[DEFAULT_BACKEND]
                 ) -> int:
        return budget - self.total

    def to_dict(self, budget: int = VMEM_BUDGET_BYTES[DEFAULT_BACKEND]
                ) -> dict:
        return {"kernel": self.kernel, **self.config,
                "streamed_in_bytes": self.streamed_in,
                "streamed_out_bytes": self.streamed_out,
                "invariant_bytes": self.invariant,
                "scratch_bytes": self.scratch,
                "total_bytes": self.total,
                "budget_bytes": budget,
                "headroom_bytes": self.headroom(budget),
                "fits": self.fits(budget)}


def _onehot_scratch(tile_elems: int) -> int:
    """The codebook binary-search / requant one-hot intermediates:
    (tile_elems, CHUNK) compares materialized per codebook chunk
    (kernels/common.py lookup/requant)."""
    return tile_elems * _kc.CHUNK * _F32


def fused_update_tile(algo: str, *, rows: int = _kc.DEFAULT_ROWS,
                      block_size: int = 2048, bits_m: int = 8,
                      bits_r: int = 8, stochastic: bool = False
                      ) -> TileBudget:
    """VMEM bytes of one ``fused_update_pallas`` grid step — the exact
    in_specs/out_specs assembly of kernels/fused_update.py:484-546."""
    spec = _fu.ALGO_SPECS[algo]
    if spec.matrix:
        raise AnalysisError(
            f"{algo} is matrix-class; budget its NS chain with "
            f"newton_schulz_tiles()")
    two = spec.n_states == 2
    bsz = block_size
    w1 = packed_width(bsz, bits_m)
    w2 = packed_width(bsz, bits_r) if two else 0

    row = rows * bsz * _F32          # (rows, bsz) f32
    code1 = rows * w1 * _U8          # (rows, w1) u8
    code2 = rows * w2 * _U8
    one = rows * 1 * _F32            # (rows, 1) f32 / i32
    const = _kc.CODEBOOK_SIZE * _F32  # (1, 256) f32
    scal = _fu.N_SCALARS * _F32      # (1, 8) f32

    streamed_in = 2 * row + code1 + one            # p, g, codes_m, absmax_m
    if two:
        streamed_in += code2 + one                 # codes_r, absmax_r
    if stochastic:
        streamed_in += 2 * one                     # block_seeds, offsets
    if spec.needs_norms:
        streamed_in += one                         # tensor_scale slice
    invariant = scal + 2 * const                   # scalars, qmap, bounds
    if two:
        invariant += 2 * const

    streamed_out = row + code1 + one               # p', codes_m', absmax_m'
    if two:
        streamed_out += code2 + one

    # Scratch: per-state unpack (i32) + decode (f32) for sub-byte slots,
    # the update intermediates (~2 row-sized f32 temps), the requant
    # one-hot per state, and the stochastic uniforms.
    tile_elems = rows * bsz
    n_states = 2 if two else 1
    scratch = 2 * row                              # update temps
    scratch += n_states * tile_elems * (_I32 + _F32)   # unpack + decode
    scratch += n_states * _onehot_scratch(tile_elems)  # requant search
    if stochastic:
        scratch += n_states * tile_elems * _F32        # counter uniforms

    return TileBudget(
        kernel="fused_update", streamed_in=streamed_in,
        streamed_out=streamed_out, invariant=invariant, scratch=scratch,
        config={"algo": algo, "rows": rows, "block_size": bsz,
                "bits_m": bits_m, "bits_r": bits_r if two else None,
                "stochastic": stochastic})


def quantize_tile(*, rows: int = _kc.DEFAULT_ROWS, block_size: int = 2048
                  ) -> TileBudget:
    """One ``quantize_blockwise`` grid step (blockwise_quant.py): in
    (rows, bsz) f32 + (1, 256) codebook -> (rows, w) u8 + (rows, 1) f32."""
    bsz = block_size
    row = rows * bsz * _F32
    return TileBudget(
        kernel="blockwise_quant",
        streamed_in=row,
        streamed_out=rows * bsz * _U8 + rows * _F32,
        invariant=2 * _kc.CODEBOOK_SIZE * _F32,     # codebook + bounds
        scratch=_onehot_scratch(rows * bsz),
        config={"rows": rows, "block_size": bsz})


def dequantize_tile(*, rows: int = _kc.DEFAULT_ROWS, block_size: int = 2048
                    ) -> TileBudget:
    """One ``dequantize_blockwise`` grid step (blockwise_dequant.py): in
    (rows, bsz) u8 + (rows, 1) f32 + (1, 256) codebook -> (rows, bsz)."""
    bsz = block_size
    return TileBudget(
        kernel="blockwise_dequant",
        streamed_in=rows * bsz * _U8 + rows * _F32,
        streamed_out=rows * bsz * _F32,
        invariant=_kc.CODEBOOK_SIZE * _F32,
        scratch=_onehot_scratch(rows * bsz),
        config={"rows": rows, "block_size": bsz})


def newton_schulz_tiles(m: int, *, tile_n: int = _ns.TILE_N) -> list:
    """The two NS pallas_calls per iteration (newton_schulz.py): the gram
    kernel streams one (m, tile_n) operand tile per grid step and
    accumulates into a grid-invariant (m, m) output; the apply kernel
    streams (m, tile_n) in and out against an invariant (m, m) factor.
    ``m`` is the padded small dimension (sublane multiple)."""
    mp = -(-m // _ns._SUBLANE) * _ns._SUBLANE
    gram = TileBudget(
        kernel="newton_schulz_gram",
        streamed_in=mp * tile_n * _F32,
        streamed_out=0,
        invariant=mp * mp * _F32,             # accumulator lives across grid
        scratch=mp * mp * _F32,               # the per-step partial product
        config={"m": mp, "tile_n": tile_n})
    apply_ = TileBudget(
        kernel="newton_schulz_apply",
        streamed_in=mp * tile_n * _F32,
        streamed_out=mp * tile_n * _F32,
        invariant=mp * mp * _F32,
        scratch=mp * tile_n * _F32,
        config={"m": mp, "tile_n": tile_n})
    return [gram, apply_]


def ns_max_m(*, tile_n: int = _ns.TILE_N,
             budget: int = VMEM_BUDGET_BYTES[DEFAULT_BACKEND]) -> int:
    """Largest (sublane-aligned) small dimension the NS kernels support
    within ``budget`` — the envelope of newton_schulz.py's "the small dim
    fits VMEM" assumption.  Matrix leaves beyond this need a tiled (m, m)
    accumulator the kernel does not implement; the audit pins the envelope
    so a config regression (or a budget model change) is caught statically."""
    m = _ns._SUBLANE
    while all(t.fits(budget) for t in
              newton_schulz_tiles(m + _ns._SUBLANE, tile_n=tile_n)):
        m += _ns._SUBLANE
    return m


# ------------------------------------------------------- grid alignment
def check_partition_plan(part, plan, grid: int) -> tuple:
    """Validate an (ArenaPartition, BucketPlan) pair against the block
    ``grid`` the dispatch was built on (``cfg.shard_multiple``): span
    starts and span_pad stay grid-aligned (a span boundary inside a
    storage-shard block would split whole-block ownership), spans cover
    exactly [0, total), and bucket ranges tile [0, span_pad) exactly with
    grid-aligned boundaries (the overlap schedule slices kernel inputs at
    these rows).  Takes the *built objects* so a regression in
    make_partition/make_buckets — or a hand-constructed bad plan — is
    caught, not just reproduced."""
    problems = []
    if part.span_pad % grid != 0:
        problems.append(f"span_pad {part.span_pad} not a multiple of "
                        f"grid={grid}")
    for start, length in part.spans:
        if start % grid != 0:
            problems.append(f"span start {start} misaligned to grid={grid}")
    lengths = sum(length for _, length in part.spans)
    if lengths != part.total:
        problems.append(f"spans cover {lengths} rows, total is {part.total}")
    if plan is not None:
        if plan.span_pad != part.span_pad:
            problems.append(f"plan span_pad {plan.span_pad} != partition "
                            f"span_pad {part.span_pad}")
        prev = 0
        for k0, k1 in plan.ranges:
            if k0 != prev:
                problems.append(f"bucket ranges not contiguous at {k0} "
                                f"(expected {prev})")
            if k1 <= k0:
                problems.append(f"empty/negative bucket range ({k0}, {k1})")
            if k0 % grid != 0:
                problems.append(f"bucket start {k0} misaligned to "
                                f"grid={grid}")
            if k1 % grid != 0 and k1 != part.span_pad:
                problems.append(f"bucket end {k1} misaligned to grid={grid}"
                                f" (span_pad={part.span_pad})")
            prev = k1
        if plan.ranges and prev != part.span_pad:
            problems.append(f"bucket ranges end at {prev}, span_pad is "
                            f"{part.span_pad}")
    ok = not problems
    return ok, ("grid-aligned" if ok else "; ".join(problems))


def check_grid_alignment(total: int, n_shards: int, n_buckets: int,
                         grid: int = _kc.DEFAULT_ROWS) -> tuple:
    """Build the partition/bucket plan exactly as the partitioned dispatch
    does (blockopt: make_partition/make_buckets on cfg.shard_multiple) and
    validate it with :func:`check_partition_plan`."""
    from repro.core.optim import base as _base
    part = _base.make_partition(total, n_shards, grid=grid)
    plan = _base.make_buckets(part, n_buckets, grid=grid)
    ok, detail = check_partition_plan(part, plan, grid)
    return ok, (f"partition(total={total}, shards={n_shards}, "
                f"buckets={n_buckets}, grid={grid}): {detail}")


# ------------------------------------------------------------- the table
def budget_table(*, rows: int = _kc.DEFAULT_ROWS, block_size: int = 2048,
                 budget: int = VMEM_BUDGET_BYTES[DEFAULT_BACKEND]) -> list:
    """VMEM budget rows for every registered element-wise fused-update
    config (each non-matrix algo x 8-bit and 4-bit momentum x stochastic
    on/off), the quant/dequant kernels, and representative NS sizes."""
    tiles = []
    for algo, spec in _fu.ALGO_SPECS.items():
        if spec.matrix:
            continue
        for bits_m in (8, 4):
            for stoch in (False, True):
                tiles.append(fused_update_tile(
                    algo, rows=rows, block_size=block_size, bits_m=bits_m,
                    stochastic=stoch))
    tiles.append(quantize_tile(rows=rows, block_size=block_size))
    tiles.append(dequantize_tile(rows=rows, block_size=block_size))
    # NS rows: the repo's representative matrix-leaf sizes plus the
    # envelope boundary (documentation rows; m=4096 does NOT fit — muon
    # leaves that large need a tiled accumulator, see ns_max_m()).
    for m in (256, 1024, 4096):
        tiles.extend(newton_schulz_tiles(m))
    return [t.to_dict(budget) for t in tiles]


def audit(*, rows: int = _kc.DEFAULT_ROWS, block_size: int = 2048,
          budget: int = VMEM_BUDGET_BYTES[DEFAULT_BACKEND]) -> list:
    """Run the full kernel-budget audit: every budget_table row must fit,
    and the partitioned dispatch's representative arena shapes must stay
    grid-aligned.  Returns (name, ok, detail) tuples."""
    results = []
    max_m = ns_max_m(budget=budget)
    for row in budget_table(rows=rows, block_size=block_size, budget=budget):
        cfg = {k: v for k, v in row.items()
               if k not in ("kernel", "fits") and not k.endswith("_bytes")}
        name = f"vmem:{row['kernel']}:{cfg}"
        detail = (f"{row['total_bytes']} B of {row['budget_bytes']} B "
                  f"({row['headroom_bytes']} B headroom)")
        if row["kernel"].startswith("newton_schulz") and row["m"] > max_m:
            # documentation row beyond the kernel's supported envelope
            results.append((name, True, detail + f" [beyond NS envelope "
                            f"m<={max_m}; informational]"))
            continue
        results.append((name, row["fits"], detail))
    # The NS envelope itself must cover the repo's matrix-leaf sizes: the
    # reduced configs orthogonalize up to d_model=1024 leaves.
    results.append((f"ns_envelope:max_m={max_m}", max_m >= 1024,
                    f"largest VMEM-resident NS small-dim is {max_m}, "
                    f"need >= 1024"))
    # Representative arena shapes: uneven totals, shard counts from the
    # config matrix, bucket counts from the overlap schedule.  The grid is
    # what production passes (cfg.shard_multiple == mesh size), so this
    # re-validates the make_partition/make_buckets contract the overlap
    # slicing depends on — coverage, contiguity, grid alignment.
    for total, shards, buckets, grid in ((1000, 4, 1, 4), (12345, 4, 2, 4),
                                         (8192, 8, 4, 8), (7, 4, 2, 4),
                                         (1000, 4, 2, rows)):
        ok, detail = check_grid_alignment(total, shards, buckets, grid=grid)
        results.append((f"grid:total={total},shards={shards},"
                        f"buckets={buckets},grid={grid}", ok, detail))
    return results
