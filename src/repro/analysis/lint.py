"""Repo lint gate: AST rules encoding repo conventions (DESIGN.md §15c).

Four rules, each encoding a convention the repo learned the hard way:

  * ``bare-assert`` — ``assert`` statements in library code.  Asserts
    vanish under ``python -O`` (serve/engine.py already documents this),
    so user-reachable validation must raise typed exceptions
    (:mod:`repro.errors`).  Internal kernel-invariant asserts are being
    burned down via the baseline.
  * ``host-sync-in-jit`` — ``.item()`` / ``jax.device_get`` inside a
    jit-decorated function: a silent device->host sync that serializes
    the step (the §14 telemetry work exists precisely to avoid these).
  * ``env-read-at-trace`` — ``os.environ`` / ``os.getenv`` inside a
    function body: config must be read at import or passed explicitly;
    a trace-time env read bakes the value into the compiled step
    invisibly (the sanctioned pattern is a module-level flag like
    ``tracing._PHASE_TRACING``).
  * ``duplicate-import`` — the same module imported twice in one file.

Violations are compared against a committed baseline
(``lint_baseline.json``: per (file, rule) counts).  New violations fail;
existing ones burn down — shrinking a count below baseline auto-shrinks
the baseline on the next ``--write-baseline``.  Stdlib-only on purpose.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os

BASELINE_FILE = os.path.join(os.path.dirname(__file__),
                             "lint_baseline.json")
RULES = ("bare-assert", "host-sync-in-jit", "env-read-at-trace",
         "duplicate-import")


@dataclasses.dataclass(frozen=True)
class Violation:
    file: str
    line: int
    rule: str
    msg: str

    def __str__(self):
        return f"{self.file}:{self.line}: [{self.rule}] {self.msg}"


def _decorator_names(fn: ast.AST) -> list:
    """Dotted-name text of each decorator (partial(jax.jit, ...) included)."""
    out = []
    for d in getattr(fn, "decorator_list", []):
        for node in ast.walk(d):
            if isinstance(node, ast.Attribute):
                out.append(node.attr)
            elif isinstance(node, ast.Name):
                out.append(node.id)
    return out


def _is_jitted(fn: ast.AST) -> bool:
    return any(n in ("jit", "pjit") for n in _decorator_names(fn))


def _dotted(node: ast.AST) -> str:
    """'jax.device_get' for an Attribute/Name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _check_file(path: str, rel: str) -> list:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    tree = ast.parse(src, filename=path)
    out = []

    # bare-assert: every assert statement in library code
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            out.append(Violation(rel, node.lineno, "bare-assert",
                                 "assert vanishes under -O; raise a typed "
                                 "exception (repro.errors) instead"))

    # host-sync-in-jit: .item() / jax.device_get inside jit-decorated fns
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _is_jitted(fn):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            dn = _dotted(node.func)
            # .item() on any expression (x.item(), x.sum().item(), ...):
            # _dotted can't name a chain rooted in a call, so match the
            # attribute itself.
            is_item = (isinstance(node.func, ast.Attribute)
                       and node.func.attr == "item")
            if is_item or dn in ("jax.device_get", "device_get"):
                out.append(Violation(
                    rel, node.lineno, "host-sync-in-jit",
                    f"{dn or '.item'}() inside jit-decorated {fn.name}() "
                    f"forces a device->host sync at trace/run time"))

    # env-read-at-trace: os.environ/os.getenv inside any function body
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(fn):
            dn = ""
            if isinstance(node, ast.Call):
                dn = _dotted(node.func)
            elif isinstance(node, ast.Attribute):
                dn = _dotted(node)
            if dn in ("os.getenv", "os.environ"):
                out.append(Violation(
                    rel, node.lineno, "env-read-at-trace",
                    f"{dn} read inside {fn.name}(): read config at import "
                    f"(module-level flag) or pass it explicitly"))

    # duplicate-import: same module bound twice at module level
    seen: dict = {}
    for node in tree.body:
        names = []
        if isinstance(node, ast.Import):
            names = [(a.name, a.asname or a.name) for a in node.names]
        elif isinstance(node, ast.ImportFrom):
            mod = "." * node.level + (node.module or "")
            names = [(f"{mod}:{a.name}", a.asname or a.name)
                     for a in node.names]
        for key, _ in names:
            if key in seen:
                out.append(Violation(
                    rel, node.lineno, "duplicate-import",
                    f"{key} already imported at line {seen[key]}"))
            else:
                seen[key] = node.lineno
    return out


def lint_paths(root: str) -> list:
    """Lint every .py file under ``root`` (the src/repro tree)."""
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            out.extend(_check_file(path, rel))
    return sorted(out, key=lambda v: (v.file, v.line, v.rule))


def counts(violations: list) -> dict:
    """Per ``"file::rule"`` violation counts (the baseline unit)."""
    out: dict = {}
    for v in violations:
        key = f"{v.file}::{v.rule}"
        out[key] = out.get(key, 0) + 1
    return out


def load_baseline(path: str = BASELINE_FILE) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def write_baseline(violations: list, path: str = BASELINE_FILE) -> dict:
    c = counts(violations)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(c, f, indent=2, sort_keys=True)
        f.write("\n")
    return c


def compare(violations: list, baseline: dict) -> tuple:
    """(new, fixed): violations beyond the per-(file, rule) baseline
    count, and baseline entries whose count shrank (candidates for a
    ``--write-baseline`` refresh)."""
    cur = counts(violations)
    new = {k: (n, baseline.get(k, 0)) for k, n in cur.items()
           if n > baseline.get(k, 0)}
    fixed = {k: (cur.get(k, 0), n) for k, n in baseline.items()
             if cur.get(k, 0) < n}
    return new, fixed


def run(root: str, *, baseline_path: str = BASELINE_FILE,
        update_baseline: bool = False) -> tuple:
    """Full lint gate: returns (ok, report_lines)."""
    violations = lint_paths(root)
    if update_baseline:
        c = write_baseline(violations, baseline_path)
        return True, [f"baseline rewritten: {sum(c.values())} violation(s) "
                      f"across {len(c)} (file, rule) pair(s)"]
    baseline = load_baseline(baseline_path)
    new, fixed = compare(violations, baseline)
    lines = []
    if new:
        by_key = {}
        for v in violations:
            by_key.setdefault(f"{v.file}::{v.rule}", []).append(v)
        for k, (n, base) in sorted(new.items()):
            lines.append(f"NEW {k}: {n} violation(s), baseline {base}")
            for v in by_key[k]:
                lines.append(f"  {v}")
    if fixed:
        for k, (n, base) in sorted(fixed.items()):
            lines.append(f"improved {k}: {n} (baseline {base}) — run "
                         f"--write-baseline to ratchet down")
    lines.append(f"{len(violations)} violation(s) total, baseline "
                 f"{sum(baseline.values())}, {len(new)} regressing "
                 f"(file, rule) pair(s)")
    return not new, lines
