"""Config-matrix lowering builder + contract evaluator (DESIGN.md §15a).

Builds every lowering the registered contracts run on — jitted train
steps across the {pooled, partitioned, partitioned+ZeRO-2} x algo x
state-bits matrix, bare fused-update lowerings per (algo, bits), and the
knob pairs (telemetry_every 0 vs N, overlap_buckets 1 vs K, partition
on/off) — then evaluates :mod:`repro.analysis.contracts` over them.
Nothing executes: every artifact is ``jax.jit(...).lower(...)`` text,
so the whole audit runs on the CPU host in seconds.

Matrix notes:

  * ``percentile_clipping=95`` is set in partitioned cells so the §12
    replication pins appear for every algo (percentile_clip pins each
    grad leaf only when the config is partition-active).
  * The multi-device cells need >= 4 devices;
    ``python -m repro.analysis`` forces 4 host devices via XLA_FLAGS
    before importing jax.  Under fewer devices those cells are skipped
    with a notice (and the audit fails unless ``allow_skips``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

from repro.analysis.contracts import (AnalysisError, ContractResult,
                                      Lowering, contracts_for, evaluate)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One config-matrix point (static description; contracts read it)."""
    name: str
    algo: str                  # optimizer name for make_optimizer
    state_bits: tuple          # (bits_m, bits_r)
    partition: int = 1         # partition_shards (1 = pooled, unsharded)
    shard_grads: bool = False  # ZeRO-2 grad accumulation
    overlap_buckets: int = 1


# The audited matrix: one pooled cell and two partitioned cells per
# (algo, bits) point.  adamw exercises the 2-state element-wise family,
# muon the matrix-class path; (4, 8) rides the sub-byte packing.
def default_cells() -> list:
    cells = []
    for algo in ("adamw8", "muon8"):
        for bits in ((8, 8), (4, 8)):
            tag = f"{algo}-b{bits[0]}{bits[1]}"
            cells.append(Cell(f"{tag}-pooled", algo, bits))
            cells.append(Cell(f"{tag}-part4", algo, bits, partition=4))
            cells.append(Cell(f"{tag}-part4-zero2", algo, bits,
                              partition=4, shard_grads=True,
                              overlap_buckets=2))
    return cells


@functools.lru_cache(maxsize=1)
def _harness():
    """The tiny model/pipeline the matrix lowers (built once)."""
    import jax.numpy as jnp
    from repro.configs import base
    from repro.data.pipeline import DataConfig, SyntheticLMPipeline

    cfg = base.reduced(base.get_config("paper-lm-209m"), d_model=64,
                       n_layers=2, vocab_size=128)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=128, seq_len=32,
                                          global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    return cfg, batch


def _mesh(n: int):
    import jax
    if jax.device_count() < n:
        return None
    return jax.make_mesh((n,), ("data",))


def _make_opt(cell: Cell, **overrides):
    from repro.core.optim import make_optimizer
    kw = dict(lr=5e-3, min_8bit_size=1024, state_bits=cell.state_bits)
    if cell.partition > 1:
        mesh = _mesh(cell.partition)
        if mesh is None:
            return None
        kw.update(mesh=mesh, percentile_clipping=95,
                  shard_grads=cell.shard_grads,
                  overlap_buckets=cell.overlap_buckets)
    kw.update(overrides)
    return make_optimizer(cell.algo, **kw)


def lower_step(cell: Cell, **overrides) -> Optional[Lowering]:
    """Lowered jitted train step for ``cell`` (None = needs more devices)."""
    import jax
    from repro.train import loop as L
    cfg, batch = _harness()
    opt = _make_opt(cell, **overrides)
    if opt is None:
        return None
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    low = L.jit_train_step(cfg, opt).lower(state, batch)
    tag = "".join(f"-{k}{v}" for k, v in sorted(overrides.items()))
    return Lowering(name=f"step:{cell.name}{tag}", text=low.as_text())


def lower_update(algo: str, bits_m: int = 8) -> Lowering:
    """Bare fused-update lowering per (algo, bits) — the 'update' scope
    subject.  Uses impl='jnp' (the XLA oracle): the dtype/accumulation
    contracts audit the math's lowering, which the CPU host can build."""
    import jax
    import jax.numpy as jnp
    from repro.core import qmap as qmap_lib
    from repro.core.lowbit import PackedCodes, packed_width
    from repro.kernels import ops

    nb, bsz = 8, 256
    qm = jnp.asarray(qmap_lib.dynamic_map(signed=True, bits=bits_m))
    qr = jnp.asarray(qmap_lib.dynamic_map(signed=False))
    w = packed_width(bsz, bits_m)

    if algo == "muon":
        shape = (32, 64)
        p = jnp.zeros(shape, jnp.float32)
        g = jnp.zeros(shape, jnp.bfloat16)
    else:
        p = jnp.zeros((nb, bsz), jnp.float32)
        g = jnp.zeros((nb, bsz), jnp.float32)
    cm = jnp.zeros((nb, w), jnp.uint8)
    if bits_m != 8:
        cm = PackedCodes(cm, bits_m, bsz)
    am = jnp.zeros((nb,), jnp.float32)
    two = ops._fu.ALGO_SPECS[algo].n_states == 2
    cr = jnp.zeros((nb, bsz), jnp.uint8) if two else None
    ar = jnp.zeros((nb,), jnp.float32) if two else None

    def update(p, g, cm, am, cr, ar):
        return ops.fused_update(algo, p, g, cm, am, cr, ar, qm,
                                qr if two else None, lr=1e-3, impl="jnp")

    low = jax.jit(update).lower(p, g, cm, am, cr, ar)
    return Lowering(name=f"update:{algo}-b{bits_m}", text=low.as_text())


def lower_serve(kv_bits: int = 8) -> Lowering:
    """Lowered jitted paged decode step (the 'serve' scope subject): tiny
    dense config, donated cache pytree, page table + positions as inputs
    (DESIGN.md §17)."""
    import jax
    import jax.numpy as jnp
    from repro.models import layers as mlayers
    from repro.models import model as mm

    cfg, _ = _harness()
    params, _ = mm.init_model(cfg, jax.random.PRNGKey(0))
    n_slots, n_pages, page = 4, 16, 8
    caches = mm.init_paged_cache(cfg, n_slots, n_pages, page, kv_bits)
    paged = mlayers.PagedContext(
        jnp.zeros((n_slots, 4), jnp.int32),
        jnp.zeros((n_slots,), jnp.int32), impl="jnp")
    tok = jnp.zeros((n_slots, 1), jnp.int32)

    def step(params, token, caches, paged):
        return mm.paged_decode_step(cfg, params, token, caches, paged)

    low = jax.jit(step, donate_argnums=(2,)).lower(params, tok, caches,
                                                   paged)
    return Lowering(name=f"serve:decode-b{kv_bits}", text=low.as_text())


def _pair_cells(cells: list) -> dict:
    """Pick the matrix cells the knob-pair contracts run on."""
    by_name = {c.name: c for c in cells}
    return {
        # telemetry pair: pooled adamw (byte-equality needs an otherwise
        # identical config)
        "pair:telemetry": by_name.get("adamw8-b88-pooled"),
        # overlap pair: the ZeRO-2 cell (overlap_buckets only matters there)
        "pair:overlap": by_name.get("adamw8-b88-part4-zero2"),
        # partition pair: partitioned vs pooled adamw
        "pair:partition": by_name.get("adamw8-b88-part4"),
        # sentinel pair (§16): pooled adamw — off / explicit-off /
        # on-but-idle lowerings
        "pair:sentinel": by_name.get("adamw8-b88-pooled"),
    }


def run_contracts(cells: Optional[list] = None, *,
                  allow_skips: bool = False, log=print) -> list:
    """Evaluate every registered contract over the matrix.  Returns the
    ContractResult list; raises AnalysisError if multi-device cells had
    to be skipped and ``allow_skips`` is False."""
    # Importing the protected modules registers their contracts.
    import repro.kernels.ops  # noqa: F401
    import repro.serve.kvcache  # noqa: F401
    import repro.sharding.rules  # noqa: F401
    import repro.train.loop  # noqa: F401

    cells = default_cells() if cells is None else cells
    results: list = []
    skipped: list = []

    step_contracts = contracts_for("step")
    for cell in cells:
        low = lower_step(cell)
        if low is None:
            skipped.append(cell.name)
            continue
        for spec in step_contracts:
            r = evaluate(spec, low, cell)
            if r is not None:
                results.append(r)
                log(str(r))

    update_contracts = contracts_for("update")
    for algo in ("adamw", "muon"):
        for bits_m in (8, 4):
            low = lower_update(algo, bits_m)
            cell = Cell(low.name, algo, (bits_m, 8))
            for spec in update_contracts:
                r = evaluate(spec, low, cell)
                if r is not None:
                    results.append(r)
                    log(str(r))

    serve_contracts = contracts_for("serve")
    for kv_bits in (8, 4):
        low = lower_serve(kv_bits)
        cell = Cell(low.name, "serve", (kv_bits,))
        for spec in serve_contracts:
            r = evaluate(spec, low, cell)
            if r is not None:
                results.append(r)
                log(str(r))

    for scope, cell in _pair_cells(cells).items():
        if cell is None:
            continue
        specs = contracts_for(scope)
        if not specs:
            continue
        if scope == "pair:telemetry":
            pair = {n: lower_step(cell, telemetry_every=n) for n in (0, 2)}
        elif scope == "pair:overlap":
            pair = {n: lower_step(cell, overlap_buckets=n) for n in (1, 2)}
        elif scope == "pair:sentinel":
            # off (field default) vs explicit off must be byte-identical;
            # "on" only feeds the sentinel_invariant alias comparison.
            pair = {"off": lower_step(cell),
                    "off_explicit": lower_step(cell, sentinel=False),
                    "on": lower_step(cell, sentinel=True)}
        else:  # pair:partition — the pooled twin drops mesh/partitioning
            on = lower_step(cell)
            off = lower_step(dataclasses.replace(
                cell, name=cell.name + "-off", partition=1,
                shard_grads=False, overlap_buckets=1))
            pair = {"on": on, "off": off}
        if any(v is None for v in pair.values()):
            skipped.append(f"{scope}:{cell.name}")
            continue
        for spec in specs:
            r = evaluate(spec, pair, cell)
            if r is not None:
                results.append(r)
                log(str(r))

    if skipped and not allow_skips:
        raise AnalysisError(
            f"matrix cells skipped (need >= 4 devices; run via `python -m "
            f"repro.analysis`, which forces host devices): {skipped}")
    if skipped:
        log(f"skipped cells: {skipped}")
    return results


def failures(results: list) -> list:
    return [r for r in results if not r.ok]
