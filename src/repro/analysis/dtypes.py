"""The one HLO/StableHLO dtype-size table (DESIGN.md §15).

Three consumers previously carried private copies that had already drifted
(``roofline/analysis.py`` was missing the 4-bit and most f8 entries its
sibling ``roofline/hlo_cost.py`` had): the HLO cost model, the roofline
collective parser, and now the Pallas VMEM analyzer.  All three import
this table; tests/test_analysis.py pins that they stay the same object.

Keys are the dtype names as they appear in HLO/StableHLO shape strings
(``f32[8,128]`` / ``tensor<8x128xf32>``).  Sub-byte types (s4/u4) round up
to one byte — that is how XLA stores them in HBM buffers today, and the
conservative choice for a *budget* model.  Packed sub-byte optimizer
states (DESIGN.md §9) do NOT go through this table: they are uint8 words
whose per-parameter cost is ``bits/8`` by construction
(``core.lowbit.packing.packed_width``).
"""
from __future__ import annotations

DTYPE_BYTES: dict[str, int] = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2,
    "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1,
}


def dtype_bytes(name: str) -> int:
    """Bytes per element of HLO dtype ``name``; raises KeyError with the
    known names listed (a new XLA dtype should be added here, once)."""
    try:
        return DTYPE_BYTES[name]
    except KeyError:
        raise KeyError(f"unknown HLO dtype {name!r}; known: "
                       f"{sorted(DTYPE_BYTES)}") from None
