"""Test-only mutation toggles for the contract auditors (DESIGN.md §15).

An auditor that cannot fail is decoration, so tests/test_analysis.py
seeds one deliberate violation per contract class and asserts the
matching auditor fires.  The violations live *in the production code
paths* behind these toggles — e.g. ``kernels/ops.py`` promotes the
fused-update gradient to f64 under ``promote_f64``, and
``sharding/rules.py`` drops the §12 replication pin under
``drop_replication_pin`` — because a violation grafted into test-only
code would not prove the auditors watch the real dispatch.

Every toggle is read at *trace time* only (the sanctioned trace-time
flag pattern, like ``tracing._PHASE_TRACING``): flipping one never
changes an already-compiled executable, and with every toggle off (the
only production state) the guarded branches are dead code.

    with mutations.seeded("promote_f64"):
        lowered = jax.jit(step).lower(...)   # now violates no_dtype(f64)
"""
from __future__ import annotations

import contextlib

KNOWN = (
    "promote_f64",          # ops.fused_update: g -> f64 (needs x64 mode)
    "drop_replication_pin",  # rules.replicate_for_scales: identity
)

_ACTIVE: set = set()


def active(name: str) -> bool:
    """Whether mutation ``name`` is currently seeded (trace-time read)."""
    return name in _ACTIVE


@contextlib.contextmanager
def seeded(name: str):
    """Seed mutation ``name`` for the duration of the block (tests only)."""
    if name not in KNOWN:
        raise ValueError(f"unknown mutation {name!r}; known: {KNOWN}")
    _ACTIVE.add(name)
    try:
        yield
    finally:
        _ACTIVE.discard(name)
