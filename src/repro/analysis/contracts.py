"""Compile-contract checker: declarative invariants over lowered StableHLO.

The repo's correctness story rests on properties of the *compiled* step,
not of any particular run: the TrainState is donated in place (§13c), no
update math silently promotes dtype (§6), LAMB/LARS/NS accumulation stays
f32, the §12 replication pins survive partitioning, and host-side knobs
(``telemetry_every``) never change the lowering.  PR 7 checked two of
these with one-off tests; this module generalizes them into contracts —
small named checks over ``jax.jit(...).lower(...).as_text()`` — that are
**registered next to the code they protect** (train/loop.py,
kernels/ops.py, sharding/rules.py call :func:`register` at import) and
evaluated over a config matrix by ``python -m repro.analysis`` without
executing a single training step.

This module is deliberately import-light (stdlib only): production
modules import it at module level to register their contracts, so it must
never pull in jax or the subsystems it audits.  The heavy lowering
construction lives in :mod:`repro.analysis.runner`.

Scopes bind a contract to the lowering(s) it runs on:

  * ``"step"``    — every lowered train step in the config matrix.
  * ``"update"``  — the bare fused-update lowering per (algo, bits).
  * ``"pair:telemetry"`` / ``"pair:overlap"`` / ``"pair:partition"`` —
    two lowerings differing only in one knob (``telemetry_every`` 0 vs N,
    ``overlap_buckets`` 1 vs K, ``partition_shards`` 1 vs N).

Checks take ``(lowering, cell)`` — or ``(dict_of_lowerings, cell)`` for
pair scopes — and return a ``(ok, detail)`` tuple or ``None`` for
"not applicable to this cell".
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Optional


class AnalysisError(Exception):
    """A static-analysis contract or budget violation."""


@dataclasses.dataclass(frozen=True)
class Lowering:
    """One ``.lower()``-ed computation: its name and StableHLO text."""
    name: str
    text: str


@dataclasses.dataclass(frozen=True)
class ContractResult:
    contract: str
    target: str
    ok: bool
    detail: str = ""

    def __str__(self):
        mark = "PASS" if self.ok else "FAIL"
        d = f" — {self.detail}" if self.detail else ""
        return f"[{mark}] {self.contract} @ {self.target}{d}"


# ------------------------------------------------------------ text checks
# StableHLO shape strings: tensor<8x128xf32>, tensor<f32>, tensor<4xui8>.
_ELEM_RE = re.compile(r"tensor<(?:[0-9]+x)*([a-z][a-z0-9]*)>")
_RESULT_TYPE_RE = re.compile(r"->\s*(\(?tensor<[^)]*?>\)?)\s*$")


def donation_aliases(text: str) -> int:
    """Number of donated-input/output buffer aliasings the lowering
    established — the ``tf.aliasing_output`` markers in the StableHLO
    (the §13c audit, generalized from train/loop.py)."""
    return text.count("tf.aliasing_output")


def donation_markers(text: str) -> dict:
    """Both donation marker kinds in a lowering: ``aliased`` inputs whose
    output aliasing was already established at lowering time
    (``tf.aliasing_output``), and ``donors`` deferred to the compiler
    (``jax.buffer_donor`` — what XLA emits when input shardings are
    unresolved at lowering, e.g. the partitioned/shard_map step)."""
    return {"aliased": donation_aliases(text),
            "donors": text.count("jax.buffer_donor")}


def check_donates(text: str, min_aliases: int = 1) -> tuple:
    """``donates(TrainState)``: the step must mark at least
    ``min_aliases`` donated inputs (established aliasings or deferred
    buffer donors) — a donated state that establishes zero of either
    round-trips every arena through HBM twice."""
    m = donation_markers(text)
    n = m["aliased"] + m["donors"]
    ok = n >= min_aliases
    return ok, (f"{m['aliased']} aliasing(s) + {m['donors']} donor "
                f"mark(s), need >= {min_aliases}")


def find_dtype(text: str, dtype: str) -> list:
    """Lines mentioning HLO dtype ``dtype`` (as a shape element type)."""
    pat = re.compile(rf"(?:<|x){re.escape(dtype)}(?:>|\b)")
    return [ln.strip() for ln in text.splitlines() if pat.search(ln)]


def check_no_dtype(text: str, dtype: str = "f64") -> tuple:
    """``no_dtype(f64)``: the lowering must not contain the banned dtype
    anywhere — one stray promotion breaks the §6 master-dtype policy (and
    on TPU silently deoptimizes instead of failing)."""
    hits = find_dtype(text, dtype)
    ok = not hits
    detail = f"no {dtype} anywhere" if ok else \
        f"{len(hits)} {dtype} site(s), e.g.: {hits[0][:120]}"
    return ok, detail


def accumulation_sites(text: str) -> list:
    """(op, elem_dtype, line) for every accumulation-class op in the text:
    ``stablehlo.dot_general``/``stablehlo.dot`` and additive
    ``stablehlo.reduce`` forms with a result type on the same line."""
    out = []
    for ln in text.splitlines():
        s = ln.strip()
        op = None
        if "stablehlo.dot_general" in s or "stablehlo.dot " in s:
            op = "dot_general"
        elif "stablehlo.reduce" in s and "applies stablehlo.add" in s:
            op = "reduce_add"
        if op is None:
            continue
        m = _RESULT_TYPE_RE.search(s)
        if not m:
            continue
        elems = _ELEM_RE.findall(m.group(1))
        for e in elems:
            out.append((op, e, s))
    return out


def check_accumulates_in(text: str, dtype: str = "f32",
                         allow: tuple = ("i32", "i64", "ui32",
                                         "i8", "ui8", "i1")) -> tuple:
    """``accumulates_in(f32)``: every matmul / additive reduction in the
    lowering lands in ``dtype`` (integer reductions are exempt) — the
    precision-fragility guard for the fused-update and Newton–Schulz math
    (Li et al. 2023; SOLO): a bf16 gram accumulation would pass every
    shape check and quietly widen the quantization error band."""
    sites = accumulation_sites(text)
    bad = [(op, e, ln) for op, e, ln in sites
           if e != dtype and e not in allow]
    ok = not bad
    detail = f"{len(sites)} accumulation site(s), all {dtype}/integer" \
        if ok else (f"{len(bad)} site(s) accumulate outside {dtype}, "
                    f"e.g. {bad[0][1]} in: {bad[0][2][:110]}")
    return ok, detail


_PIN_OPERAND_RE = re.compile(r"\(tensor<(?:(\d+(?:x\d+)*)x)?[a-z0-9]+>\)")


def replicated_pins(text: str, vectors_only: bool = False,
                    exclude_shapes: tuple = ()) -> int:
    """Number of fully-replicated sharding pins in the lowering — the
    ``custom_call @Sharding`` sites with ``{replicated}`` placement that
    ``rules.replicate_for_scales`` emits (DESIGN.md §12).

    ``vectors_only`` skips scalar pins and ``exclude_shapes`` skips named
    operand shapes (e.g. the ``(256,)`` codebook constants, which are
    pinned by the arena layout, not by replicate_for_scales) — so callers
    can count specifically the per-tensor scale pins."""
    n = 0
    for ln in text.splitlines():
        if "@Sharding" not in ln or "replicated" not in ln:
            continue
        if vectors_only or exclude_shapes:
            m = _PIN_OPERAND_RE.search(ln)
            dims = (tuple(int(d) for d in m.group(1).split("x"))
                    if m and m.group(1) else ())
            if vectors_only and not dims:
                continue
            if dims in tuple(exclude_shapes):
                continue
        n += 1
    return n


def check_replicated(text: str, min_pins: int = 1, *,
                     vectors_only: bool = False,
                     exclude_shapes: tuple = ()) -> tuple:
    """``replicated(tensor_scales, gnorm_vec)``: a partitioned lowering
    must pin its global-scale reductions fully replicated (§12) — without
    the pin SPMD may distribute the reduction and change the f32 summation
    order, silently breaking the partitioned/unpartitioned bit-exactness
    contract."""
    n = replicated_pins(text, vectors_only=vectors_only,
                        exclude_shapes=exclude_shapes)
    ok = n >= min_pins
    return ok, f"{n} replicated pin(s), need >= {min_pins}"


def marker_positions(text: str, markers) -> list:
    """First-occurrence index of each marker substring (-1 = absent)."""
    return [text.find(m) for m in markers]


def check_collective_order(text: str, *markers, require_all=True) -> tuple:
    """``collective_order(a -> b -> ...)``: the first occurrence of each
    marker must appear in the given order.  Used for the §13 step shape —
    the params all-gather (serving the previous update's deferred
    materialization) precedes the grad reduce-scatters, which precede the
    update's donated writeback."""
    pos = marker_positions(text, markers)
    missing = [m for m, p in zip(markers, pos) if p < 0]
    if missing:
        return (not require_all), f"marker(s) absent: {missing}"
    present = [(m, p) for m, p in zip(markers, pos)]
    ordered = all(p1 < p2 for (_, p1), (_, p2) in zip(present, present[1:]))
    chain = " -> ".join(m for m, _ in present)
    return ordered, f"first-occurrence order {'holds' if ordered else 'VIOLATED'}: {chain}"


def lowering_invariant(texts: dict, *, compare_aliases_only: bool = False
                       ) -> tuple:
    """``lowering_invariant_to(knob)``: the PR-7 zero-overhead guard as a
    reusable API.  ``texts`` maps knob values to StableHLO text; with
    ``compare_aliases_only=False`` all texts must be *byte-identical*
    (the knob is host-schedule only); with ``True`` only the donation-
    aliasing counts must match (the knob may restructure the computation
    — e.g. ``overlap_buckets`` changes bucketing — but must never cost an
    in-place arena)."""
    items = sorted(texts.items(), key=lambda kv: str(kv[0]))
    if len(items) < 2:
        raise AnalysisError("lowering_invariant needs >= 2 lowerings")
    if compare_aliases_only:
        counts = {k: sum(donation_markers(t).values()) for k, t in items}
        vals = set(counts.values())
        ok = len(vals) == 1 and next(iter(vals)) > 0
        return ok, f"donation marks per knob value: {counts}"
    base_k, base_t = items[0]
    for k, t in items[1:]:
        if t != base_t:
            # locate the first differing line for the report
            a, b = base_t.splitlines(), t.splitlines()
            for i, (la, lb) in enumerate(zip(a, b)):
                if la != lb:
                    return False, (f"knob {base_k!r} vs {k!r}: lowering "
                                   f"diverges at line {i + 1}: "
                                   f"{la.strip()[:60]!r} != "
                                   f"{lb.strip()[:60]!r}")
            return False, (f"knob {base_k!r} vs {k!r}: lowering lengths "
                           f"differ ({len(a)} vs {len(b)} lines)")
    return True, f"{len(items)} lowering(s) byte-identical"


# --------------------------------------------------------------- registry
@dataclasses.dataclass(frozen=True)
class ContractSpec:
    """One registered contract: a named check bound to a scope.  ``check``
    takes ``(lowering_or_pair, cell)`` and returns ``(ok, detail)`` or
    ``None`` (not applicable to this cell)."""
    name: str
    scope: str
    check: Callable[[Any, Any], Optional[tuple]]
    doc: str = ""


_REGISTRY: dict = {}


def register(name: str, scope: str, check: Callable, doc: str = "") -> None:
    """Register (or re-register — module reloads are idempotent) a
    contract.  Call this next to the code the contract protects."""
    _REGISTRY[name] = ContractSpec(name=name, scope=scope, check=check,
                                   doc=doc)


def contracts_for(scope: str) -> list:
    """Registered contracts bound to ``scope``, name-ordered."""
    return [s for _, s in sorted(_REGISTRY.items()) if s.scope == scope]


def all_contracts() -> list:
    return [s for _, s in sorted(_REGISTRY.items())]


def evaluate(spec: ContractSpec, subject, cell) -> Optional[ContractResult]:
    """Run one contract; ``None`` means not applicable."""
    out = spec.check(subject, cell)
    if out is None:
        return None
    ok, detail = out
    target = getattr(cell, "name", None) or getattr(subject, "name", "?")
    return ContractResult(contract=spec.name, target=str(target),
                          ok=bool(ok), detail=detail)
