"""Static-analysis subsystem (DESIGN.md §15): compile-contract auditors,
the Pallas VMEM/grid resource analyzer, and the repo lint gate.

Three auditors, one CLI (``python -m repro.analysis``, a blocking CI leg):

  * :mod:`repro.analysis.contracts` — declarative invariant checks over
    ``jax.jit(...).lower(...)`` StableHLO text (donation aliasing, dtype
    bans, f32 accumulation, collective ordering, replication pins, and
    knob-invariant lowering), registered next to the code they protect
    and evaluated over a config matrix without running a training step.
  * :mod:`repro.analysis.kernel_budget` — per-tile VMEM byte model derived
    from the kernels' BlockSpec/grid layouts, checked against a
    per-backend budget, plus grid alignment vs ArenaPartition/BucketPlan.
  * :mod:`repro.analysis.lint` — AST rules encoding repo conventions
    (no bare assert on user-reachable paths, no host syncs in jit, no
    trace-time env reads, no duplicate imports) with a burn-down baseline.

This ``__init__`` stays import-light on purpose: production modules
(kernels/ops.py, train/loop.py, sharding/rules.py) import
``repro.analysis.contracts`` / ``.mutations`` at module level to register
their contracts, so nothing here may pull in jax or the heavy subsystems.
``runner`` / ``kernel_budget`` / ``lint`` are imported explicitly by the
CLI and tests.
"""
from repro.analysis import contracts, dtypes, mutations
from repro.analysis.dtypes import DTYPE_BYTES, dtype_bytes

__all__ = ["contracts", "dtypes", "mutations", "DTYPE_BYTES", "dtype_bytes"]
