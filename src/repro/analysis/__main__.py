"""CLI for the static-analysis gate: ``python -m repro.analysis``.

Subcommands (default: all three, any failure exits non-zero):

  contracts   evaluate registered compile contracts over the config matrix
  kernels     Pallas VMEM budget + grid-alignment audit
  lint        AST lint gate against the committed baseline
              (``--write-baseline`` rewrites it)

The contract matrix includes 4-way partitioned cells, so the CLI forces
4 host platform devices before jax is imported — run it as a module, not
via an already-jax-initialized interpreter.
"""
from __future__ import annotations

import argparse
import os
import sys

# Must happen before any jax import (runner lowers on a 4-device mesh).
if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()


def _run_contracts(args) -> int:
    from repro.analysis import runner
    from repro.analysis.contracts import AnalysisError
    try:
        results = runner.run_contracts(allow_skips=args.allow_skips)
    except AnalysisError as e:
        print(f"contracts: {e}")
        return 1
    bad = runner.failures(results)
    print(f"contracts: {len(results) - len(bad)}/{len(results)} passed")
    return 1 if bad else 0


def _run_kernels(args) -> int:
    del args
    from repro.analysis import kernel_budget
    results = kernel_budget.audit()
    bad = [r for r in results if not r[1]]
    for name, ok, detail in results:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} — {detail}")
    print(f"kernels: {len(results) - len(bad)}/{len(results)} passed")
    return 1 if bad else 0


def _run_lint(args) -> int:
    from repro.analysis import lint
    root = args.root or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    ok, lines = lint.run(root, update_baseline=args.write_baseline)
    for ln in lines:
        print(ln)
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="compile contracts + kernel budgets + repo lint")
    ap.add_argument("what", nargs="?", default="all",
                    choices=("all", "contracts", "kernels", "lint"))
    ap.add_argument("--allow-skips", action="store_true",
                    help="tolerate matrix cells skipped for lack of devices")
    ap.add_argument("--write-baseline", action="store_true",
                    help="lint: rewrite the baseline instead of checking")
    ap.add_argument("--root", default=None,
                    help="lint: tree to lint (default: the repro package)")
    args = ap.parse_args(argv)

    legs = {"contracts": _run_contracts, "kernels": _run_kernels,
            "lint": _run_lint}
    picked = legs.items() if args.what == "all" else \
        [(args.what, legs[args.what])]
    rc = 0
    for name, fn in picked:
        print(f"=== {name} ===")
        rc |= fn(args)
    print("ANALYSIS " + ("PASS" if rc == 0 else "FAIL"))
    return rc


if __name__ == "__main__":
    sys.exit(main())
