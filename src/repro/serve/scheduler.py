"""Slot-based continuous batching over the paged quantized KV cache.

Replaces the fixed-bucket ``ServeEngine.generate`` loop for mixed-length
streams (DESIGN.md §17).  One decode step advances EVERY active slot; a
slot frees the moment its request completes, so the next waiting request
admits mid-stream instead of waiting for the whole bucket to drain.

Admission policy (§17):

  * submit-time validation: a request that could never fit the pool
    (``ceil((P + max_new) / page_size)`` pages beyond the per-seq cap or
    the whole pool) is rejected with ``ConfigError`` up front;
  * admit = reserve a slot and the prompt's pages, prefill the prompt
    through the DENSE 16-bit path (batch 1, ``max_len == P``), quantize
    the rows into the reserved pages (``commit_prefill_to_paged``), and
    sample the first token from the prefill logits;
  * lazy extension: pages are allocated one page-boundary at a time as a
    sequence grows; when the pool is dry the YOUNGEST request is
    preempted (LIFO) — released entirely and pushed back to the *front*
    of the waiting queue, so the oldest work is never starved;
  * restart-safe sampling: the stream for generated-token ``g`` of
    request ``rid`` is ``fold_in(fold_in(PRNGKey(seed), rid), g)`` —
    independent of scheduling, so a preempted request regenerates the
    same tokens it lost and differential tests stay exact.

Throughput note: sampling happens ON DEVICE inside the jitted step (the
scheduler only needs token COUNTS, which it knows, to admit/evict/
complete — never token values), so decode steps queue back-to-back with
no per-step host round-trip; the host blocks once per completion (the
latency observation) and copies the token matrix once per ``serve``.
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.errors import ConfigError
from repro.models import layers as L
from repro.models import model as M
from repro.serve import engine as engine_lib
from repro.serve.kvcache import PagedKVCache, PagedKVConfig, kv_bytes_per_token


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    prompt: tuple                  # token ids
    max_new_tokens: int


@dataclasses.dataclass
class SchedulerConfig:
    kv: PagedKVConfig = dataclasses.field(default_factory=PagedKVConfig)
    temperature: float = 0.0       # 0 => greedy
    seed: int = 0
    impl: str = "jnp"              # gather-dequant kernel (jnp|interpret)


class ContinuousBatchingEngine:
    """Continuous batching: admit/evict per decode step, paged 8/4-bit KV."""

    def __init__(self, cfg, params, sched_cfg: Optional[SchedulerConfig] =
                 None, registry=None):
        self.cfg = cfg
        self.params = params
        self.scfg = sched_cfg or SchedulerConfig()
        self.registry = registry
        self.kv = PagedKVCache(self.scfg.kv)
        kvc = self.scfg.kv
        self.caches = M.init_paged_cache(cfg, kvc.n_slots, kvc.n_pages,
                                         kvc.page_size, kvc.kv_bits)
        self._lat_counts = np.zeros((engine_lib.N_LATENCY_BINS,), np.int64)
        self._latencies_ms: list = []
        self._last_tok = jnp.zeros((kvc.n_slots,), jnp.int32)
        self._live: dict = {}      # rid -> live-request record (see _admit)
        base_key = jax.random.PRNGKey(self.scfg.seed)
        temp = self.scfg.temperature

        def _sample_rows(rows, rids, gen_idx):
            """rows: (B, V) logits -> (B,) sampled tokens, on device."""
            if temp <= 0.0:
                return jnp.argmax(rows, axis=-1).astype(jnp.int32)

            def one(row, rid, g):
                key = jax.random.fold_in(jax.random.fold_in(base_key, rid),
                                         g)
                return jax.random.categorical(key, row / temp)

            return jax.vmap(one)(rows, rids, gen_idx).astype(jnp.int32)

        impl = self.scfg.impl

        def _step(params, last_tok, caches, table, pos, rids, gen_idx):
            """One decode step, all bookkeeping on device: sample in-jit,
            advance positions/gen counters in-jit — between scheduling
            events (admit/complete/evict/page-boundary) the host launches
            these back-to-back with zero uploads or syncs."""
            paged = L.PagedContext(table, pos, impl=impl)
            logits, caches = M.paged_decode_step(cfg, params,
                                                 last_tok[:, None], caches,
                                                 paged)
            tok = _sample_rows(logits[:, 0], rids, gen_idx)
            active = pos >= 0
            return (tok, caches, jnp.where(active, pos + 1, pos),
                    jnp.where(active, gen_idx + 1, gen_idx))

        def _sample_one(row, rid, g):
            return _sample_rows(row[None], jnp.asarray([rid]),
                                jnp.asarray([g]))[0]

        # pages update in place: the cache pytree is donated (§17 contract)
        self._decode = jax.jit(_step, donate_argnums=(2,))
        self._sample1 = jax.jit(_sample_one)
        self._prefills: dict = {}  # prompt_len -> jitted dense prefill
        self._commits: dict = {}   # prompt_len -> jitted page commit

    # ----------------------------------------------------------- helpers
    def _prefill_fn(self, P: int):
        if P not in self._prefills:
            cfg16 = dataclasses.replace(self.cfg, kv_cache_bits=16)

            def _pf(params, tokens):
                return M.prefill(cfg16, params, tokens, max_len=P)

            self._prefills[P] = jax.jit(_pf)
        return self._prefills[P]

    def _commit_fn(self, P: int):
        if P not in self._commits:
            kv_bits = self.scfg.kv.kv_bits

            def _cm(paged_caches, dense, slot, table_row):
                return M.commit_prefill_to_paged(self.cfg, paged_caches,
                                                 dense, slot, table_row, P,
                                                 kv_bits=kv_bits)

            self._commits[P] = jax.jit(_cm, donate_argnums=(0,))
        return self._commits[P]

    def _count(self, name: str, n: int = 1):
        if self.registry is not None:
            self.registry.counter(name).inc(n)

    def _gauges(self):
        if self.registry is None:
            return
        kvc = self.scfg.kv
        self.registry.gauge("serve/sched/slot_occupancy").set(
            self.kv.n_active / kvc.n_slots)
        self.registry.gauge("serve/sched/page_occupancy").set(
            self.kv.alloc.occupancy)

    def _observe_request(self, wall_ms: float):
        self._latencies_ms.append(wall_ms)
        if self.registry is None:
            return
        self._lat_counts[bisect.bisect(engine_lib.LATENCY_BIN_EDGES_MS,
                                       wall_ms)] += 1
        self.registry.histogram(
            "serve/latency_ms",
            n_bins=engine_lib.N_LATENCY_BINS).observe_counts(self._lat_counts)

    # ------------------------------------------------------- transitions
    def _validate(self, req: Request):
        kvc = self.scfg.kv
        total = len(req.prompt) + req.max_new_tokens
        need = kvc.pages_needed(total)
        if need > kvc.max_pages_per_seq or need > kvc.n_pages:
            raise ConfigError(
                f"request {req.rid}: {total} tokens need {need} pages, "
                f"pool caps at min(max_pages_per_seq={kvc.max_pages_per_seq}"
                f", n_pages={kvc.n_pages})")
        if req.max_new_tokens <= 0:
            raise ConfigError(
                f"request {req.rid}: max_new_tokens must be positive")

    def _admit(self, req: Request) -> bool:
        P = len(req.prompt)
        slot = self.kv.admit(req.rid, P)
        if slot is None:
            return False
        t0 = time.perf_counter()
        logits, dense = self._prefill_fn(P)(
            self.params, jnp.asarray(np.asarray(req.prompt, np.int32)[None]))
        self.caches = self._commit_fn(P)(
            self.caches, dense, slot, jnp.asarray(self.kv.page_table[slot]))
        tok0 = self._sample1(logits[0, -1], req.rid, 0)   # device scalar
        self._last_tok = self._last_tok.at[slot].set(tok0)
        # chain = where each generated token lives, without syncing:
        # ("a", device_scalar) for the admission sample, ("s", step_idx)
        # for decode steps (the slot row of that step's token vector)
        self._live[req.rid] = {"req": req, "t0": t0, "n_out": 1,
                               "chain": [("a", tok0)]}
        self._count("serve/sched/admitted")
        self._count("serve/prompt_tokens", P)
        return True

    def _evict_youngest(self, waiting, protect=None) -> bool:
        """Preempt the youngest admitted request back to the queue front."""
        victims = sorted(self.kv.slots.values(), key=lambda s: -s.admit_order)
        for st in victims:
            if st.rid == protect:
                continue
            self.kv.release(st.rid)
            waiting.appendleft(self._live.pop(st.rid)["req"])
            self._count("serve/sched/evictions")
            return True
        return False

    def _complete(self, rid: int, done: dict):
        st = self._live.pop(rid)
        self.kv.release(rid)
        # block on the request's last token: the one per-request device
        # sync, and what makes the latency observation wall-clock-honest
        last = st["chain"][-1]
        jax.block_until_ready(last[1] if last[0] == "a" else self._last_tok)
        done[rid] = st
        self._observe_request((time.perf_counter() - st["t0"]) * 1e3)
        self._count("serve/sched/completed")
        self._count("serve/generated_tokens", st["n_out"])

    # --------------------------------------------------------------- run
    def serve(self, requests) -> dict:
        """Run every request to completion; returns {rid: (n,) int32}."""
        for r in requests:
            self._validate(r)
        waiting = collections.deque(requests)
        done: dict = {}
        step_toks: list = []       # per decode step: (B,) device tokens
        step_slots: list = []      # per decode step: {rid: slot} snapshot
        kvc = self.scfg.kv
        t_serve = time.perf_counter()
        while waiting or self._live:
            # 1. admit as many waiting requests as slot+page budget allows
            while waiting and self.kv.free_slot() is not None:
                if not self._admit(waiting[0]):
                    break
                waiting.popleft()
            # 2. single-token completions never reach the decode batch
            for rid in [r for r, st in self._live.items()
                        if st["n_out"] >= st["req"].max_new_tokens]:
                self._complete(rid, done)
            if not self._live:
                # everything completed this turn; retry admission next
                # iteration — unless nothing can fit an EMPTY pool, which
                # validation should have caught
                if waiting and self.kv.alloc.n_allocated == 0 and \
                        not self._admit(waiting[0]):
                    raise ConfigError(
                        f"request {waiting[0].rid} cannot admit into an "
                        f"empty pool — capacity validation is broken")
                if waiting and self.kv.n_active > 0:
                    waiting.popleft()          # the forced admit succeeded
                continue
            # 3. make sure every active slot's write position has a page
            for rid in list(self._live):
                if rid not in self._live:      # evicted for a prior slot
                    continue
                while not self.kv.extend(rid):
                    if not self._evict_youngest(waiting, protect=rid):
                        raise ConfigError(
                            f"request {rid} cannot extend with the pool to "
                            f"itself — capacity validation is broken")
            self._gauges()
            # 4. run the next k decode steps back-to-back: scheduling can
            # only change at a completion or a page boundary, both known
            # ahead of time, so until then positions/counters advance on
            # device and the host does no uploads and no syncs
            rids = np.zeros((kvc.n_slots,), np.int32)
            gen = np.zeros((kvc.n_slots,), np.int32)
            snapshot = {}
            k = None
            for rid in self._live:
                slot = self.kv.slot_of(rid)
                st = self._live[rid]
                rids[slot] = rid
                gen[slot] = st["n_out"]
                snapshot[rid] = slot
                to_done = st["req"].max_new_tokens - st["n_out"]
                to_edge = (len(self.kv.slots[slot].pages) * kvc.page_size
                           - self.kv.slots[slot].position)
                k = min(x for x in (k, to_done, to_edge) if x is not None)
            table = jnp.asarray(self.kv.page_table)
            pos = jnp.asarray(self.kv.positions)
            d_rids, d_gen = jnp.asarray(rids), jnp.asarray(gen)
            for _ in range(k):
                self._last_tok, self.caches, pos, d_gen = self._decode(
                    self.params, self._last_tok, self.caches, table, pos,
                    d_rids, d_gen)
                step_toks.append(self._last_tok)
                step_slots.append(snapshot)
            # 5. advance host bookkeeping k steps, complete finished
            for rid, slot in snapshot.items():
                st = self._live[rid]
                for j in range(k):
                    self.kv.advance(rid)
                    st["n_out"] += 1
                    st["chain"].append(("s", len(step_toks) - k + j))
                if st["n_out"] >= st["req"].max_new_tokens:
                    self._complete(rid, done)
        # one bulk sync for every decode-step token vector
        mat = np.asarray(jnp.stack(step_toks)) if step_toks else \
            np.zeros((0, kvc.n_slots), np.int32)
        results: dict = {}
        n_gen = 0
        for rid, st in done.items():
            toks = [int(np.asarray(e[1])) if e[0] == "a" else
                    int(mat[e[1], step_slots[e[1]][rid]])
                    for e in st["chain"]]
            results[rid] = np.asarray(toks, np.int32)
            n_gen += len(toks)
        wall = time.perf_counter() - t_serve
        if self.registry is not None and n_gen and wall > 0:
            self.registry.gauge("serve/tokens_per_s").set(n_gen / wall)
            self.registry.gauge("serve/kv_bytes_per_token").set(
                kv_bytes_per_token(self.cfg, kvc.kv_bits))
            self._count("serve/requests", len(results))
        self._gauges()
        return results

    # ----------------------------------------------------------- metrics
    def latency_percentiles(self) -> dict:
        """p50/p99 per-request latency (ms) over everything served."""
        if not self._latencies_ms:
            return {"p50_ms": 0.0, "p99_ms": 0.0}
        arr = np.asarray(self._latencies_ms)
        return {"p50_ms": float(np.percentile(arr, 50)),
                "p99_ms": float(np.percentile(arr, 99))}
