"""Paged quantized KV-cache management for serving (DESIGN.md §17).

Host-side bookkeeping over the device-side page pool that
``models.model.init_paged_cache`` builds:

  * :class:`PageAllocator` — the free list.  Strict: allocating from an
    empty pool returns None (the scheduler's eviction trigger), freeing a
    free page or foreign id raises ``ConfigError``.  The invariants the
    property suite pins (tests/test_serve_paged.py): no double-free, no
    orphaned page, ``n_free + n_allocated == n_pages`` exactly, always.
  * :class:`PagedKVCache` — slots + page tables + the allocator, wrapping
    the model cache pytree.  One *slot* is one row of the fixed decode
    batch; a request owns a slot and an ordered list of physical pages
    (its page-table row).  ``admit``/``extend``/``release`` keep the host
    mirror (numpy) and the device ``PagedContext`` inputs consistent.

Device-side compile contracts (evaluated by ``python -m repro.analysis``
over :func:`repro.analysis.runner.lower_serve`): the jitted paged decode
step must donate the cache pytree (pages update in place — a serving
engine that silently double-buffers its KV pool has no memory win) and
must lower with no f64 anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.analysis import contracts as _contracts
from repro.errors import ConfigError


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    """Static layout of the serving KV pool."""
    page_size: int = 16
    n_pages: int = 64
    n_slots: int = 8
    max_pages_per_seq: int = 16
    kv_bits: int = 8               # 8 | 4 (packed codes)

    def __post_init__(self):
        if self.kv_bits not in (4, 8):
            raise ConfigError(f"kv_bits must be 4 or 8, got {self.kv_bits}")
        for f in ("page_size", "n_pages", "n_slots", "max_pages_per_seq"):
            if getattr(self, f) <= 0:
                raise ConfigError(f"{f} must be positive")

    def pages_needed(self, n_tokens: int) -> int:
        """Pages covering token positions [0, n_tokens)."""
        return -(-n_tokens // self.page_size)

    def max_tokens_per_seq(self) -> int:
        return self.max_pages_per_seq * self.page_size


class PageAllocator:
    """Free-list allocator over ``n_pages`` physical pages."""

    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ConfigError(f"n_pages must be positive, got {n_pages}")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))
        self._allocated: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    @property
    def occupancy(self) -> float:
        return self.n_allocated / self.n_pages

    def alloc(self, n: int) -> Optional[list]:
        """``n`` pages, or None (all-or-nothing) when the pool is short."""
        if n < 0:
            raise ConfigError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._allocated:
                raise ConfigError(
                    f"double-free or foreign page id {p} (allocated: "
                    f"{sorted(self._allocated)})")
            self._allocated.remove(p)
            self._free.append(p)


@dataclasses.dataclass
class SlotState:
    """Host mirror of one occupied decode slot."""
    rid: int                       # request id
    pages: list                    # ordered physical page ids
    position: int                  # next token index to be written
    admit_order: int               # monotonic admit counter (evict = LIFO)


class PagedKVCache:
    """Slots + page tables over one model's paged cache pytree."""

    def __init__(self, kvcfg: PagedKVConfig):
        self.cfg = kvcfg
        self.alloc = PageAllocator(kvcfg.n_pages)
        self.slots: dict = {}      # slot index -> SlotState
        self._by_rid: dict = {}    # rid -> slot index
        self._admits = 0
        self.page_table = np.full((kvcfg.n_slots, kvcfg.max_pages_per_seq),
                                  -1, np.int32)
        self.positions = np.full((kvcfg.n_slots,), -1, np.int32)

    # ------------------------------------------------------------ queries
    @property
    def n_active(self) -> int:
        return len(self.slots)

    def free_slot(self) -> Optional[int]:
        for s in range(self.cfg.n_slots):
            if s not in self.slots:
                return s
        return None

    def slot_of(self, rid: int) -> int:
        return self._by_rid[rid]

    def youngest_rid(self) -> Optional[int]:
        """Most recently admitted request (the eviction victim)."""
        if not self.slots:
            return None
        return max(self.slots.values(), key=lambda st: st.admit_order).rid

    # ------------------------------------------------------- transitions
    def admit(self, rid: int, prompt_len: int) -> Optional[int]:
        """Reserve a slot + pages covering the prompt AND the first
        generated token's append (position ``prompt_len``).  Returns the
        slot index, or None when no slot/pages are available."""
        need = self.cfg.pages_needed(prompt_len + 1)
        if need > self.cfg.max_pages_per_seq:
            raise ConfigError(
                f"request {rid}: prompt of {prompt_len} tokens needs {need} "
                f"pages > max_pages_per_seq={self.cfg.max_pages_per_seq}")
        slot = self.free_slot()
        if slot is None:
            return None
        pages = self.alloc.alloc(need)
        if pages is None:
            return None
        st = SlotState(rid=rid, pages=pages, position=prompt_len,
                       admit_order=self._admits)
        self._admits += 1
        self.slots[slot] = st
        self._by_rid[rid] = slot
        self.page_table[slot, :need] = pages
        self.positions[slot] = prompt_len
        return slot

    def extend(self, rid: int) -> bool:
        """Ensure the slot's CURRENT write position has a page; allocates
        one page at the boundary.  False = pool exhausted (evict and
        retry)."""
        st = self.slots[self._by_rid[rid]]
        need = self.cfg.pages_needed(st.position + 1)
        if need <= len(st.pages):
            return True
        if need > self.cfg.max_pages_per_seq:
            raise ConfigError(
                f"request {rid} at position {st.position} exceeds "
                f"max_pages_per_seq={self.cfg.max_pages_per_seq}")
        new = self.alloc.alloc(need - len(st.pages))
        if new is None:
            return False
        slot = self._by_rid[rid]
        self.page_table[slot, len(st.pages):need] = new
        st.pages.extend(new)
        return True

    def advance(self, rid: int) -> None:
        """The decode step wrote position ``position``; move to the next."""
        slot = self._by_rid[rid]
        self.slots[slot].position += 1
        self.positions[slot] = self.slots[slot].position

    def release(self, rid: int) -> None:
        """Free every page and the slot (completion or eviction)."""
        slot = self._by_rid.pop(rid)
        st = self.slots.pop(slot)
        self.alloc.free(st.pages)
        self.page_table[slot, :] = -1
        self.positions[slot] = -1

    # ---------------------------------------------------------- metrics
    def check_invariants(self) -> None:
        """Raise ConfigError on any bookkeeping drift (test hook)."""
        owned = [p for st in self.slots.values() for p in st.pages]
        if len(owned) != len(set(owned)):
            raise ConfigError("page owned by two slots")
        if set(owned) != self.alloc._allocated:
            raise ConfigError(
                f"orphaned/phantom pages: slots own {sorted(set(owned))}, "
                f"allocator says {sorted(self.alloc._allocated)}")
        if self.alloc.n_free + self.alloc.n_allocated != self.cfg.n_pages:
            raise ConfigError("occupancy bookkeeping drift")
        table_pages = set(self.page_table[self.page_table >= 0].tolist())
        if table_pages != set(owned):
            raise ConfigError("device page table out of sync with slots")


def kv_bytes_per_token(cfg, kv_bits: int) -> float:
    """Stored KV bytes per generated token across all attn layers (codes +
    absmax; the page-table int32s amortize to noise and are excluded).
    ``kv_bits=16`` gives the unquantized fp16 baseline."""
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")
    if kv_bits == 16:
        per_row = 2 * Dh
        return float(2 * KV * per_row * n_attn)          # k and v
    from repro.kernels.paged_kv import packed_row_width
    per_row = packed_row_width(Dh, kv_bits) + 4          # codes + absmax f32
    return float(2 * KV * per_row * n_attn)


# ------------------------------------------------- compile contracts (§15)
# Registered here, next to the serving cache they protect; evaluated over
# repro.analysis.runner.lower_serve by `python -m repro.analysis`.

_contracts.register(
    "serve_decode.donates_cache", "serve",
    lambda low, cell: _contracts.check_donates(low.text, min_aliases=1),
    doc="the jitted paged decode step updates its KV pages in place "
        "(donated cache pytree) — no shadow copy of the pool (§17)")
_contracts.register(
    "serve_decode.no_f64", "serve",
    lambda low, cell: _contracts.check_no_dtype(low.text, "f64"),
    doc="no f64 anywhere in the paged decode step (§6 dtype policy)")
