"""Batched serving engine: prefill + decode with a fixed-slot batch.

``ServeEngine`` jit-compiles one prefill and one decode step per (batch,
prompt-len) bucket and runs greedy/temperature sampling.  ``decode_fn`` is
the function the dry-run lowers for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M

# Request-latency histogram edges (ms), log-spaced.  The registry's
# Histogram takes PRE-BINNED counts (registry.py), so the engine bins
# host-side: a request of latency t lands in bisect(edges, t) — one
# overflow bin past the last edge.
LATENCY_BIN_EDGES_MS = (1.0, 3.0, 10.0, 30.0, 100.0, 300.0,
                        1000.0, 3000.0, 10000.0)
N_LATENCY_BINS = len(LATENCY_BIN_EDGES_MS) + 1


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0       # 0 => greedy
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg, params, serve_cfg: ServeConfig = ServeConfig(),
                 registry=None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        # Optional telemetry (DESIGN.md §14): request / prompt-token /
        # generated-token counters, a per-request latency histogram and a
        # generated-tokens/s gauge on the serving surface.  None = no
        # telemetry, no overhead.
        self.registry = registry
        # cumulative latency bins: observe_counts REPLACES the histogram
        # value, so the engine owns the running counts
        self._lat_counts = np.zeros((N_LATENCY_BINS,), np.int64)
        # per-call stream counter: folding it into the seed gives every
        # generate() call its own sampling stream — a fixed PRNGKey(seed)
        # here made successive temperature>0 batches sample identically
        self._n_calls = 0

        def _prefill(params, tokens):
            return M.prefill(cfg, params, tokens, max_len=serve_cfg.max_len)

        def _decode(params, token, caches, pos):
            return M.decode_step(cfg, params, token, caches, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _observe_request(self, n_requests: int, n_tokens: int,
                         wall_s: float) -> None:
        self._lat_counts[bisect.bisect(LATENCY_BIN_EDGES_MS,
                                       wall_s * 1e3)] += n_requests
        self.registry.histogram("serve/latency_ms",
                                n_bins=N_LATENCY_BINS).observe_counts(
                                    self._lat_counts)
        if n_tokens and wall_s > 0:
            self.registry.gauge("serve/tokens_per_s").set(
                n_tokens / wall_s)

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.scfg.temperature,
                                      axis=-1)

    def generate(self, prompts: np.ndarray, max_new_tokens: int):
        """prompts: (B, P) int32 (right-aligned, equal length for the batch
        bucket). Returns (B, max_new_tokens) int32."""
        B, P = prompts.shape
        if max_new_tokens < 0:
            raise ValueError(f"max_new_tokens must be >= 0, got "
                             f"{max_new_tokens}")
        # A bare assert vanishes under `python -O`; capacity overrun must
        # fail loudly with the offending lengths either way.
        if P + max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt length {P} + max_new_tokens {max_new_tokens} "
                f"exceeds max_len {self.scfg.max_len}")
        if self.registry is not None:
            self.registry.counter("serve/requests").inc(B)
            self.registry.counter("serve/prompt_tokens").inc(B * P)
        t0 = time.perf_counter()
        if max_new_tokens == 0:
            # the prefill-sampled token belongs to position P; emitting it
            # would return shape (B, 1) for a 0-token request
            if self.registry is not None:
                self._observe_request(B, 0, time.perf_counter() - t0)
            return np.zeros((B, 0), np.int32)
        key = jax.random.fold_in(jax.random.PRNGKey(self.scfg.seed),
                                 self._n_calls)
        self._n_calls += 1
        logits, caches = self._prefill(self.params, jnp.asarray(prompts))
        out = []
        key, k = jax.random.split(key)
        tok = self._sample(logits[:, -1], k)
        out.append(tok)
        for i in range(max_new_tokens - 1):
            key, k = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None], caches,
                                          jnp.asarray(P + i, jnp.int32))
            tok = self._sample(logits[:, 0], k)
            out.append(tok)
        res = np.asarray(jnp.stack(out, axis=1))   # blocks on the device
        if self.registry is not None:
            self.registry.counter("serve/generated_tokens").inc(
                B * max_new_tokens)
            self._observe_request(B, B * max_new_tokens,
                                  time.perf_counter() - t0)
        return res
