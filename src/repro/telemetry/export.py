"""Telemetry sinks and the JSONL event schema (DESIGN.md §14).

One writer for everything the repo records about a run: the typed-metric
registry (``registry.py``), the quantization-health probes (``qhealth.py``)
and the step-phase timeline (``tracing.py``) all emit *events* — plain
dicts with a ``kind`` — into *sinks*.  Three sinks exist:

  * :class:`JsonlSink` — one JSON object per line (the ``--telemetry-dir``
    artifact format; schema-validated by :func:`validate_jsonl`);
  * :class:`InMemorySink` — a list, for tests and the quickstart summary;
  * :class:`BenchJsonSink` — routes events into a ``BENCH_*.json``
    trajectory file via :func:`append_json_trajectory`, the dedupe-by-
    (cell, commit) writer that ``benchmarks/common.append_bench_json``
    delegates to — so benchmark rows and telemetry share one writer.

The schema is versioned (``SCHEMA``) and deliberately small: every event
carries ``kind`` and ``step``; per-kind required fields are listed in
``EVENT_FIELDS`` and enforced by :func:`validate_event`.  Extra fields are
always allowed (events are forward-compatible).
"""
from __future__ import annotations

import json
import os
from typing import Any, Iterable, Optional

SCHEMA = "repro.telemetry.v1"

# kind -> required fields (beyond "kind"/"step"/"schema").  Extra fields are
# allowed; validation only enforces presence + basic types of these.
EVENT_FIELDS = {
    # one named, typed metric sample (registry.py)
    "metric": ("name", "type", "value"),
    # host-side step-phase timeline entry (tracing.py)
    "phase": ("phase", "wall_s"),
    # trace-time dispatch accounting for one compiled step (tracing.py)
    "trace": ("phases",),
    # per-segment quantization health (qhealth.py)
    "qhealth": ("target", "segment", "slot", "saturation_fraction",
                "util_hist", "util_fraction", "absmax_mean", "absmax_drift"),
    # detector escalation (sentinel.py, DESIGN.md §16): a watched signal
    # crossed its threshold — reason names the detector, severity is one
    # of ANOMALY_SEVERITIES, value is the offending measurement
    "anomaly": ("reason", "severity", "value"),
}

METRIC_TYPES = ("counter", "gauge", "histogram")
ANOMALY_SEVERITIES = ("warn", "error", "fatal")


def validate_event(ev: Any) -> list:
    """Schema errors for one event dict (empty list == valid)."""
    errs = []
    if not isinstance(ev, dict):
        return [f"event is {type(ev).__name__}, not dict"]
    kind = ev.get("kind")
    if kind not in EVENT_FIELDS:
        return [f"unknown kind {kind!r} (have {sorted(EVENT_FIELDS)})"]
    if ev.get("schema") != SCHEMA:
        errs.append(f"schema is {ev.get('schema')!r}, want {SCHEMA!r}")
    if not isinstance(ev.get("step"), int):
        errs.append(f"step is {ev.get('step')!r}, want int")
    for f in EVENT_FIELDS[kind]:
        if f not in ev:
            errs.append(f"{kind} event missing field {f!r}")
    if kind == "metric" and ev.get("type") not in METRIC_TYPES:
        errs.append(f"metric type {ev.get('type')!r} not in {METRIC_TYPES}")
    if kind == "metric" and ev.get("type") == "histogram":
        v = ev.get("value")
        if not isinstance(v, list):
            errs.append("histogram value must be a list of bin counts")
    if kind == "qhealth":
        if not isinstance(ev.get("util_hist"), list):
            errs.append("qhealth util_hist must be a list of bin counts")
    if kind == "trace" and not isinstance(ev.get("phases"), list):
        errs.append("trace phases must be a list")
    if kind == "anomaly" and "severity" in ev and \
            ev.get("severity") not in ANOMALY_SEVERITIES:
        errs.append(f"anomaly severity {ev.get('severity')!r} not in "
                    f"{ANOMALY_SEVERITIES}")
    return errs


def validate_jsonl(path: str) -> tuple:
    """Validate a telemetry JSONL artifact.

    Returns ``(events, errors)``: the parsed event dicts and a list of
    ``(line_number, error)`` strings — empty ``errors`` means the file is
    schema-valid."""
    events, errors = [], []
    with open(path) as f:
        for i, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"line {i}: not JSON ({e})")
                continue
            for err in validate_event(ev):
                errors.append(f"line {i}: {err}")
            events.append(ev)
    return events, errors


class InMemorySink:
    """Keeps events in a list (tests, quickstart summary)."""

    def __init__(self):
        self.events: list = []

    def write(self, event: dict) -> None:
        self.events.append(event)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """Appends one JSON object per line; flushes eagerly so a preempted
    run leaves a readable artifact."""

    def __init__(self, path: str):
        self.path = os.path.abspath(path)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self.path, "a")

    def write(self, event: dict) -> None:
        self._f.write(json.dumps(event) + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.flush()
        self._f.close()


class BenchJsonSink:
    """Routes events into a ``BENCH_*.json`` trajectory file: each event
    becomes one deduped entry via :func:`append_json_trajectory` (the same
    writer behind ``benchmarks/common.append_bench_json``)."""

    def __init__(self, path: str, dedupe_fields: tuple = (),
                 defaults: Optional[dict] = None):
        self.path = path
        self.dedupe_fields = tuple(dedupe_fields)
        self.defaults = dict(defaults or {})

    def write(self, event: dict) -> None:
        entry = {**self.defaults, **event}
        append_json_trajectory(self.path, entry, self.dedupe_fields)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


def append_json_trajectory(path: str, entry: dict,
                           dedupe_fields: Iterable = (),
                           defaults: Optional[dict] = None) -> str:
    """Record ``entry`` in a JSON trajectory file ``{"entries": [...]}``
    and return the absolute path.

    An existing entry agreeing with ``entry`` on every field in
    ``dedupe_fields`` is *replaced*, so repeat runs of the same cell don't
    pile up and the file reads as one row per (cell, commit).
    ``defaults`` are set on the entry only where absent.  Tolerates a
    missing or corrupt file.  This is the single trajectory writer shared
    by ``benchmarks/common.append_bench_json`` and :class:`BenchJsonSink`.
    """
    path = os.path.abspath(path)
    entry = dict(entry)
    for k, v in (defaults or {}).items():
        entry.setdefault(k, v)
    # Every trajectory entry carries a git_sha (it's a dedupe key): entries
    # written outside a git checkout — or by callers that couldn't resolve
    # one (detached/missing .git) — are stamped "unknown" rather than the
    # writer raising or silently dropping the key.
    entry.setdefault("git_sha", "unknown")
    data = {"entries": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {"entries": []}
    entries = data.setdefault("entries", [])
    fields = tuple(dedupe_fields)

    def key(e: dict) -> tuple:
        return tuple(repr(e.get(k)) for k in fields)

    if fields:
        k = key(entry)
        data["entries"] = [e for e in entries
                           if not (isinstance(e, dict) and key(e) == k)]
    data["entries"].append(entry)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return path
