"""Run inspector CLI: triage a telemetry dir or flight dump (§16).

    PYTHONPATH=src python -m repro.telemetry.inspect <run_dir>
    PYTHONPATH=src python -m repro.telemetry.inspect --flight <dump_dir>
    PYTHONPATH=src python -m repro.telemetry.inspect --diff <run_a> <run_b>
    PYTHONPATH=src python -m repro.telemetry.inspect --validate <run_dir>

Reads the schema-validated JSONL artifact a ``--telemetry-dir`` run
produced (``export.validate_jsonl`` is the gate — the inspector refuses
to summarize a malformed file) and renders the triage views: per-phase
wall-time breakdown, per-compile dispatch accounting, quantization-health
trends (first→last saturation/drift per probed segment), and the anomaly
timeline.  ``--flight`` renders a flight-recorder bundle (trigger, last
healthy snapshot, metrics ring tail).  ``--diff`` compares two runs'
phase totals and final gauge values.

Exit codes (CI contract, scripts/ci.sh):

    0  clean — schema-valid, no anomaly events
    1  anomalies present (or a flight dump was triggered)
    2  schema errors / unreadable artifact

``--validate`` runs only the schema gate (0/2), exposing
``export.validate_jsonl`` as a command-line check.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.telemetry import export as _export
from repro.telemetry import flight as _flight

EXIT_CLEAN, EXIT_ANOMALIES, EXIT_SCHEMA = 0, 1, 2


def _find_jsonl(path: str) -> Optional[str]:
    """Resolve a run dir (or direct file path) to its telemetry JSONL."""
    if os.path.isfile(path):
        return path
    if os.path.isdir(path):
        cands = sorted(f for f in os.listdir(path) if f.endswith(".jsonl"))
        pref = [c for c in cands if c == "telemetry.jsonl"] or cands
        if pref:
            return os.path.join(path, pref[0])
    return None


def _load(path: str, out) -> tuple:
    """(events, n_schema_errors) for one run; prints errors."""
    jsonl = _find_jsonl(path)
    if jsonl is None:
        print(f"error: no .jsonl artifact under {path}", file=out)
        return [], 1
    events, errors = _export.validate_jsonl(jsonl)
    for e in errors[:20]:
        print(f"  schema: {e}", file=out)
    if len(errors) > 20:
        print(f"  ... {len(errors) - 20} more schema errors", file=out)
    return events, len(errors)


# ------------------------------------------------------------ triage views
def _phase_breakdown(events: List[dict]) -> dict:
    """phase -> (total wall_s, count) over host "phase" events."""
    out: dict = {}
    for ev in events:
        if ev.get("kind") == "phase":
            t, n = out.get(ev["phase"], (0.0, 0))
            out[ev["phase"]] = (t + float(ev.get("wall_s", 0.0)), n + 1)
    return out


def _dispatch_accounting(events: List[dict]) -> List[dict]:
    """Trace-time per-phase dispatch counts (one list per compile)."""
    return [ev for ev in events if ev.get("kind") == "trace"]


def _qhealth_trends(events: List[dict]) -> dict:
    """(target, segment, slot) -> [first_ev, last_ev] qhealth samples."""
    trends: dict = {}
    for ev in events:
        if ev.get("kind") != "qhealth":
            continue
        key = (ev.get("target"), ev.get("segment"), ev.get("slot"))
        if key in trends:
            trends[key][1] = ev
        else:
            trends[key] = [ev, ev]
    return trends


def _anomalies(events: List[dict]) -> List[dict]:
    return [ev for ev in events if ev.get("kind") == "anomaly"]


def _final_gauges(events: List[dict]) -> dict:
    """name -> last scalar value over gauge/counter metric events."""
    out: dict = {}
    for ev in events:
        if ev.get("kind") == "metric" and ev.get("type") in ("gauge",
                                                             "counter"):
            v = ev.get("value")
            if isinstance(v, (int, float)):
                out[ev["name"]] = float(v)
    return out


def _render_run(path: str, events: List[dict], out) -> None:
    print(f"== run: {path} ({len(events)} events)", file=out)
    phases = _phase_breakdown(events)
    if phases:
        print("-- phase breakdown (host wall-clock)", file=out)
        total = sum(t for t, _ in phases.values()) or 1.0
        for ph, (t, n) in sorted(phases.items(), key=lambda kv: -kv[1][0]):
            print(f"   {ph:24s} {t:9.3f}s  x{n:<5d} {100 * t / total:5.1f}%",
                  file=out)
    for tr in _dispatch_accounting(events):
        pieces = ", ".join(f"{p.get('phase')}={p.get('dispatches')}"
                           for p in tr.get("phases", [])
                           if p.get("dispatches"))
        print(f"-- dispatch accounting (compile @ step {tr.get('step')}): "
              f"{pieces or 'no fused dispatches recorded'}", file=out)
    trends = _qhealth_trends(events)
    if trends:
        print("-- qhealth trends (first -> last)", file=out)
        for (tgt, seg, slot), (a, b) in sorted(trends.items(),
                                               key=lambda kv: str(kv[0])):
            print(f"   {tgt}/{seg}/{slot}: sat "
                  f"{a.get('saturation_fraction', 0):.4f}->"
                  f"{b.get('saturation_fraction', 0):.4f}  drift "
                  f"{a.get('absmax_drift', 0):.4f}->"
                  f"{b.get('absmax_drift', 0):.4f}", file=out)
    anoms = _anomalies(events)
    if anoms:
        print(f"-- anomaly timeline ({len(anoms)} events)", file=out)
        for ev in anoms:
            print(f"   step {ev.get('step'):>6} [{ev.get('severity')}] "
                  f"{ev.get('reason')}: value={ev.get('value')} "
                  f"{ev.get('detail', '')}", file=out)
    else:
        print("-- no anomalies", file=out)


def _render_flight(dump_dir: str, out) -> int:
    """Render a flight dump; returns an exit code."""
    try:
        manifest = _flight.load_dump(dump_dir)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"error: cannot read flight dump {dump_dir}: {e}", file=out)
        return EXIT_SCHEMA
    print(f"== flight dump: {dump_dir}", file=out)
    print(f"   reason: {manifest.get('reason')}  trigger step: "
          f"{manifest.get('trigger_step')}  last healthy snapshot: "
          f"{manifest.get('snapshot_step')}", file=out)
    print(f"   git_sha: {manifest.get('git_sha')}  config_hash: "
          f"{manifest.get('config_hash')}", file=out)
    ring = manifest.get("ring", [])
    for row in ring[-5:]:
        extras = {k: v for k, v in row.items() if k != "step"}
        brief = ", ".join(f"{k}={v:.4g}" if isinstance(v, float)
                          else f"{k}={v}" for k, v in list(extras.items())[:6])
        print(f"   ring step {row.get('step'):>6}: {brief}", file=out)
    # dump anomalies are schema-checked too: a dump that recorded a
    # malformed event should fail loudly here, not in a later reader
    errs = [e for ev in manifest.get("anomalies", [])
            for e in _export.validate_event(ev)]
    for ev in manifest.get("anomalies", []):
        print(f"   anomaly step {ev.get('step'):>6} [{ev.get('severity')}] "
              f"{ev.get('reason')}: {ev.get('value')}", file=out)
    if errs:
        for e in errs[:10]:
            print(f"   schema: {e}", file=out)
        return EXIT_SCHEMA
    # a flight dump only exists because something triggered it
    return EXIT_ANOMALIES


def _render_diff(a: str, b: str, out) -> int:
    ev_a, err_a = _load(a, out)
    ev_b, err_b = _load(b, out)
    if err_a or err_b:
        return EXIT_SCHEMA
    print(f"== diff: {a} vs {b}", file=out)
    ph_a, ph_b = _phase_breakdown(ev_a), _phase_breakdown(ev_b)
    for ph in sorted(set(ph_a) | set(ph_b)):
        ta, tb = ph_a.get(ph, (0.0, 0))[0], ph_b.get(ph, (0.0, 0))[0]
        mark = "" if ta == 0 else f" ({(tb - ta) / ta * 100:+.1f}%)"
        print(f"   phase {ph:24s} {ta:9.3f}s -> {tb:9.3f}s{mark}", file=out)
    ga, gb = _final_gauges(ev_a), _final_gauges(ev_b)
    for name in sorted(set(ga) | set(gb)):
        va, vb = ga.get(name), gb.get(name)
        if va is not None and vb is not None and va != vb:
            print(f"   gauge {name:24s} {va:.6g} -> {vb:.6g}", file=out)
    na, nb = len(_anomalies(ev_a)), len(_anomalies(ev_b))
    print(f"   anomalies: {na} -> {nb}", file=out)
    return EXIT_ANOMALIES if (na or nb) else EXIT_CLEAN


def main(argv=None, out=None) -> int:
    out = out or sys.stdout
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.inspect",
        description="triage a telemetry run dir / flight dump (§16)")
    ap.add_argument("run", nargs="?", default=None,
                    help="telemetry dir (or JSONL file) to inspect")
    ap.add_argument("--flight", default=None,
                    help="flight-recorder dump dir to render")
    ap.add_argument("--diff", nargs=2, metavar=("RUN_A", "RUN_B"),
                    default=None, help="compare two runs")
    ap.add_argument("--validate", default=None, metavar="RUN",
                    help="schema-validate only (exit 0/2)")
    args = ap.parse_args(argv)

    if args.validate is not None:
        events, n_err = _load(args.validate, out)
        ok = n_err == 0
        print(f"{'VALID' if ok else 'INVALID'}: {len(events)} events, "
              f"{n_err} schema error(s)", file=out)
        return EXIT_CLEAN if ok else EXIT_SCHEMA

    if args.diff is not None:
        return _render_diff(args.diff[0], args.diff[1], out)

    code = EXIT_CLEAN
    if args.run is not None:
        events, n_err = _load(args.run, out)
        if n_err:
            return EXIT_SCHEMA
        _render_run(args.run, events, out)
        if _anomalies(events):
            code = EXIT_ANOMALIES
    if args.flight is not None:
        fcode = _render_flight(args.flight, out)
        code = max(code, fcode)
    if args.run is None and args.flight is None:
        ap.print_usage(out)
        return EXIT_SCHEMA
    return code


if __name__ == "__main__":
    raise SystemExit(main())
