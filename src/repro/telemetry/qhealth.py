"""Quantization-health probes (DESIGN.md §14).

The paper's central risk is *silent* quantization failure: saturated
absmax blocks, dead codebook regions, EMA dynamics drifting outside the
dynamic qmap's precise range (the 4-bit ``r`` failure mode, DESIGN.md §9).
:class:`QHealthProbe` measures all of it online, from state already on
device, on the host's probe schedule (``OptimConfig.telemetry_every``) —
never inside the jitted train step, so the step stays bit-identical with
probing on or off and the only host sync is at the scheduled step.

Per quantized segment (every ``QuantSegment`` of the pooled
:class:`~repro.core.optim.base.QuantArena`, and every per-leaf
:class:`~repro.core.optim.base.Quant8Leaf` — muon matrix leaves ride
per-leaf inside the pooled layout) and per state slot (``m``/``r``):

  * ``saturation_fraction`` — fraction of the segment's live blocks with
    at least one code on the codebook edge (|q| == max|q|): the block's
    max landed on the format's last level, so growth is being clipped.
  * ``edge_code_fraction`` — the same signal at element granularity.
  * ``util_hist`` — codebook-utilization histogram over the segment's
    codes (``2^bits`` bins: 256 at 8-bit, 16 at 4-bit); sub-byte
    ``PackedCodes`` unpack through the lowbit path on device first, then
    the codes are fetched and binned host-side with ``np.bincount`` (an
    XLA scatter would cost more on CPU than the train step; the counts
    are exact integers either way).  ``util_fraction`` = fraction of
    levels with nonzero count (dead regions show up as util < 1).
  * ``absmax_mean`` + ``absmax_drift`` — mean per-block absmax and its
    ratio to a host-side EMA baseline (decay ``ema_decay``): dynamic-range
    drift over training, the SOLO divergence precursor.
  * ``rms_error`` — sampled quantize→dequantize round-trip RMS (relative)
    of the leaf's f32 master in the slot-m format: the measured
    representation error the ROADMAP's adaptive-format direction
    (STQuant-style bitwidth/block-size choice) consumes as input.

Padding is masked throughout: elements past a segment's logical ``n``
(block tail + ``shard_multiple`` rows) are excluded from every histogram
and fraction, so zero-padding can't fake a healthy zero-code population.

Partition-awareness: under a ZeRO-1/2 mesh the arena arrays are pinned
fully-replicated via ``rules.replicate_for_scales`` before the probe's
reductions — the §12 mechanism that compiles a global reduction as the
single-device oracle's, keeping probe results identical on 1- and
N-device meshes (the f32 summation order never depends on placement).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockwise
from repro.core.lowbit import unpack_codes, unwrap_codes
from repro.core.optim.base import (Full32Leaf, Pool32Leaf, PooledQuantLeaf,
                                   Quant8Leaf, path_str)

DEFAULT_SAMPLE_BLOCKS = 32


def _segment_stats(codes, qmap, absmax, segments):
    """Per-segment health reductions over unpacked int codes (nb, B).

    ``segments`` is a static tuple of ``(offset, n_blocks, n)``; returns
    (sat (S,), edge_frac (S,), absmax_mean (S,)).  Padding elements (past
    each segment's logical n) are masked out of every reduction; blocks
    past the last live one (shard_multiple padding) are excluded from the
    block-level fractions.  The codebook-utilization histogram is NOT
    computed here: an XLA scatter over the arena costs more on CPU than
    the train step itself, so the caller fetches the unpacked codes and
    bins them host-side with ``np.bincount`` (exact integer counts either
    way — see ``_segment_hists``)."""
    bsz = codes.shape[1]
    q = jnp.abs(qmap)[codes]                    # |dequant value| per code
    edge = jnp.max(jnp.abs(qmap))
    is_edge = q >= edge                         # exact: same-codebook lookup
    sats, fracs, ameans = [], [], []
    for off, nb, n in segments:
        nvb = max(min(-(-n // bsz), nb), 1)     # live blocks (static)
        e = jax.lax.slice_in_dim(is_edge, off, off + nvb)
        am = jax.lax.slice_in_dim(absmax, off, off + nvb)
        valid = (jnp.arange(nvb * bsz).reshape(nvb, bsz) < n)
        n_valid = jnp.maximum(jnp.sum(valid), 1)
        blk_edge = jnp.any(e & valid, axis=1)
        sats.append(jnp.sum(blk_edge) / nvb)
        fracs.append(jnp.sum(e & valid) / n_valid)
        ameans.append(jnp.mean(am))
    return jnp.stack(sats), jnp.stack(fracs), jnp.stack(ameans)


def _segment_hists(codes, segments, n_bins):
    """Host-side per-segment codebook-utilization histograms over unpacked
    uint8 codes (numpy array, (nb, B)).  Exact counts, padding masked —
    identical to a ``jnp.bincount`` with validity weights, at C speed."""
    bsz = codes.shape[1]
    hists = []
    for off, nb, n in segments:
        nvb = max(min(-(-n // bsz), nb), 1)
        c = codes[off:off + nvb].reshape(-1)
        valid = np.arange(nvb * bsz) < n
        h = np.bincount(c[valid], minlength=n_bins).astype(np.int64)
        hists.append(h[:n_bins])
    return np.stack(hists)


def _roundtrip_rms(blocks, qmap):
    """Relative RMS error of one quantize→dequantize round trip of f32
    blocks in the codebook's format (the online analogue of
    bench_qerror's offline measurement)."""
    codes, absmax = blockwise.quantize_blocks(blocks, qmap)
    deq = blockwise.dequantize_blocks(codes, absmax, qmap)
    num = jnp.sqrt(jnp.mean(jnp.square(blocks - deq)))
    den = jnp.sqrt(jnp.mean(jnp.square(blocks)))
    return num / (den + 1e-12)


class QHealthProbe:
    """Scheduled quantization-health probe over one optimizer's state.

    One instance per run (it owns the host-side absmax EMA baselines and
    the jitted probe executables).  ``probe(state, step)`` returns a list
    of "qhealth" event dicts ready for the telemetry sinks; the only host
    sync is fetching the probe results themselves.
    """

    def __init__(self, opt, mesh=None,
                 sample_blocks: int = DEFAULT_SAMPLE_BLOCKS,
                 ema_decay: float = 0.9):
        self.opt = opt
        self.mesh = mesh
        self.sample_blocks = int(sample_blocks)
        self.ema_decay = float(ema_decay)
        self._ema: Dict[tuple, float] = {}
        # Codebooks per slot from the optimizer's code formats (the probe
        # must judge codes against the exact map that produced them).
        self._qmaps = {"m": opt._qmap1, "r": opt._qmap2}
        self._bits = dict(zip(("m", "r"), opt.cfg.state_bits_pair))

        mesh_local = mesh

        @functools.partial(jax.jit, static_argnames=("bits", "segments"))
        def stats(codes_raw, absmax, qmap, *, bits, segments):
            if mesh_local is not None:
                from repro.sharding import rules
                codes_raw, absmax = rules.replicate_for_scales(
                    mesh_local, (codes_raw, absmax))
            codes = unpack_codes(codes_raw, bits).astype(jnp.uint8)
            return (_segment_stats(codes.astype(jnp.int32), qmap, absmax,
                                   segments), codes)

        self._stats = stats

        # All segments' round-trip RMS in ONE dispatch: a probe that issued
        # one tiny jitted call per segment would cost more in dispatch
        # overhead than the train step itself (the 1.05x overhead gate in
        # bench_telemetry_overhead pins this).
        @jax.jit
        def rms_many(blocks_tuple, qmap):
            return jnp.stack([_roundtrip_rms(b, qmap)
                              for b in blocks_tuple])

        self._rms_many = rms_many

    # ----------------------------------------------------------- internals
    def _drift(self, key: tuple, mean: float) -> float:
        """Current/EMA absmax ratio; the EMA updates after the read, so the
        first probe reports drift 1.0 and later probes measure movement
        against the trailing baseline."""
        ema = self._ema.get(key)
        drift = 1.0 if not ema else mean / ema
        d = self.ema_decay
        self._ema[key] = mean if ema is None else d * ema + (1 - d) * mean
        return drift

    def _slot_events(self, target, slot, codes, absmax, segs, step,
                     masters=None):
        """qhealth events for one state slot of one arena/leaf.  ``segs``
        is ((path, offset, n_blocks, n), ...); ``masters`` optionally maps
        path -> f32 blocks for the round-trip RMS sample."""
        qmap = self._qmaps[slot]
        bits = self._bits[slot]
        raw, rbits, _ = unwrap_codes(codes)
        bits = rbits if rbits is not None else bits
        n_bins = int(qmap.shape[-1])
        static = tuple((off, nb, n) for _, off, nb, n in segs)
        # one device round-trip for this slot's stats + unpacked codes
        (sat, frac, amean), codes_u8 = jax.device_get(self._stats(
            raw, absmax, qmap, bits=bits, segments=static))
        hist = _segment_hists(codes_u8, static, n_bins)
        rms = {}
        if masters is not None and slot == "m":
            paths = [p for p, _, _, _ in segs if p in masters]
            if paths:
                blocks = tuple(masters[p][:self.sample_blocks]
                               for p in paths)
                vals = np.asarray(self._rms_many(blocks, qmap))
                rms = {p: (float(v), int(b.shape[0]))
                       for p, v, b in zip(paths, vals, blocks)}
        events = []
        for i, (path, off, nb, n) in enumerate(segs):
            mean = float(amean[i])
            ev = {
                "kind": "qhealth", "step": int(step),
                "target": target, "segment": path, "slot": slot,
                "bits": int(bits), "n_bins": n_bins,
                "n_blocks": int(nb),
                "saturation_fraction": float(sat[i]),
                "edge_code_fraction": float(frac[i]),
                "util_hist": hist[i].tolist(),
                "util_fraction": float(np.mean(hist[i] > 0)),
                "absmax_mean": mean,
                "absmax_drift": self._drift((target, path, slot), mean),
            }
            if path in rms:
                ev["rms_error"], ev["rms_sample_blocks"] = rms[path]
            events.append(ev)
        return events

    def _master_blocks(self, leaf) -> Optional[Any]:
        """Leaf master as f32 blocks, if the leaf carries one."""
        if isinstance(leaf, Quant8Leaf):
            return leaf.master
        if isinstance(leaf, PooledQuantLeaf):
            bsz = self.opt.cfg.block_size
            flat = leaf.master.reshape(-1).astype(jnp.float32)
            pad = leaf.n_blocks * bsz - flat.shape[0]
            return jnp.pad(flat, (0, pad)).reshape(leaf.n_blocks, bsz)
        return None

    # -------------------------------------------------------------- probe
    def probe(self, state, step: int = -1) -> List[dict]:
        """Health events for every quantized segment of ``state`` (a
        Block8bitOptimizer ``OptState``): the pooled arena's segments plus
        every per-leaf Quant8Leaf (muon matrix leaves / unpooled layout).
        """
        events: List[dict] = []
        leaves = jax.tree_util.tree_flatten_with_path(
            state.leaves,
            is_leaf=lambda x: isinstance(
                x, (Quant8Leaf, Full32Leaf, PooledQuantLeaf, Pool32Leaf))
        )[0]

        arena = getattr(state, "arena", None)
        if arena is not None:
            masters = {}
            for path, leaf in leaves:
                if isinstance(leaf, PooledQuantLeaf):
                    blocks = self._master_blocks(leaf)
                    if blocks is not None:
                        masters[path_str(path)] = blocks
            segs = tuple((s.path, s.offset, s.n_blocks, s.n)
                         for s in arena.segments)
            if segs:
                events += self._slot_events("arena", "m", arena.codes_m,
                                            arena.absmax_m, segs, step,
                                            masters)
                if arena.codes_r is not None:
                    events += self._slot_events("arena", "r", arena.codes_r,
                                                arena.absmax_r, segs, step)

        for path, leaf in leaves:
            if not isinstance(leaf, Quant8Leaf):
                continue
            p = path_str(path)
            segs = ((p, 0, int(leaf.absmax_m.shape[0]), leaf.n),)
            masters = {p: self._master_blocks(leaf)}
            events += self._slot_events("leaf", "m", leaf.codes_m,
                                        leaf.absmax_m, segs, step, masters)
            if leaf.codes_r is not None:
                events += self._slot_events("leaf", "r", leaf.codes_r,
                                            leaf.absmax_r, segs, step)
        return events
