"""Typed metric registry (DESIGN.md §14).

Metrics are *named and typed* — a name is registered once as a counter,
gauge or histogram, and re-registering it as a different type is an error
(the failure mode of ad-hoc metric dicts: the same key meaning different
things in different call sites).  The registry is host-side state: values
are plain Python/numpy scalars, and emission to sinks happens explicitly
(``record_scalars`` per step, or ``flush`` for a point-in-time snapshot),
so nothing here ever touches a jitted computation.

    reg = MetricRegistry(step_offset_sink...)
    reg.add_sink(JsonlSink(path))
    reg.counter("serve/requests").inc()
    reg.gauge("train/loss").set(2.3)
    reg.histogram("qhealth/util", n_bins=256).observe_counts(counts)
    reg.flush(step=7)           # one "metric" event per registered metric

``record_scalars(step, mapping)`` is the train-loop adapter: every entry
of the step's metric dict becomes a gauge sample (created on first use),
emitted immediately — the existing ``train/loop.py`` metrics
(``loss``, ``pclip_scale``, ``opt_fused_dispatches``, ...) route through
it unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.errors import FormatError
from repro.telemetry.export import SCHEMA


def _scalar(v: Any) -> float:
    """Host float from a python/numpy/jax scalar (no-op for floats)."""
    return float(np.asarray(v))


class Counter:
    """Monotonically increasing count (requests, tokens, events)."""

    mtype = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> int:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(got {n})")
        self.value += int(n)
        return self.value


class Gauge:
    """Last-value metric (loss, bytes/param, saturation fraction)."""

    mtype = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: Any) -> float:
        self.value = _scalar(v)
        return self.value


class Histogram:
    """Binned counts (codebook utilization).  The repo's histograms arrive
    *pre-binned* (``jnp.bincount`` on device), so the API takes counts
    directly instead of streaming observations."""

    mtype = "histogram"

    def __init__(self, name: str, n_bins: int):
        self.name = name
        self.n_bins = int(n_bins)
        self.value = np.zeros((self.n_bins,), np.int64)

    def observe_counts(self, counts: Any) -> np.ndarray:
        c = np.asarray(counts, np.int64).reshape(-1)
        if c.shape[0] != self.n_bins:
            raise FormatError(f"histogram {self.name}: got {c.shape[0]} "
                              f"bins, expected {self.n_bins}")
        self.value = c
        return self.value


class MetricRegistry:
    """Named, typed metrics plus the sinks they emit to."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._sinks: list = []

    # ------------------------------------------------------------- metrics
    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.mtype}, not a "
                            f"{cls.mtype}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, n_bins: int) -> Histogram:
        h = self._get(name, Histogram, n_bins)
        if h.n_bins != int(n_bins):
            raise TypeError(f"histogram {name!r} has {h.n_bins} bins, "
                            f"not {n_bins}")
        return h

    def metrics(self) -> dict:
        """Snapshot {name: current value} (histograms as lists)."""
        out = {}
        for name, m in self._metrics.items():
            v = m.value
            out[name] = v.tolist() if isinstance(v, np.ndarray) else v
        return out

    def get(self, name: str):
        """Current value of ``name`` (None if never set/registered)."""
        m = self._metrics.get(name)
        if m is None:
            return None
        v = m.value
        return v.tolist() if isinstance(v, np.ndarray) else v

    # --------------------------------------------------------------- sinks
    def add_sink(self, sink) -> None:
        self._sinks.append(sink)

    def emit_event(self, event: dict) -> None:
        """Stamp the schema version and write to every sink."""
        event = dict(event)
        event.setdefault("schema", SCHEMA)
        event.setdefault("step", -1)
        for s in self._sinks:
            s.write(event)

    def _metric_event(self, m, step: int) -> dict:
        v = m.value
        if isinstance(v, np.ndarray):
            v = v.tolist()
        ev = {"kind": "metric", "step": int(step), "name": m.name,
              "type": m.mtype, "value": v}
        if isinstance(m, Histogram):
            ev["n_bins"] = m.n_bins
        return ev

    def flush(self, step: int = -1) -> None:
        """Emit one "metric" event per registered metric (current values)
        and flush the sinks."""
        for m in self._metrics.values():
            if m.value is None:
                continue
            self.emit_event(self._metric_event(m, step))
        for s in self._sinks:
            s.flush()

    def record_scalars(self, step: int, mapping: dict,
                       prefix: str = "") -> None:
        """Route one step's scalar metric dict through gauges and emit
        each immediately — the ``train/loop.py`` metrics adapter.  Values
        may be python/numpy/jax scalars (converted on the host; the train
        loop already syncs them for logging, so this adds no new device
        round-trip)."""
        mapping = dict(mapping)
        try:                      # one bulk transfer instead of one per
            import jax            # metric; registry itself stays jax-free
            mapping = jax.device_get(mapping)
        except ImportError:
            pass
        for name, v in mapping.items():
            a = np.asarray(v)
            if a.ndim != 0:
                continue            # scalar metrics only
            g = self.gauge(prefix + name)
            g.set(a)
            self.emit_event(self._metric_event(g, step))
        for s in self._sinks:
            s.flush()

    def close(self) -> None:
        for s in self._sinks:
            s.close()
