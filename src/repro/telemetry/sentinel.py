"""Host-side anomaly detectors over the in-graph numerics sentinel
(DESIGN.md §16).

The *device* half of the sentinel lives in the kernels: with
``OptimConfig.sentinel=True`` every fused-update dispatch emits a compact
``(n_blocks, N_HEALTH)`` count tile — nonfinite grad/update elements,
nonfinite or overflowing absmax, requant edge-code saturation — reduced
in VMEM alongside the update itself (no extra HBM round-trip) and summed
into one ``(N_HEALTH,)`` vector per step that ``train/loop.py`` surfaces
as ``sent_*`` metrics.  The *host* half is :class:`AnomalyDetector`: a
cheap per-step scan of those metrics (plus loss/gnorm trends and qhealth
probe output) that escalates threshold crossings into versioned
``anomaly`` JSONL events (``export.EVENT_FIELDS["anomaly"]``).

Detectors and their reasons:

  * ``nonfinite_loss``   (fatal) — loss is NaN/inf; the step is garbage.
  * ``sentinel_nonfinite`` (fatal) — the kernels counted nonfinite grad
    or update elements; names the first offending slot in ``detail``.
  * ``absmax_overflow``  (error) — a block absmax crossed the f32-safety
    threshold (``ABSMAX_OVERFLOW_THRESHOLD``); dequant will soon inf.
  * ``loss_spike``       (warn/error) — loss z-score over a trailing
    window crossed ``loss_z``; zero-variance windows score 0 (same
    convention as ``tracing.StepTimer``).
  * ``gnorm_spike``      (warn/error) — grad norm jumped vs the trailing
    median.  Cross-checked against percentile clipping: when the step's
    ``pclip_scale`` shows the clip already engaged (< 1), the spike was
    absorbed and the event stays a warning.
  * ``qhealth_saturation`` (warn/error) — a probe segment's element-level
    ``edge_code_fraction`` or ``absmax_drift`` crossed its threshold.
    Block-level ``saturation_fraction`` is deliberately NOT escalated:
    under absmax scaling every nonzero block's max element lands on the
    top code by construction, so it sits near 1.0 on healthy runs and
    carries no signal.  The element fraction is ~1/block_size when
    healthy and approaches 1.0 only when the whole block is clipping.

Everything here is plain Python/NumPy over host scalars — the detector
never touches device buffers and costs nothing when not constructed.
"""
from __future__ import annotations

import collections
from typing import List, Optional

import numpy as np

from repro.kernels.fused_update import (ABSMAX_OVERFLOW_THRESHOLD,  # noqa: F401
                                        HEALTH_SLOTS, N_HEALTH)
from repro.telemetry.export import ANOMALY_SEVERITIES, SCHEMA  # noqa: F401

# sentinel metric keys as they appear in the step metrics dict
_NONFINITE_SLOTS = tuple(s for s in HEALTH_SLOTS if s.startswith("nonfinite"))
_OVERFLOW_SLOTS = tuple(s for s in HEALTH_SLOTS
                        if s.startswith("absmax_overflow"))
_EDGE_SLOTS = tuple(s for s in HEALTH_SLOTS if s.startswith("edge_hits"))


def anomaly_event(step: int, reason: str, severity: str, value: float,
                  **extra) -> dict:
    """One schema-valid ``anomaly`` event."""
    if severity not in ANOMALY_SEVERITIES:
        raise ValueError(f"severity {severity!r} not in {ANOMALY_SEVERITIES}")
    ev = {"kind": "anomaly", "schema": SCHEMA, "step": int(step),
          "reason": reason, "severity": severity, "value": float(value)}
    ev.update(extra)
    return ev


class AnomalyDetector:
    """Scans per-step metrics for numeric-health escalations.

        det = AnomalyDetector()
        for ev in det.observe_step(step, metrics):
            reg.emit_event(ev)

    ``metrics`` is the train-step output dict (host scalars or 0-d
    arrays); the detector reads ``loss``, ``grad_norm``, optional
    ``pclip_scale`` and the ``sent_*`` sentinel counters when present.
    State is a pair of trailing windows (loss, gnorm) — O(window) memory.
    """

    def __init__(self, window: int = 20, loss_z: float = 6.0,
                 gnorm_factor: float = 10.0,
                 qhealth_edge: float = 0.25,
                 qhealth_drift: float = 10.0):
        self.window = int(window)
        self.loss_z = float(loss_z)
        self.gnorm_factor = float(gnorm_factor)
        self.qhealth_edge = float(qhealth_edge)
        self.qhealth_drift = float(qhealth_drift)
        self._loss = collections.deque(maxlen=self.window)
        self._gnorm = collections.deque(maxlen=self.window)
        self.anomalies: List[dict] = []

    def _emit(self, ev: dict) -> dict:
        self.anomalies.append(ev)
        return ev

    # ------------------------------------------------------------- steps
    def observe_step(self, step: int, metrics: dict) -> List[dict]:
        """Anomaly events for one step's metrics (possibly empty)."""
        out: List[dict] = []
        loss = float(metrics.get("loss", 0.0))
        gnorm = float(metrics.get("grad_norm", 0.0))
        pclip = metrics.get("pclip_scale")

        if not np.isfinite(loss):
            out.append(self._emit(anomaly_event(
                step, "nonfinite_loss", "fatal", loss,
                detail="loss is not finite; the step output is unusable")))

        # kernel-counted nonfinite elements: any count > 0 is fatal —
        # the quantized state now stores garbage for those blocks.
        nf_total, nf_first = 0.0, None
        for slot in _NONFINITE_SLOTS:
            v = float(metrics.get(f"sent_{slot}", 0.0))
            if v > 0 and nf_first is None:
                nf_first = slot
            nf_total += v
        if nf_total > 0:
            out.append(self._emit(anomaly_event(
                step, "sentinel_nonfinite", "fatal", nf_total,
                detail=f"first offending slot: {nf_first}")))

        ov_total = sum(float(metrics.get(f"sent_{s}", 0.0))
                       for s in _OVERFLOW_SLOTS)
        if ov_total > 0:
            out.append(self._emit(anomaly_event(
                step, "absmax_overflow", "error", ov_total,
                detail=f"block absmax > {ABSMAX_OVERFLOW_THRESHOLD:g}")))

        # trend detectors need a full window BEFORE this step
        if np.isfinite(loss) and len(self._loss) >= self.window:
            w = np.array(self._loss)
            std = float(w.std())
            z = (loss - float(w.mean())) / std if std > 0.0 else 0.0
            if z > self.loss_z:
                sev = "error" if z > 2 * self.loss_z else "warn"
                out.append(self._emit(anomaly_event(
                    step, "loss_spike", sev, z,
                    detail=f"loss {loss:.4g} vs trailing mean "
                           f"{float(w.mean()):.4g}")))
        if np.isfinite(gnorm) and len(self._gnorm) >= self.window:
            med = float(np.median(np.array(self._gnorm)))
            if med > 0 and gnorm > self.gnorm_factor * med:
                # percentile clip already engaged => the optimizer
                # absorbed the spike; keep it a warning.
                clipped = pclip is not None and float(pclip) < 1.0
                out.append(self._emit(anomaly_event(
                    step, "gnorm_spike", "warn" if clipped else "error",
                    gnorm / med,
                    detail=f"gnorm {gnorm:.4g} vs trailing median "
                           f"{med:.4g}" + (" (pclip engaged)"
                                           if clipped else ""))))
        if np.isfinite(loss):
            self._loss.append(loss)
        if np.isfinite(gnorm):
            self._gnorm.append(gnorm)
        return out

    # ----------------------------------------------------------- qhealth
    def observe_qhealth(self, events: list) -> List[dict]:
        """Escalate qhealth probe events whose element-level edge-code
        fraction or absmax drift crossed the detector thresholds.

        Block-level ``saturation_fraction`` is read but never escalated
        (see module docstring: it is ~1.0 by construction when healthy).
        """
        out: List[dict] = []
        for ev in events:
            if not isinstance(ev, dict) or ev.get("kind") != "qhealth":
                continue
            step = int(ev.get("step", -1))
            tgt = f"{ev.get('target')}/{ev.get('segment')}/{ev.get('slot')}"
            edge = float(ev.get("edge_code_fraction", 0.0))
            if edge > self.qhealth_edge:
                sev = "error" if edge > 2 * self.qhealth_edge else "warn"
                out.append(self._emit(anomaly_event(
                    step, "qhealth_saturation", sev, edge,
                    detail=f"{tgt} edge_code_fraction")))
            drift = float(ev.get("absmax_drift", 1.0))
            if drift > self.qhealth_drift:
                out.append(self._emit(anomaly_event(
                    step, "qhealth_saturation", "warn", drift,
                    detail=f"{tgt} absmax_drift")))
        return out

    # ----------------------------------------------------------- summary
    def worst_severity(self) -> Optional[str]:
        """Highest severity seen so far (None if clean)."""
        seen = {ev["severity"] for ev in self.anomalies}
        for sev in reversed(ANOMALY_SEVERITIES):
            if sev in seen:
                return sev
        return None
