"""Flight recorder: a crash forensics ring + on-trigger dump (§16).

Black-box recorder for training runs: a host-side ring buffer keeps the
last K steps' compact metrics (loss, grad norm, sentinel counters, step
wall time — plain floats, no device buffers), and a one-deep snapshot
slot holds a host copy of the most recent *healthy* ``TrainState``.  On
an anomaly trigger — fatal detector event or nonfinite-loss crash — the
recorder dumps a forensic bundle:

    <dump_dir>/
      flight.json          # schema, trigger reason/step, metrics ring,
                           # anomaly timeline, config hash, git sha,
                           # telemetry JSONL tail
      state/step_NNNN/     # the last healthy TrainState in the ordinary
                           # checkpoint format (train/checkpoint.py):
                           # arena codes + absmax, masters, RNG key, step

The state bundle reuses the elastic checkpoint machinery verbatim, so a
dump restores exactly like any checkpoint — onto any mesh — and a run
resumed from it replays the step before the blow-up bit-exactly
(tests/test_sentinel.py pins this).  Because the train step donates its
input state, the snapshot is taken from the *output* state after each
healthy step (the donated input buffer is dead); an unhealthy step's
output is deliberately never snapshotted.

Everything is plain host Python: a run without a recorder constructs
nothing and pays nothing.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import subprocess
from typing import Any, Optional

import jax

from repro.train import checkpoint as _ckpt

FLIGHT_SCHEMA = "repro.flight.v1"


def _git_sha() -> str:
    """Current commit (best-effort; "unknown" outside a usable checkout)."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def config_hash(config: Any) -> str:
    """Stable content hash of a config object (repr-based: dataclass
    reprs list every field, so any hyperparameter change moves the hash)."""
    return hashlib.sha256(repr(config).encode()).hexdigest()[:16]


def _scalarize(metrics: dict) -> dict:
    """Host-float view of a step metrics dict (drops non-scalars)."""
    out = {}
    for k, v in metrics.items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            continue
    return out


class FlightRecorder:
    """Ring of recent step metrics + last-healthy-state snapshot.

        fr = FlightRecorder(ring=64)
        for i in range(steps):
            state, metrics = step_fn(state, batch)
            fr.record(i, metrics, wall_s=dt)
            if <healthy>:
                fr.snapshot(i, state)       # host copy of the NEW state
            else:
                fr.dump(out_dir, reason="nonfinite_loss", trigger_step=i)

    ``snapshot_every`` thins the device_get cost for long healthy runs
    (the snapshot then lags up to that many steps — still a valid resume
    point, just an earlier one).
    """

    def __init__(self, ring: int = 64, snapshot_every: int = 1):
        self.ring = int(ring)
        self.snapshot_every = max(1, int(snapshot_every))
        self._ring: collections.deque = collections.deque(maxlen=self.ring)
        self._snap_step: Optional[int] = None
        self._snap_state: Any = None
        self.anomalies: list = []

    # ------------------------------------------------------------ record
    def record(self, step: int, metrics: dict, **extra) -> None:
        """Append one step's compact metrics to the ring (host floats)."""
        row = {"step": int(step)}
        row.update(_scalarize(metrics))
        row.update(_scalarize(extra))
        self._ring.append(row)

    def snapshot(self, step: int, state: Any) -> None:
        """Retain a host copy of ``state`` as the last healthy resume
        point.  Call AFTER the step's health verdict, with the step's
        OUTPUT state (the donated input is dead)."""
        if step % self.snapshot_every:
            return
        self._snap_step = int(step)
        self._snap_state = jax.device_get(state)

    def note_anomaly(self, event: dict) -> None:
        self.anomalies.append(dict(event))

    @property
    def snapshot_step(self) -> Optional[int]:
        return self._snap_step

    # -------------------------------------------------------------- dump
    def dump(self, dump_dir: str, *, reason: str, trigger_step: int,
             config: Any = None, telemetry_path: Optional[str] = None,
             tail: int = 50) -> str:
        """Write the forensic bundle; returns ``dump_dir``.

        ``telemetry_path``: the run's telemetry JSONL — its last ``tail``
        events are embedded so the dump is self-contained even if the
        telemetry dir is lost."""
        os.makedirs(dump_dir, exist_ok=True)
        if self._snap_state is not None:
            _ckpt.save(os.path.join(dump_dir, "state"), self._snap_step,
                       self._snap_state)
        jsonl_tail: list = []
        if telemetry_path and os.path.exists(telemetry_path):
            with open(telemetry_path) as f:
                lines = [ln.strip() for ln in f if ln.strip()]
            for ln in lines[-int(tail):]:
                try:
                    jsonl_tail.append(json.loads(ln))
                except json.JSONDecodeError:
                    jsonl_tail.append({"unparsed": ln})
        manifest = {
            "schema": FLIGHT_SCHEMA,
            "reason": reason,
            "trigger_step": int(trigger_step),
            "snapshot_step": self._snap_step,
            "git_sha": _git_sha(),
            "config_hash": config_hash(config) if config is not None else None,
            "ring": list(self._ring),
            "anomalies": list(self.anomalies),
            "jsonl_tail": jsonl_tail,
        }
        with open(os.path.join(dump_dir, "flight.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        return dump_dir


def load_dump(dump_dir: str) -> dict:
    """The ``flight.json`` manifest of a dump (raises if absent/invalid)."""
    with open(os.path.join(dump_dir, "flight.json")) as f:
        manifest = json.load(f)
    if manifest.get("schema") != FLIGHT_SCHEMA:
        raise ValueError(f"{dump_dir}: schema {manifest.get('schema')!r}, "
                         f"want {FLIGHT_SCHEMA!r}")
    return manifest


def restore_state(dump_dir: str, template: Any,
                  shardings: Optional[Any] = None) -> tuple:
    """``(snapshot_step, state)`` from a dump's state bundle — the last
    healthy TrainState, restored elastically like any checkpoint."""
    manifest = load_dump(dump_dir)
    step = manifest.get("snapshot_step")
    if step is None:
        raise ValueError(f"{dump_dir}: dump carries no state snapshot")
    state = _ckpt.restore(os.path.join(dump_dir, "state"), step, template,
                          shardings)
    return int(step), state
