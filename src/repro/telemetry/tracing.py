"""Step-phase tracing + the shared step-timing helper (DESIGN.md §14).

Two distinct clocks live here:

**Trace-time phase annotations** — :func:`annotate` wraps each phase of
the optimizer step (blockwise quant/dequant, per-bucket ``fused_update``
dispatches, Newton–Schulz gram/apply passes, reduce-scatter, deferred
all-gather).  Annotations are OFF by default and the wrapper is then a
literal no-op (``yield`` and nothing else), so the default jitted
computation — and its StableHLO text — is byte-identical to a build
without telemetry (the zero-overhead guard in tests/test_telemetry.py
pins this).  When enabled via :func:`set_phase_tracing`, each ``annotate``
block:

  * enters ``jax.named_scope`` (names the ops for XLA/HLO dumps) and
    ``jax.profiler.TraceAnnotation`` (names the region for the profiler
    timeline), and
  * records a *trace event*: ``(phase, fused dispatches inside, trace
    wall-clock)``.  Under jit this fires at trace time, so one compiled
    step yields one dispatch-accounted phase list — exactly the launches
    baked into the executable (the same convention as
    ``ops.fused_update_count``; DESIGN.md §10).

**Host wall-clock** — :class:`StepTimer` is the single definition of
``ms/step`` and ``compile_s``: the first executed step pays jit tracing +
XLA compilation and is reported apart (``compile_s``), steady-state steps
accumulate into ``ms/step``, and a trailing-window z-score flags
stragglers.  ``train/loop.py``-era call sites (``launch/train.py``,
quickstart, benchmarks) all use this one helper instead of inlining the
split.  :func:`host_phase` times host-side phases (probe runs, eval) into
"phase" events for the JSONL timeline.
"""
from __future__ import annotations

import contextlib
import time
from typing import List, Optional

import numpy as np

_PHASE_TRACING = [False]
_TRACE_EVENTS: List[dict] = []
_PHASE_EVENTS: List[dict] = []


def set_phase_tracing(enabled: bool) -> None:
    """Turn trace-time phase annotation on/off (process-wide, default off).
    Flip BEFORE tracing/jitting the step: the flag is read at trace time,
    so already-compiled executables keep whatever the flag was when they
    were traced."""
    _PHASE_TRACING[0] = bool(enabled)


def phase_tracing_enabled() -> bool:
    return _PHASE_TRACING[0]


@contextlib.contextmanager
def phase_tracing(enabled: bool = True):
    """Scoped :func:`set_phase_tracing` (restores the prior flag)."""
    prev = _PHASE_TRACING[0]
    _PHASE_TRACING[0] = bool(enabled)
    try:
        yield
    finally:
        _PHASE_TRACING[0] = prev


def trace_events() -> list:
    """Trace events recorded since :func:`reset_trace_events` — one dict
    ``{"phase", "dispatches", "trace_s"}`` per annotated region entered
    while tracing.  Nested regions appear as separate entries (outer spans
    include inner dispatches)."""
    return list(_TRACE_EVENTS)


def reset_trace_events() -> None:
    _TRACE_EVENTS.clear()


@contextlib.contextmanager
def annotate(phase: str):
    """Name one step phase.  A no-op unless phase tracing is enabled —
    keeping the default trace, and therefore the compiled step, untouched.
    Enabled, it enters ``jax.named_scope``/``TraceAnnotation`` and records
    a trace event with the number of fused_update dispatches issued inside
    the region (trace-time accounting, DESIGN.md §10)."""
    if not _PHASE_TRACING[0]:
        yield
        return
    import jax
    from repro.kernels import ops  # lazy: ops imports this module
    n0 = ops.fused_update_count()
    t0 = time.perf_counter()
    with contextlib.ExitStack() as stack:
        stack.enter_context(jax.named_scope(f"tel.{phase}"))
        try:
            stack.enter_context(jax.profiler.TraceAnnotation(f"tel.{phase}"))
        except Exception:
            pass  # profiler backend unavailable; named_scope still applies
        yield
    _TRACE_EVENTS.append({
        "phase": phase,
        "dispatches": ops.fused_update_count() - n0,
        "trace_s": time.perf_counter() - t0,
    })


def trace_event_dict(step: int) -> dict:
    """One "trace" JSONL event summarizing the recorded trace events (the
    per-phase dispatch accounting of the step compiled at ``step``)."""
    return {"kind": "trace", "step": int(step),
            "phases": [dict(e) for e in _TRACE_EVENTS]}


# ------------------------------------------------------ host-side timeline
@contextlib.contextmanager
def host_phase(phase: str, step: int = -1):
    """Record host wall-clock for one phase into the pending "phase" event
    list (drained by :func:`drain_phase_events`)."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        _PHASE_EVENTS.append({"kind": "phase", "step": int(step),
                              "phase": phase,
                              "wall_s": time.perf_counter() - t0})


def drain_phase_events() -> list:
    evs, _PHASE_EVENTS[:] = list(_PHASE_EVENTS), []
    return evs


class StepTimer:
    """The single ms/step + compile_s definition (PR-6 convention).

    The first recorded step is the compile step: its wall time is stored
    as ``compile_s`` and EXCLUDED from the steady-state series, because it
    pays jit tracing + XLA compilation and would otherwise skew ms/step
    and the straggler z-scores.  Subsequent steps append to ``times``.

        timer = StepTimer()
        for i in range(steps):
            with timer.step():
                ... run one step, block on the result ...
            if timer.straggler_z is not None and timer.straggler_z > 4: ...
    """

    def __init__(self, window: int = 20, z_threshold: float = 4.0):
        self.window = int(window)
        self.z_threshold = float(z_threshold)
        self.compile_s: Optional[float] = None
        self.times: List[float] = []
        self.last_dt: Optional[float] = None
        self.straggler_z: Optional[float] = None

    @contextlib.contextmanager
    def step(self):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(time.perf_counter() - t0)

    def record(self, dt: float) -> float:
        """Record one step's wall time; returns it.  First call lands in
        ``compile_s``, later calls in the steady series."""
        dt = float(dt)
        self.last_dt = dt
        self.straggler_z = None
        if self.compile_s is None:
            self.compile_s = dt
            return dt
        # straggler detection: z-score over the trailing window,
        # computed against the window BEFORE this step
        if len(self.times) > self.window:
            w = np.array(self.times[-self.window:-1])
            std = float(w.std())
            # A zero-variance window has no scale to judge deviation
            # against — the epsilon-divide made any jump look like a
            # billions-sigma straggler (or NaN).  Report 0.0: "no
            # evidence", not "infinite evidence".
            self.straggler_z = (float((dt - w.mean()) / std)
                                if std > 0.0 else 0.0)
        self.times.append(dt)
        return dt

    @property
    def is_straggler(self) -> bool:
        return (self.straggler_z is not None
                and self.straggler_z > self.z_threshold)

    def steady_ms(self) -> float:
        """Mean steady-state step time in ms (nan before the 2nd step)."""
        return 1e3 * float(np.mean(self.times)) if self.times else float("nan")

    def summary(self) -> dict:
        return {"compile_s": self.compile_s, "steady_ms": self.steady_ms(),
                "n_steps": len(self.times) + (self.compile_s is not None)}
