"""Observability for the 8-bit stack (DESIGN.md §14).

Three pillars:
  * :mod:`repro.telemetry.qhealth` — scheduled quantization-health probes
    (saturation, codebook utilization, absmax drift, round-trip RMS);
  * :mod:`repro.telemetry.tracing` — step-phase annotations, trace-time
    dispatch accounting, and the shared ``StepTimer`` (ms/step +
    compile_s single definition);
  * :mod:`repro.telemetry.registry` / :mod:`repro.telemetry.export` —
    typed metrics (counter/gauge/histogram) and the JSONL / in-memory /
    BENCH-trajectory sinks behind them.

All of it is off by default and adds nothing to the jitted step when off
(pinned by tests/test_telemetry.py's zero-overhead guard).
"""
from repro.telemetry.export import (BenchJsonSink, InMemorySink, JsonlSink,
                                    SCHEMA, append_json_trajectory,
                                    validate_event, validate_jsonl)
from repro.telemetry.qhealth import QHealthProbe
from repro.telemetry.registry import MetricRegistry
from repro.telemetry.tracing import (StepTimer, annotate, drain_phase_events,
                                     host_phase, phase_tracing,
                                     phase_tracing_enabled,
                                     reset_trace_events, set_phase_tracing,
                                     trace_event_dict, trace_events)

__all__ = [
    "SCHEMA", "BenchJsonSink", "InMemorySink", "JsonlSink",
    "append_json_trajectory", "validate_event", "validate_jsonl",
    "QHealthProbe", "MetricRegistry", "StepTimer", "annotate",
    "drain_phase_events", "host_phase", "phase_tracing",
    "phase_tracing_enabled", "reset_trace_events", "set_phase_tracing",
    "trace_event_dict", "trace_events",
]
