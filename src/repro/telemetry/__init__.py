"""Observability for the 8-bit stack (DESIGN.md §14).

Three pillars:
  * :mod:`repro.telemetry.qhealth` — scheduled quantization-health probes
    (saturation, codebook utilization, absmax drift, round-trip RMS);
  * :mod:`repro.telemetry.tracing` — step-phase annotations, trace-time
    dispatch accounting, and the shared ``StepTimer`` (ms/step +
    compile_s single definition);
  * :mod:`repro.telemetry.registry` / :mod:`repro.telemetry.export` —
    typed metrics (counter/gauge/histogram) and the JSONL / in-memory /
    BENCH-trajectory sinks behind them;
  * :mod:`repro.telemetry.sentinel` / :mod:`repro.telemetry.flight` —
    the numerics sentinel's host-side anomaly detectors and the
    flight-recorder crash-forensics dump (DESIGN.md §16), inspected via
    ``python -m repro.telemetry.inspect``.

All of it is off by default and adds nothing to the jitted step when off
(pinned by tests/test_telemetry.py's zero-overhead guard and the
``train_step.sentinel_invariant`` compile contract).
"""
from repro.telemetry.export import (ANOMALY_SEVERITIES, BenchJsonSink,
                                    InMemorySink, JsonlSink,
                                    SCHEMA, append_json_trajectory,
                                    validate_event, validate_jsonl)
from repro.telemetry.flight import (FLIGHT_SCHEMA, FlightRecorder,
                                    config_hash, load_dump, restore_state)
from repro.telemetry.qhealth import QHealthProbe
from repro.telemetry.sentinel import (AnomalyDetector, HEALTH_SLOTS,
                                      anomaly_event)
from repro.telemetry.registry import MetricRegistry
from repro.telemetry.tracing import (StepTimer, annotate, drain_phase_events,
                                     host_phase, phase_tracing,
                                     phase_tracing_enabled,
                                     reset_trace_events, set_phase_tracing,
                                     trace_event_dict, trace_events)

__all__ = [
    "SCHEMA", "BenchJsonSink", "InMemorySink", "JsonlSink",
    "append_json_trajectory", "validate_event", "validate_jsonl",
    "ANOMALY_SEVERITIES", "AnomalyDetector", "HEALTH_SLOTS",
    "anomaly_event", "FLIGHT_SCHEMA", "FlightRecorder", "config_hash",
    "load_dump", "restore_state",
    "QHealthProbe", "MetricRegistry", "StepTimer", "annotate",
    "drain_phase_events", "host_phase", "phase_tracing",
    "phase_tracing_enabled", "reset_trace_events", "set_phase_tracing",
    "trace_event_dict", "trace_events",
]
