"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which makes
it useless for scanned-layer models (layers scan, microbatch scan, KV-chunk
scan, recurrent time scans).  This module parses the post-SPMD optimized HLO
text and accumulates:

  * flops       — 2 * prod(result dims) * prod(contracting dims) per ``dot``
                  (matmul flops — the standard MFU accounting; elementwise
                  flops are not counted, noted in EXPERIMENTS.md),
  * bytes       — Σ (result + operand bytes) over *top-level* instructions of
                  executable computations (entry / while bodies / conditional
                  branches).  Optimized-HLO top-level ops are the fusion
                  units, i.e. exactly the HBM traffic quanta.  No-traffic ops
                  (tuple/gte/parameter/constant/bitcast) are skipped,
  * collectives — per-kind link-bytes with ring-algorithm factors:
                  all-gather/reduce-scatter: size*(g-1)/g, all-reduce:
                  2*size*(g-1)/g, all-to-all: size*(g-1)/g,
                  collective-permute: size,

with every quantity multiplied by the product of enclosing loop trip counts
(``backend_config={"known_trip_count":{"n":...}}``; fallback: trip 1 +
a warning flag in the result).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict

# One shared dtype-size table for every HLO-text consumer (DESIGN.md §15).
from repro.analysis.dtypes import DTYPE_BYTES as _DTYPE_BYTES

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{")
_TRIP_RE = re.compile(r'known_trip_count[\\\":{ ]+n[\\\":]+\s*\\?\"?(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_NO_TRAFFIC_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "iota", "rng-bit-generator",
}


def _opcode(rhs: str) -> str:
    """Opcode of an instruction right-hand side (handles tuple-shape
    results whose parentheses precede the opcode)."""
    s = rhs
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    s = s[i + 1:]
                    break
    head = s.split("(", 1)[0].strip()
    return head.split()[-1] if head else ""

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _result_of(rhs: str) -> str:
    """The result shape portion of an instruction right-hand side."""
    if rhs.startswith("("):
        return rhs.split(") ", 1)[0] + ")"
    return rhs.split(" ", 1)[0]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs: list[tuple[str, str]] = []    # (result_name, full_rhs)
        self.shapes: dict[str, str] = {}           # name -> result shape str


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            # parameters: "argname: shape, argname2: shape2"
            for part in hdr.group(2).split(", "):
                if ":" in part:
                    pname, pshape = part.split(":", 1)
                    cur.shapes[pname.strip().lstrip("%")] = pshape.strip()
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, rhs = m.group(1), m.group(2)
            cur.instrs.append((name, rhs))
            cur.shapes[name] = _result_of(rhs)
    return comps


def _dot_flops(rhs: str, shapes: dict[str, str]) -> float:
    out_dims = _shape_dims(_result_of(rhs))
    n_out = 1
    for d in out_dims:
        n_out *= d
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    mo = re.search(r"dot\(([^)]*)\)", rhs)
    contract = 1
    if mc and mo:
        lhs_name = mo.group(1).split(",")[0].strip().lstrip("%")
        lhs_shape = shapes.get(lhs_name, "")
        dims = _shape_dims(lhs_shape)
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(dims):
                contract *= dims[int(idx)]
    return 2.0 * n_out * contract


def analyze_hlo(hlo: str, *, n_devices_hint: int = 1) -> dict:
    comps = parse_computations(hlo)

    # ---- per-computation local costs + control-flow edges ----
    local = {}
    edges = defaultdict(list)       # comp -> [(child_comp, multiplier)]
    fusion_calls = defaultdict(list)  # comp -> [child fusion computations]
    unknown_trips = 0

    for cname, comp in comps.items():
        flops = bytes_ = 0.0
        coll = defaultdict(float)
        for iname, rhs in comp.instrs:
            if " dot(" in rhs or rhs.startswith("dot("):
                flops += _dot_flops(rhs, comp.shapes)
            if " while(" in rhs:
                mt = _TRIP_RE.search(rhs)
                trip = int(mt.group(1)) if mt else 1
                if not mt:
                    unknown_trips += 1
                mb = re.search(r"body=%?([\w\.\-]+)", rhs)
                mc2 = re.search(r"condition=%?([\w\.\-]+)", rhs)
                if mb:
                    edges[cname].append((mb.group(1), trip))
                if mc2:
                    edges[cname].append((mc2.group(1), trip))
                continue
            mcond = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if mcond:
                for child in mcond.group(1).split(","):
                    edges[cname].append((child.strip().lstrip("%"), 1.0))
            mcall = re.search(r"calls=%?([\w\.\-]+)", rhs)
            if mcall:
                fusion_calls[cname].append(mcall.group(1))
            # bytes: top-level traffic ops only.  Slicing/scatter ops touch
            # only the slice region, not their full buffer operand:
            #   dynamic-slice / gather   -> 2 x result (+indices, negligible)
            #   dynamic-update-slice     -> 2 x update operand (in-place)
            #   scatter                  -> 2 x updates operand
            op = _opcode(rhs)
            if op in _NO_TRAFFIC_OPS:
                pass
            elif op in ("dynamic-slice", "gather"):
                bytes_ += 2.0 * _shape_bytes(_result_of(rhs))
            elif op in ("dynamic-update-slice", "scatter"):
                margs = re.search(r"\(([^)]*)\)", rhs[rhs.find("("):])
                upd = 0
                if margs:
                    ops_b = [_shape_bytes(comp.shapes.get(
                        a.strip().lstrip("%"), ""))
                        for a in margs.group(1).split(",")]
                    big = max(ops_b) if ops_b else 0
                    upd = sum(ops_b) - big     # everything but the buffer
                bytes_ += 2.0 * upd
            else:
                rb = _shape_bytes(_result_of(rhs))
                ob = 0
                margs = re.search(r"\(([^)]*)\)", rhs[rhs.find("("):])
                if margs:
                    for a in margs.group(1).split(","):
                        ob += _shape_bytes(comp.shapes.get(
                            a.strip().lstrip("%"), ""))
                bytes_ += rb + ob
            # collectives
            for kind in _COLL_KINDS:
                if f" {kind}(" in rhs or rhs.startswith(f"{kind}("):
                    size = _shape_bytes(_result_of(rhs))
                    g = _group_size(rhs, n_devices_hint)
                    factor = (g - 1) / g if g > 1 else 0.0
                    if kind == "all-reduce":
                        moved = 2.0 * size * factor
                    elif kind == "collective-permute":
                        moved = float(size)
                    else:
                        moved = size * factor
                    coll[kind] += moved
                    break
        local[cname] = {"flops": flops, "bytes": bytes_, "coll": dict(coll)}

    # fold fusion-body dot flops into their callers (bytes stay top-level)
    def fusion_flops(cname, seen=None):
        seen = seen or set()
        if cname in seen:
            return 0.0
        seen.add(cname)
        f = 0.0
        for child in fusion_calls.get(cname, []):
            f += local.get(child, {"flops": 0})["flops"] \
                + fusion_flops(child, seen)
        return f

    # ---- propagate multipliers through control flow ----
    entry = None
    for cname in comps:
        if re.match(r"^main", cname) or entry is None:
            pass
    # ENTRY computation: the one not referenced as body/cond/branch/fusion
    referenced = set()
    for cname in comps:
        for child, _ in edges[cname]:
            referenced.add(child)
        for child in fusion_calls[cname]:
            referenced.add(child)
    candidates = [c for c in comps if c not in referenced]
    # heuristic: entry is the unreferenced computation with the most instrs
    entry = max(candidates, key=lambda c: len(comps[c].instrs)) \
        if candidates else next(iter(comps))

    mult = defaultdict(float)
    mult[entry] = 1.0
    stack = [entry]
    seen_stack = set()
    while stack:
        c = stack.pop()
        if c in seen_stack:
            continue
        seen_stack.add(c)
        for child, trip in edges.get(c, []):
            mult[child] += mult[c] * trip
            stack.append(child)

    total = {"flops": 0.0, "bytes": 0.0}
    coll_total = defaultdict(float)
    for cname, m in mult.items():
        if m <= 0 or cname not in local:
            continue
        lc = local[cname]
        total["flops"] += m * (lc["flops"] + fusion_flops(cname))
        total["bytes"] += m * lc["bytes"]
        for k, v in lc["coll"].items():
            coll_total[k] += m * v

    coll_total_sum = sum(coll_total.values())
    return {
        "flops": total["flops"],
        "bytes": total["bytes"],
        "collective_bytes": coll_total_sum,
        "collectives": dict(coll_total),
        "entry": entry,
        "unknown_trip_whiles": unknown_trips,
    }
