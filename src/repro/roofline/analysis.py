"""Roofline-term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device / link_bw

cost_analysis() on the SPMD-partitioned module reports *per-device* flops /
bytes, so dividing by per-chip peaks is the assignment's
``HLO_FLOPs / (chips x peak)`` with the even-sharding identity.
collective_bytes is parsed from the post-partitioning HLO: the sum of operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (per-device shapes).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e constants (assignment-specified)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

# One shared dtype-size table for every HLO-text consumer (DESIGN.md §15);
# this module's private copy had drifted (no s4/u4, fewer f8 variants).
from repro.analysis.dtypes import DTYPE_BYTES as _DTYPE_BYTES

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _first_shape_bytes(line: str) -> int:
    """Bytes of the result shape(s) at the start of an HLO instruction line."""
    # e.g.  %all-gather.1 = f32[16,512]{0,1} all-gather(...)
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    # result may be a tuple: (f32[..], f32[..])
    head = rhs.split(")", 1)[0] if rhs.startswith("(") else rhs.split(" ", 1)[0]
    total = 0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind from post-SPMD HLO text."""
    out: dict[str, int] = {}
    count = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ROOT"):
            m = _COLL_RE.search(ls)
            if m and f" {m.group(1)}" in ls:
                kind = m.group(1)
                b = _first_shape_bytes(ls)
                out[kind] = out.get(kind, 0) + b
                count += 1
    out["_n_ops"] = count
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6*N*D (or 6*N_active*D) global
    useful_flops_ratio: float     # model_flops / (flops_per_device * chips)

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(cost: dict, hlo_text: str, *, n_chips: int,
            model_flops_global: float) -> Roofline:
    """Prefers the trip-count-aware HLO cost model (repro.roofline.hlo_cost);
    XLA's cost_analysis undercounts while-loop bodies (counts them once) and
    is kept in the artifact only for reference."""
    from repro.roofline import hlo_cost
    hc = hlo_cost.analyze_hlo(hlo_text, n_devices_hint=n_chips)
    flops = float(hc["flops"])
    byts = float(hc["bytes"])
    coll = dict(hc["collectives"])
    coll["_n_unknown_trip_whiles"] = hc["unknown_trip_whiles"]
    cb = float(hc["collective_bytes"])
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = cb / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_hlo = flops * n_chips
    ratio = (model_flops_global / total_hlo) if total_hlo > 0 else 0.0
    return Roofline(flops_per_device=flops, bytes_per_device=byts,
                    coll_bytes_per_device=cb, coll_breakdown=coll,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, bottleneck=bottleneck,
                    model_flops=model_flops_global, useful_flops_ratio=ratio)


def newton_schulz_flops(rows: int, cols: int, steps: int = 5) -> float:
    """FLOPs of the tiled NS(steps) orthogonalization on an (rows, cols)
    matrix (kernels/newton_schulz.py; DESIGN.md §11): per iteration one
    gram (2·m²·n), one m×m finalize (2·m³) and one apply (2·m²·n), with
    m = min dim.  The repo's first compute-bound optimizer kernel."""
    m, n = sorted((rows, cols))
    return float(steps) * (4.0 * m * m * n + 2.0 * m ** 3)


def muon_update_roofline(shape: tuple, *, bits: int = 8,
                         block_size: int = 2048, steps: int = 5) -> dict:
    """Roofline position of one quantized-Muon matrix-leaf update.

    Unlike the element-wise family (~11 B/param streamed, ~O(100) ops/param
    → bandwidth-bound, §3 napkin math), Muon adds the NS matmul chain whose
    FLOPs/param grow with min(m, n): ~4·steps·min_dim, vs ~14 bytes/param
    streamed.  The update flips compute-bound once
    min_dim ≳ bytes_per_param·(peak/bw)/(4·steps) ≈ 14·240/20 ≈ 170 on
    v5e — i.e. essentially every real weight matrix; the per-block
    dequant/requant stays bandwidth-bound but no longer dominates.  Used
    by ``bench_speed``'s muon sweep to derive the analytic TPU position."""
    rows, cols = shape
    n = rows * cols
    # p read+write (4+4), g read (4), momentum codes read+write
    # (2 · bits/8), absmax amortized (8/block_size per state).
    bytes_per_param = 12.0 + 2.0 * bits / 8.0 + 8.0 / block_size
    flops = newton_schulz_flops(rows, cols, steps) + 8.0 * n  # + EMA/step
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_per_param * n / HBM_BW
    return {
        "flops": flops,
        "bytes": bytes_per_param * n,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "bottleneck": "compute" if compute_s > memory_s else "memory",
    }


def model_flops(cfg, case) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params), 2*N*D for
    inference forward (D = tokens processed by the step)."""
    n_active = cfg.active_param_count()
    if case.kind == "train":
        tokens = case.global_batch * case.seq_len
        return 6.0 * n_active * tokens
    if case.kind == "prefill":
        tokens = case.global_batch * case.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * case.global_batch
