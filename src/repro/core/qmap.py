"""Quantization codebooks ("qmaps") for k-bit optimizer states.

All maps are 2^bits-entry sorted float32 arrays over [-1, 1] (signed) or
[0, 1] (unsigned); the paper's 8-bit maps are the ``bits=8`` point.  The
dynamic (tree) maps follow the construction of the released bitsandbytes
implementation (`create_dynamic_map`), which is the reference for the paper
"8-bit Optimizers via Block-wise Quantization" (Dettmers et al., ICLR 2022):

  * 1 sign bit (signed maps only),
  * the number of leading zero bits selects a decimal exponent 10^(i - E + 1)
    for E exponent levels,
  * the remaining bits linearly quantize the fraction over [0.1, 1].

The unsigned "dynamic quantization" variant (paper §2.2) re-purposes the sign
bit as one extra fraction bit for the strictly-positive second Adam state.

Sub-byte bitwidths (4/5/6) use the same tree construction with fewer total
bits — the format Li et al. 2023 ("Memory Efficient Optimizers with 4-bit
States") show is viable for the first Adam moment.  The k-bit code-format
subsystem (`repro.core.lowbit`, DESIGN.md §9) owns bit-packing; this module
only generates level values.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.errors import ConfigError, FormatError

# Bit layout used by the reference implementation: for b total bits, b - 1
# dynamic-exponent levels (7 for the 8-bit maps).


def _dynamic_levels(signed: bool, inverse: bool = False,
                    bits: int = 8) -> list[float]:
    """Positive values of the dynamic (tree) map, before sign mirroring."""
    data: list[float] = []
    max_exp_bits = bits - 1
    non_sign_bits = bits - 1
    for i in range(max_exp_bits):
        # Fraction slots double per level; unsigned maps get one extra bit.
        n_frac = 2 ** (i + non_sign_bits - max_exp_bits) * (1 if signed else 2)
        if n_frac < 1:
            continue
        boundaries = np.linspace(0.1, 1.0, n_frac + 1)
        means = (boundaries[:-1] + boundaries[1:]) / 2.0
        if inverse:
            # Inverse dynamic quantization (paper App F.1): swap exponent
            # order so the *small*-magnitude end gets the most fraction bits.
            exponent = 10.0 ** (-i)
        else:
            exponent = 10.0 ** (-(max_exp_bits - 1) + i)
        data += (exponent * means).tolist()
    return data


def _finalize(values: list[float], bits: int) -> np.ndarray:
    values = list(values)
    values.append(0.0)
    values.append(1.0)
    target = 2 ** bits
    if len(values) > target:
        raise ConfigError(f"codebook construction produced {len(values)} "
                          f"levels for {bits}-bit storage (max {target})")
    # Pad (never needed for the standard configs, kept for safety/parity with
    # the reference implementation which pads with zeros).
    values += [0.0] * (target - len(values))
    out = np.sort(np.asarray(values, dtype=np.float32))
    if out.shape != (target,):
        raise FormatError(f"finalized codebook shape {out.shape} != "
                          f"({target},)")
    return out


@functools.lru_cache(maxsize=None)
def dynamic_map(signed: bool = True, bits: int = 8) -> np.ndarray:
    """Dynamic (tree) quantization map. Signed: Adam m / momentum. Unsigned:
    Adam r (second moment), with the sign bit re-used as a fraction bit."""
    pos = _dynamic_levels(signed=signed, bits=bits)
    if signed:
        vals = pos + [-v for v in pos]
    else:
        vals = pos
    return _finalize(vals, bits)


@functools.lru_cache(maxsize=None)
def inverse_dynamic_map(signed: bool = True, bits: int = 8) -> np.ndarray:
    """Inverse dynamic quantization (paper Appendix F.1)."""
    pos = _dynamic_levels(signed=signed, inverse=True, bits=bits)
    if signed:
        vals = pos + [-v for v in pos]
    else:
        vals = pos
    return _finalize(vals, bits)


@functools.lru_cache(maxsize=None)
def linear_map(signed: bool = True, bits: int = 8) -> np.ndarray:
    """Linear quantization baseline (ablation rows of paper Table 3)."""
    if signed:
        return np.linspace(-1.0, 1.0, 2 ** bits).astype(np.float32)
    return np.linspace(0.0, 1.0, 2 ** bits).astype(np.float32)


def _norm_ppf(p: np.ndarray) -> np.ndarray:
    """Inverse CDF of the standard normal (Acklam's rational approximation).

    scipy is not available in the container; this approximation has
    |rel err| < 1.15e-9 which is far below 8-bit resolution.
    """
    p = np.asarray(p, dtype=np.float64)
    a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
    b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00]
    plow, phigh = 0.02425, 1 - 0.02425
    out = np.empty_like(p)
    lo = p < plow
    hi = p > phigh
    mid = ~(lo | hi)
    if lo.any():
        q = np.sqrt(-2 * np.log(p[lo]))
        out[lo] = (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
                  ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if hi.any():
        q = np.sqrt(-2 * np.log(1 - p[hi]))
        out[hi] = -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
                   ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if mid.any():
        q = p[mid] - 0.5
        r = q * q
        out[mid] = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / \
                   (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
    return out


@functools.lru_cache(maxsize=None)
def normal_quantile_map(signed: bool = True, bits: int = 8) -> np.ndarray:
    """Quantile map per paper Eq. 5 with X = N(0,1) (or |N(0,1)| unsigned)."""
    k = 2 ** bits
    if signed:
        # Eq. 5: midpoints of 2^k + 1 equally spaced quantiles.
        qs = _norm_ppf(np.linspace(1.0 / (k + 1), k / (k + 1), k + 1))
        q = (qs[:-1] + qs[1:]) / 2.0
    else:
        # Half-normal: quantiles of |N(0,1)| via Phi^-1((1+p)/2).
        ps = np.linspace(1.0 / (k + 1), k / (k + 1), k + 1)
        qs = _norm_ppf((1.0 + ps) / 2.0)
        q = (qs[:-1] + qs[1:]) / 2.0
    q = q / np.max(np.abs(q))
    return np.sort(q.astype(np.float32))


QMAPS = {
    "dynamic": dynamic_map,
    "inverse_dynamic": inverse_dynamic_map,
    "linear": linear_map,
    "quantile_normal": normal_quantile_map,
}


def get_qmap(name: str, signed: bool, bits: int = 8) -> np.ndarray:
    """Return the 2^bits-entry sorted codebook for `name` (default 256)."""
    try:
        return QMAPS[name](signed=signed, bits=bits)
    except KeyError:
        raise ValueError(f"unknown qmap '{name}'; have {sorted(QMAPS)}") from None


def boundaries(qmap: np.ndarray) -> np.ndarray:
    """255 nearest-neighbour decision boundaries (midpoints) of a sorted map."""
    return ((qmap[1:] + qmap[:-1]) / 2.0).astype(np.float32)
