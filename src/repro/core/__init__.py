"""Core of the paper's contribution: block-wise dynamic 8-bit quantization
and the 8-bit optimizers built on it."""
from repro.core.blockwise import (  # noqa: F401
    DEFAULT_BLOCK_SIZE,
    QuantizedTensor,
    dequantize,
    quantize,
    quantization_error,
    zeros_like_quantized,
)
from repro.core.qmap import get_qmap  # noqa: F401
