"""Bit-packing of sub-byte optimizer-state codes into uint8 words.

Layout: codes are written MSB-first into a big-endian bitstream per row —
code i occupies stream bits ``[i*b, (i+1)*b)`` and byte j holds stream bits
``[8j, 8j+8)`` with stream bit 8j at the byte's bit 7.  For b = 4 this is
the familiar two-codes-per-byte nibble layout; for b = 5/6 codes straddle
byte boundaries, which the bitstream formulation handles uniformly.  A row
of N codes therefore packs to exactly ``N*b/8`` bytes (N must be a multiple
of 8/gcd(b,8); every supported quantization block size is a multiple of 8).

``pack_codes`` / ``unpack_codes`` are pure jnp — broadcast shifts, masks and
static reshapes only (no gathers, no host round trips) — so the *same
functions* run inside the Pallas fused-update kernel (unpack → dequant →
update → requant → pack in VMEM) and on the XLA reference path.  Packed
codes parity between ``impl="interpret"`` and ``impl="jnp"`` therefore
holds by construction, the same contract the 8-bit kernels already follow
(DESIGN.md §3, §9).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.errors import FormatError

SUPPORTED_BITS = (4, 5, 6, 8)


def packed_width(n_codes: int, bits: int) -> int:
    """Bytes per row of ``n_codes`` b-bit codes (exact, no slack)."""
    if bits not in SUPPORTED_BITS:
        raise FormatError(f"bits={bits} unsupported; choose from "
                          f"{SUPPORTED_BITS}")
    if (n_codes * bits) % 8 != 0:
        raise FormatError(f"{n_codes} codes of {bits} bits do not fill "
                          f"whole bytes")
    return (n_codes * bits) // 8


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """(..., N) integer codes in [0, 2^bits) -> (..., N*bits/8) uint8."""
    if bits == 8:
        return codes.astype(jnp.uint8)
    *lead, n = codes.shape
    w = packed_width(n, bits)
    c = codes.astype(jnp.int32)
    # codes -> per-code bit planes, MSB first: (..., N, bits)
    tsel = jax.lax.broadcasted_iota(jnp.int32, (*lead, n, bits), len(lead) + 1)
    stream = (c[..., None] >> (bits - 1 - tsel)) & 1
    # bitstream -> bytes, MSB first: (..., W, 8) -> (..., W)
    stream = stream.reshape(*lead, w, 8)
    ksel = jax.lax.broadcasted_iota(jnp.int32, (*lead, w, 8), len(lead) + 1)
    return jnp.sum(stream << (7 - ksel), axis=-1).astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int) -> jax.Array:
    """(..., W) uint8 words -> (..., W*8/bits) int32 codes in [0, 2^bits)."""
    if bits == 8:
        return packed.astype(jnp.int32)
    if bits not in SUPPORTED_BITS:
        raise FormatError(f"bits={bits} unsupported; choose from "
                          f"{SUPPORTED_BITS}")
    *lead, w = packed.shape
    n = (w * 8) // bits
    b = packed.astype(jnp.int32)
    ksel = jax.lax.broadcasted_iota(jnp.int32, (*lead, w, 8), len(lead) + 1)
    stream = ((b[..., None] >> (7 - ksel)) & 1).reshape(*lead, n, bits)
    tsel = jax.lax.broadcasted_iota(jnp.int32, (*lead, n, bits), len(lead) + 1)
    return jnp.sum(stream << (bits - 1 - tsel), axis=-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedCodes:
    """Bit-packed codes for one state tensor in the flat block domain.

    packed : (n_blocks, n_codes*bits/8) uint8 — the only array child, so
             sharding/checkpoint trees see exactly one leaf per container
             and shard its *block-count* axis (dim 0), never the byte axis.
    bits   : static bitwidth of each code (4/5/6; 8-bit states stay plain
             uint8 arrays and never enter this container).
    n_codes: static logical codes per row (= the quantization block size).
    """

    packed: jax.Array
    bits: int
    n_codes: int

    def tree_flatten(self):
        return (self.packed,), (self.bits, self.n_codes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    @classmethod
    def from_codes(cls, codes: jax.Array, bits: int) -> "PackedCodes":
        return cls(pack_codes(codes, bits), bits, int(codes.shape[-1]))

    def unpack(self) -> jax.Array:
        """-> (n_blocks, n_codes) int32 codes."""
        return unpack_codes(self.packed, self.bits)

    @property
    def shape(self) -> tuple:
        """Logical (unpacked) code-array shape."""
        return (*self.packed.shape[:-1], self.n_codes)

    def nbytes(self) -> int:
        return int(self.packed.size)


def unwrap_codes(codes):
    """One state-slot codes container -> ``(raw, bits, n_codes)``.

    ``raw`` is the stored uint8 array, ``bits`` the code bitwidth (8 for
    plain arrays), ``n_codes`` the logical per-row code count for packed
    containers and None for plain arrays (the re-wrap sentinel).  The one
    shared unwrap point for every layer that strips ``PackedCodes`` at a
    kernel/shard_map boundary (ops.fused_update, ops.segment_tensor_scales,
    the partitioned span dispatch)."""
    if isinstance(codes, PackedCodes):
        return codes.packed, codes.bits, codes.n_codes
    return codes, 8, None
