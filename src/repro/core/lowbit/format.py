"""Per-state-slot code format: bitwidth + signedness + codebook family.

One :class:`CodeFormat` describes how a single optimizer state slot (first
moment, second moment, ...) is stored: a 2^bits-entry codebook from
``repro.core.qmap`` and, for sub-byte widths, bit-packed storage via
:class:`~repro.core.lowbit.packing.PackedCodes`.  The optimizer engine
builds one format per slot from ``OptimConfig.state_bits`` (per-slot
bitwidths, e.g. 4-bit first / 8-bit second moment as Li et al. 2023
recommend) and everything below — kernels, checkpoint, sharding — follows
the container type.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import qmap as qmap_lib
from repro.core.lowbit.packing import SUPPORTED_BITS, PackedCodes
from repro.errors import FormatError


@dataclasses.dataclass(frozen=True)
class CodeFormat:
    """Static description of one quantized state slot's storage format."""

    bits: int = 8
    signed: bool = True
    qmap_name: str = "dynamic"

    def __post_init__(self):
        if self.bits not in SUPPORTED_BITS:
            raise FormatError(f"bits={self.bits} unsupported; choose from "
                              f"{SUPPORTED_BITS}")

    @property
    def n_levels(self) -> int:
        return 1 << self.bits

    @property
    def max_code(self) -> int:
        return self.n_levels - 1

    def codebook(self) -> np.ndarray:
        """The sorted 2^bits-entry codebook for this slot."""
        return qmap_lib.get_qmap(self.qmap_name, self.signed, bits=self.bits)

    def zero_code(self) -> int:
        """Code index whose level is (closest to) 0.0 — the init fill."""
        return int(np.argmin(np.abs(self.codebook())))

    def init_codes(self, n_blocks: int, block_size: int):
        """Zero-state codes container: PackedCodes below 8 bits, else a
        plain uint8 array (the legacy 8-bit layout, bitwise-unchanged)."""
        zc = self.zero_code()
        if self.bits == 8:
            return jnp.full((n_blocks, block_size), zc, jnp.uint8)
        row = PackedCodes.from_codes(
            jnp.full((1, block_size), zc, jnp.int32), self.bits)
        return PackedCodes(jnp.tile(row.packed, (n_blocks, 1)),
                           self.bits, block_size)

    def bytes_per_param(self, block_size: int) -> float:
        """Analytic storage cost: packed codes + amortized f32 absmax."""
        return self.bits / 8.0 + 4.0 / block_size
