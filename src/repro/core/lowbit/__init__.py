"""k-bit code-format subsystem for bit-packed optimizer states (DESIGN.md §9).

The paper's 8-bit block-wise states are one point on a memory/precision
curve; this package generalizes the code format to any bitwidth
b ∈ {4, 5, 6, 8}:

  * :mod:`repro.core.qmap` generates the dynamic/linear/quantile codebooks
    at 2^b levels (``get_qmap(name, signed, bits=b)``);
  * :class:`CodeFormat` bundles (bits, signedness, qmap name) per state
    slot and owns level-count/zero-code/byte accounting;
  * :class:`PackedCodes` is the storage container: sub-byte codes are
    bit-packed into uint8 words (two 4-bit codes per byte, big-endian
    bitstream for 5/6-bit), with pure-JAX :func:`pack_codes` /
    :func:`unpack_codes` that the Pallas kernels reuse verbatim so the
    fused path never materializes unpacked codes in HBM.

Everything above this layer (kernel registry, optimizer engine, checkpoint,
sharding) treats a state slot as (codes-container, absmax) and dispatches on
``isinstance(codes, PackedCodes)``.
"""
from repro.core.lowbit.format import CodeFormat
from repro.core.lowbit.packing import (SUPPORTED_BITS, PackedCodes,
                                       pack_codes, packed_width,
                                       unpack_codes, unwrap_codes)

__all__ = [
    "CodeFormat", "PackedCodes", "SUPPORTED_BITS", "pack_codes",
    "packed_width", "unpack_codes", "unwrap_codes",
]
