"""The paper's 8-bit optimizers (and their 32-bit twins) as one engine.

``Block8bitOptimizer`` implements Adam/AdamW/Momentum/LAMB/LARS/AdaGrad with
per-leaf state that is either block-wise 8-bit quantized (``Quant8Leaf``) or
full 32-bit (``Full32Leaf`` — used for the 32-bit baselines, for leaves below
``min_8bit_size``, and for leaves matched by the stable-embedding override,
paper §2.3).

The update is the paper's §2 procedure: dequantize -> 32-bit math ->
requantize, executed through the ``(algo, impl)`` registry behind
``repro.kernels.ops.fused_update``: one fused Pallas pass per state tensor
on TPU (``impl='pallas'``), the same kernels interpreted on CPU
(``impl='interpret'``), or the parameterized jnp oracle (``impl='jnp'``).
Every algorithm and every ablation mode (stochastic rounding, tensor-wise
quantization) takes this one path — there is no separate multi-pass
fallback anymore (DESIGN.md §3).

State signedness per algorithm (paper §2.2: the strictly-positive second
moment uses the unsigned dynamic map with the sign bit re-purposed as an
extra fraction bit):

  adam/adamw/lamb : m -> signed dynamic, r -> unsigned dynamic
  momentum/lars   : m -> signed dynamic
  adagrad         : accumulator -> unsigned dynamic (stored in the m slot)

Storage bitwidth is per state slot (``cfg.state_bits``; DESIGN.md §9): each
slot gets a :class:`~repro.core.lowbit.CodeFormat` whose 2^bits-entry
codebook and (for sub-byte widths) bit-packed ``PackedCodes`` container
flow through the same fused kernels — e.g. ``state_bits=(4, 8)`` stores a
4-bit first moment next to an 8-bit second moment (Li et al. 2023).

Optional percentile clipping (``cfg.percentile_clipping < 100``) maintains a
per-optimizer history of squared global gradient norms in
``OptState.gnorm_vec`` (bitsandbytes-style; DESIGN.md §7) and scales
gradients by a scalar inside the fused kernel — no extra pass over the
states.  The history is ordinary optimizer state: it is checkpointed and
restored like every other leaf.

**Pooled single dispatch** (``cfg.pooled``, default on; DESIGN.md §10):
``init`` concatenates every quantized leaf's statistics into one
:class:`~repro.core.optim.base.QuantArena` and every sub-``min_quant_size``
leaf's fp32 state into one :class:`~repro.core.optim.base.Pool32Arena`, so
``apply`` issues **one** ``kops.fused_update`` per arena (plus one jnp
update for the fp32 pool) instead of one launch per parameter leaf.
Per-leaf stochastic-rounding seeds become per-block seed vectors and
LAMB/LARS trust ratios are finalized per arena *segment*, so pooled and
per-leaf dispatch are bit-identical — ``pooled=False`` is kept as the
parity oracle (and serves the tensor-wise ablation, which needs a
per-tensor absmax).  Checkpoints always store the per-leaf canonical form
(:func:`unpool_state`), so pooled and per-leaf runs share checkpoints in
both directions.

**Matrix-class leaves** (DESIGN.md §11): subclasses can route leaves to a
matrix algorithm via the ``_leaf_class``/``_init_matrix_leaf`` hooks and
re-point ``self._ew_algo`` at their element-wise fallback —
``MuonOptimizer`` routes 2-D leaves to Newton–Schulz momentum updates
(one-state ``Quant8Leaf``, dispatched per leaf even under pooling) while
everything else runs fused adamw through the machinery above.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowbit import CodeFormat, PackedCodes
from repro.core.lowbit import unwrap_codes as lowbit_unwrap
from repro.core.optim import base
from repro.core.optim.base import (ArenaPartition, FlatSegment, Full32Leaf,
                                   OptimConfig, Pool32Arena, Pool32Leaf,
                                   PooledQuantLeaf, Quant8Leaf, QuantArena,
                                   QuantSegment, blocks_to_param,
                                   flatten_to_blocks, make_buckets,
                                   make_partition, path_str)
from repro.errors import ConfigError, FormatError
from repro.models.constrain import constrain as _constrain
from repro.telemetry import tracing as _tracing
from repro.kernels import fused_update as kfu
from repro.kernels import ops as kops

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array           # int32 scalar, number of updates applied
    # tree of Quant8Leaf / Full32Leaf (per-leaf dispatch) or
    # PooledQuantLeaf / Pool32Leaf / Full32Leaf (pooled dispatch)
    leaves: Pytree
    # (pclip_history,) f32 squared-gnorm history, or None when percentile
    # clipping is off (cfg.percentile_clipping == 100).
    gnorm_vec: Optional[jax.Array] = None
    # Pooled-dispatch arenas (DESIGN.md §10); None on the per-leaf layout.
    arena: Optional[QuantArena] = None
    pool32: Optional[Pool32Arena] = None


def _is_state_leaf(x) -> bool:
    return isinstance(x, (Quant8Leaf, Full32Leaf, PooledQuantLeaf,
                          Pool32Leaf))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GradBuffer:
    """ZeRO-2 accumulated-gradient buffer (DESIGN.md §13).

    ``blocks`` holds the gradients of every pooled quantized leaf in the
    arena's flat block domain — the same layout the fused update consumes —
    padded to the partition's ``padded_total`` rows and, on a partition
    mesh, sharded to the owned span: the replicated param-shaped grad
    pytree never materializes.  ``ride`` carries the leaves that don't
    live in the arena (Full32 overrides, muon matrix leaves, pooled small
    leaves) as param-shaped f32 grads in flatten order.  ``layout`` is the
    static per-leaf routing table, one entry per param leaf in flatten
    order::

        ("arena", block_offset, n_blocks, shape, n)   |   ("ride", pos, shape)

    ``part`` is the arena's static ownership map (None when the arena is
    unpartitioned or absent) — ``accumulate_grads`` needs it to slice the
    per-bucket adds."""
    blocks: Optional[jax.Array]     # (padded_total, B) f32 | None
    ride: tuple                     # param-shaped f32 grads
    layout: tuple                   # static routing table
    part: Optional[ArenaPartition] = None

    def tree_flatten(self):
        return ((self.blocks, self.ride), (self.layout, self.part))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], tuple(children[1]), *aux)


def _state1_signed(algo: str) -> bool:
    return algo != "adagrad"


class Block8bitOptimizer:
    """init/apply optimizer owning the f32 master copy of the params."""

    def __init__(self, config: OptimConfig,
                 override_32bit: Optional[Callable[[str], bool]] = None,
                 mesh: Optional[Any] = None):
        self.cfg = config
        self.override_32bit = override_32bit or (lambda path: False)
        # Mesh for the partitioned (ZeRO-1) dispatch's shard_map path
        # (DESIGN.md §12).  None => the statically-unrolled span dispatch,
        # which computes identical results on any device count.
        self._mesh = mesh
        # The algorithm element-wise leaves run through the fused registry.
        # Matrix-class optimizers (MuonOptimizer, DESIGN.md §11) override
        # `_elementwise_algo` to their fallback algorithm ("adamw") while
        # routing 2-D leaves to the matrix update — the per-leaf routing
        # split.  The base engine has no matrix routing and rejects
        # matrix-class algos outright (feeding the flat block arena into
        # Newton–Schulz would silently orthogonalize garbage).
        self._ew_algo = self._elementwise_algo(config.algo)
        signed1 = _state1_signed(config.algo)
        bits1, bits2 = config.state_bits_pair
        self._fmt1 = CodeFormat(
            bits=bits1, signed=signed1,
            qmap_name=config.qmap_m if signed1 else config.qmap_r)
        self._fmt2 = CodeFormat(bits=bits2, signed=False,
                                qmap_name=config.qmap_r)
        self._qmap1 = jnp.asarray(self._fmt1.codebook())
        self._qmap2 = jnp.asarray(self._fmt2.codebook())
        self._impl = config.impl or kops.default_impl()

    # ------------------------------------------------------------------ init
    def _leaf_is_quantized(self, path: str, param: jax.Array) -> bool:
        if self.cfg.bits == 32:
            return False
        if param.size < self.cfg.min_quant_size:
            return False
        return not self.override_32bit(path)

    def _elementwise_algo(self, algo: str) -> str:
        """The algorithm non-matrix leaves dispatch through the fused
        registry.  Matrix optimizers override this (muon -> "adamw")."""
        if kfu.ALGO_SPECS[algo].matrix:
            raise ValueError(
                f"'{algo}' is a matrix-class algorithm; construct it via "
                f"make_optimizer / MuonOptimizer (DESIGN.md §11) — "
                f"Block8bitOptimizer has no matrix-leaf routing")
        return algo

    def _leaf_class(self, path: str, param: jax.Array) -> str:
        """Per-leaf algorithm class: "ew" (element-wise, the fused-registry
        path) or "matrix" (Newton–Schulz leaves, MuonOptimizer only —
        DESIGN.md §11).  The base engine is entirely element-wise."""
        del path, param
        return "ew"

    def _init_matrix_leaf(self, path: str, param: jax.Array):
        raise NotImplementedError(
            "matrix-class leaves need a matrix optimizer (MuonOptimizer)")

    def init(self, params: Pytree) -> OptState:
        cfg = self.cfg
        if cfg.pooling_active:
            return self._init_pooled(params)

        def init_leaf(path, p):
            path = path_str(path)
            if self._leaf_class(path, p) == "matrix":
                return self._init_matrix_leaf(path, p)
            if self._leaf_is_quantized(path, p):
                # master stays in PARAM SHAPE (sharded like the param) so the
                # fwd/bwd sees per-layer gathers inside the scan; only the
                # quantized statistics live in the flat block domain.  (The
                # flat-master variant all-gathered the whole tensor per step:
                # EXPERIMENTS.md §Perf iteration A2.)
                master = p.astype(jnp.dtype(cfg.master_dtype))
                nb = base.n_blocks_for(p.shape, cfg.block_size,
                                       cfg.shard_multiple)
                bs = cfg.block_size
                second = cfg.has_second_moment
                return Quant8Leaf(
                    master=master,
                    codes_m=self._fmt1.init_codes(nb, bs),
                    absmax_m=jnp.zeros((nb,), jnp.float32),
                    codes_r=self._fmt2.init_codes(nb, bs) if second else None,
                    absmax_r=jnp.zeros((nb,), jnp.float32) if second else None,
                    shape=tuple(p.shape), n=int(p.size))
            master = p.astype(jnp.float32)
            return Full32Leaf(
                master=master,
                m=jnp.zeros_like(master),
                r=jnp.zeros_like(master) if cfg.has_second_moment else None)

        leaves = jax.tree_util.tree_map_with_path(init_leaf, params)
        gnorm_vec = (jnp.zeros((cfg.pclip_history,), jnp.float32)
                     if cfg.percentile_clipping < 100 else None)
        return OptState(step=jnp.zeros((), jnp.int32), leaves=leaves,
                        gnorm_vec=gnorm_vec)

    def _init_pooled(self, params: Pytree) -> OptState:
        """Pooled arena layout (DESIGN.md §10): quantized statistics of all
        quantized leaves concatenate into one QuantArena; small leaves pool
        their fp32 state into one Pool32Arena; masters stay per-leaf in
        param shape (sharded like the param, §Perf A2).  Segment offsets
        are assigned in leaf flatten order, the order ``apply`` re-walks."""
        cfg = self.cfg
        mdt = jnp.dtype(cfg.master_dtype)
        bs = cfg.block_size
        second = cfg.has_second_moment
        qsegs: list = []
        fsegs: list = []
        flat32: list = []
        matrix_paths: list = []

        def init_leaf(path, p):
            path = path_str(path)
            if self._leaf_class(path, p) == "matrix":
                # Matrix-class leaves (muon) never pool: each one is its
                # own Newton–Schulz problem and dispatches per leaf
                # (DESIGN.md §11) — they ride along like Full32 overrides.
                leaf = self._init_matrix_leaf(path, p)
                if isinstance(leaf, Quant8Leaf):
                    # quantized matrix leaves get a whole-leaf owner under
                    # the partitioned dispatch (DESIGN.md §12)
                    matrix_paths.append(path)
                return leaf
            if self._leaf_is_quantized(path, p):
                nb = base.n_blocks_for(p.shape, bs, cfg.shard_multiple)
                off = qsegs[-1].offset + qsegs[-1].n_blocks if qsegs else 0
                qsegs.append(QuantSegment(path, off, nb, tuple(p.shape),
                                          int(p.size)))
                return PooledQuantLeaf(master=p.astype(mdt),
                                       shape=tuple(p.shape), n=int(p.size),
                                       offset=off, n_blocks=nb)
            if p.size < cfg.min_quant_size and not self.override_32bit(path):
                off = fsegs[-1].offset + fsegs[-1].n if fsegs else 0
                fsegs.append(FlatSegment(path, off, int(p.size),
                                         tuple(p.shape)))
                flat32.append(p.reshape(-1).astype(jnp.float32))
                return Pool32Leaf(shape=tuple(p.shape), n=int(p.size),
                                  offset=off)
            # stable-embedding override (paper §2.3): stays a per-leaf
            # Full32Leaf — large, sharded like its param.
            master = p.astype(jnp.float32)
            return Full32Leaf(
                master=master, m=jnp.zeros_like(master),
                r=jnp.zeros_like(master) if second else None)

        leaves = jax.tree_util.tree_map_with_path(init_leaf, params)
        shards = cfg.partition_shards if cfg.partition_active else 0
        mowners = tuple((p, k % max(shards, 1))
                        for k, p in enumerate(matrix_paths))
        arena = None
        if qsegs:
            total = qsegs[-1].offset + qsegs[-1].n_blocks
            arena = QuantArena(
                codes_m=self._fmt1.init_codes(total, bs),
                absmax_m=jnp.zeros((total,), jnp.float32),
                codes_r=self._fmt2.init_codes(total, bs) if second else None,
                absmax_r=jnp.zeros((total,), jnp.float32) if second else None,
                segments=tuple(qsegs),
                # ZeRO-1 ownership over the block dim (DESIGN.md §12):
                # spans are whole quantization blocks aligned to the
                # shard grid, so owned spans match the storage shards
                # (the kernel entry pads each span to its tile rows).
                partition=None if not shards else make_partition(
                    total, shards, grid=max(cfg.shard_multiple, 1),
                    matrix_owners=mowners))
        pool32 = None
        if fsegs:
            total = fsegs[-1].offset + fsegs[-1].n
            master = (jnp.concatenate(flat32) if len(flat32) > 1
                      else flat32[0])
            pool32 = Pool32Arena(
                master=master, m=jnp.zeros((total,), jnp.float32),
                r=jnp.zeros((total,), jnp.float32) if second else None,
                segments=tuple(fsegs),
                # element-granular ownership, lane-aligned (128) spans
                partition=None if not shards else make_partition(
                    total, shards, grid=128))
        gnorm_vec = (jnp.zeros((cfg.pclip_history,), jnp.float32)
                     if cfg.percentile_clipping < 100 else None)
        return OptState(step=jnp.zeros((), jnp.int32), leaves=leaves,
                        gnorm_vec=gnorm_vec, arena=arena, pool32=pool32)

    # ------------------------------------------------------------- algorithms
    def _math32(self, g, p, m, r, lr, step_f):
        """32-bit update math for Full32 leaves — the same parameterized
        update the fused kernels run (kernels/fused_update.update_math),
        with per-tensor norms computed inline.  Returns (m', r', p')."""
        cfg = self.cfg
        spec = kfu.ALGO_SPECS[self._ew_algo]
        s = dict(lr=lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
                 weight_decay=cfg.weight_decay, step=step_f,
                 tensor_scale=jnp.float32(1.0))
        s["tensor_scale"] = kfu.tensor_scale_for(spec, g, p, m, r, s,
                                                 cfg.trust_coeff)
        return kfu.update_math(spec, g, p, m, r, s)

    # -------------------------------------------------------------- clipping
    def percentile_clip(self, grads: Pytree, state: OptState):
        """Percentile-clipping scale for this step (DESIGN.md §7).

        Returns ``(gnorm_scale, new_gnorm_vec)``: the scalar every gradient
        is multiplied by inside the fused kernel, and the updated squared-
        gnorm history.  No-op (scale 1, vec unchanged) when disabled.  The
        history (including the current step's norm) must fill before
        clipping engages, so the first ``pclip_history - 1`` steps are
        never clipped; a spike on the step that fills it can be.

        ``grads`` may be the param-shaped pytree or a ZeRO-2
        :class:`GradBuffer` — the buffer path reduces each leaf on a view
        reshaped back to its param shape, in the same flatten order, so
        the history is bit-identical either way (DESIGN.md §13)."""
        cfg = self.cfg
        if cfg.percentile_clipping >= 100 or state.gnorm_vec is None:
            return jnp.float32(1.0), state.gnorm_vec
        mesh = (self._partition_mesh(cfg.partition_shards)
                if cfg.partition_active else None)
        gn2 = jnp.zeros((), jnp.float32)
        for leaf in self._grad_views(grads):
            if mesh is not None:
                # Partitioned dispatch (DESIGN.md §12): pin the global
                # gnorm reduction to replicated compute so its f32
                # summation order matches the unpartitioned oracle —
                # SPMD would otherwise distribute it (ULP drift in the
                # clip history).
                from repro.sharding import rules as _rules
                (leaf,) = _rules.replicate_for_scales(mesh, (leaf,))
            gn2 = gn2 + jnp.sum(jnp.square(leaf.astype(jnp.float32)))
        hist = state.gnorm_vec
        new_vec = hist.at[jnp.mod(state.step, hist.shape[0])].set(gn2)
        clip2 = jnp.percentile(new_vec, cfg.percentile_clipping)
        warm = (state.step + 1) >= hist.shape[0]
        scale = jnp.where(
            warm & (gn2 > clip2),
            jnp.sqrt(jnp.maximum(clip2, 0.0) / jnp.maximum(gn2, 1e-30)), 1.0)
        return scale.astype(jnp.float32), new_vec

    # ------------------------------------------- ZeRO-2 grad buffer (§13)
    def _grad_layout(self, state: OptState) -> tuple:
        """Static GradBuffer routing table from a (possibly abstract)
        pooled state: one entry per param leaf, flatten order."""
        entries: list = []
        pos = [0]

        def walk(leaf):
            if isinstance(leaf, PooledQuantLeaf):
                entries.append(("arena", leaf.offset, leaf.n_blocks,
                                tuple(leaf.shape), leaf.n))
            else:
                shape = (tuple(leaf.master.shape)
                         if isinstance(leaf, Full32Leaf)
                         else tuple(leaf.shape))
                entries.append(("ride", pos[0], shape))
                pos[0] += 1
            return leaf

        jax.tree_util.tree_map(walk, state.leaves, is_leaf=_is_state_leaf)
        return tuple(entries)

    def _constrain_buffer(self, blocks):
        """Pin the grad buffer to the owned-span layout — the resharding
        onto this constraint IS the per-bucket reduce-scatter when grads
        arrive replicated or param-sharded (DESIGN.md §13)."""
        if blocks is None:
            return None
        mesh = (self._partition_mesh(self.cfg.partition_shards)
                if self.cfg.partition_active else None)
        if mesh is None:
            return blocks
        from jax.sharding import NamedSharding
        from repro.sharding import rules as _rules
        spec = _rules.owned_span_spec(blocks.ndim, self.cfg.partition_axes)
        return jax.lax.with_sharding_constraint(
            blocks, NamedSharding(mesh, spec))

    def init_grad_buffer(self, state: OptState) -> GradBuffer:
        """Zero-initialized ZeRO-2 gradient accumulator for ``state``
        (DESIGN.md §13): arena grads in the padded flat block domain
        (owned-span sharded on a partition mesh), everything else as
        param-shaped ride-along zeros."""
        cfg = self.cfg
        if not cfg.pooling_active:
            raise ConfigError(
                "GradBuffer accumulation needs the pooled arena layout")
        layout = self._grad_layout(state)
        blocks = None
        part = None
        if state.arena is not None:
            part = state.arena.partition
            segs = state.arena.segments
            total = segs[-1].offset + segs[-1].n_blocks
            rows = part.padded_total if part is not None else total
            blocks = self._constrain_buffer(
                jnp.zeros((rows, cfg.block_size), jnp.float32))
        ride = tuple(jnp.zeros(e[2], jnp.float32)
                     for e in layout if e[0] == "ride")
        return GradBuffer(blocks=blocks, ride=ride, layout=layout,
                          part=part)

    def accumulate_grads(self, buf: GradBuffer, grads: Pytree) -> GradBuffer:
        """Add one microbatch's param-shaped grads into the ZeRO-2 buffer.

        Arena leaves flatten to the block domain and add bucket-by-bucket
        (``cfg.overlap_buckets``): each bucket's add is a separate op whose
        resharding onto the owned-span constraint — the reduce-scatter —
        can fire as soon as that bucket's grads exist, instead of waiting
        on the whole pytree.  Addition commutes with the (exact)
        reshape/pad, so the accumulated values are bit-identical to
        accumulating in param shape and flattening once (DESIGN.md §13)."""
        cfg = self.cfg
        gl = jax.tree_util.tree_leaves(grads)
        if len(gl) != len(buf.layout):
            raise FormatError(f"gradient tree has {len(gl)} leaves but the "
                              f"GradBuffer layout has {len(buf.layout)}")
        gbs = []
        ride = list(buf.ride)
        for g, e in zip(gl, buf.layout):
            if e[0] == "arena":
                gbs.append(flatten_to_blocks(g, cfg.block_size,
                                             cfg.shard_multiple))
            else:
                ride[e[1]] = ride[e[1]] + g.astype(jnp.float32)
        blocks = buf.blocks
        if blocks is not None and gbs:
            gb = jnp.concatenate(gbs) if len(gbs) > 1 else gbs[0]
            pad = blocks.shape[0] - gb.shape[0]
            if pad:
                gb = jnp.pad(gb, ((0, pad), (0, 0)))
            part = buf.part
            if cfg.overlap_active and part is not None:
                plan = make_buckets(part, cfg.overlap_buckets,
                                    grid=max(cfg.shard_multiple, 1))
                b3 = blocks.reshape(part.n_shards, part.span_pad, -1)
                g3 = gb.reshape(part.n_shards, part.span_pad, -1)
                for i, (k0, k1) in enumerate(plan.ranges):
                    with _tracing.annotate(f"grad_bucket{i}"):
                        b3 = b3.at[:, k0:k1].add(g3[:, k0:k1])
                blocks = b3.reshape(blocks.shape)
            else:
                blocks = blocks + gb
            # the owned-span constraint IS the reduce-scatter entry point
            # (DESIGN.md §13): resharding the accumulated buffer onto the
            # partition axes scatters each bucket's sum to its owner
            with _tracing.annotate("reduce_scatter"):
                blocks = self._constrain_buffer(blocks)
        return GradBuffer(blocks=blocks, ride=tuple(ride),
                          layout=buf.layout, part=buf.part)

    def _grad_views(self, grads):
        """Iterate gradient leaves in flatten order as param-shaped views,
        whether ``grads`` is the pytree or a GradBuffer.  Buffer views are
        reshaped back to the original param shape so downstream reductions
        (grad-clip norm, percentile clipping) run the oracle's exact
        per-leaf shapes (DESIGN.md §13)."""
        if not isinstance(grads, GradBuffer):
            for leaf in jax.tree_util.tree_leaves(grads):
                yield leaf
            return
        for e in grads.layout:
            if e[0] == "arena":
                _, off, nb, shape, n = e
                yield grads.blocks[off:off + nb].reshape(-1)[:n].reshape(
                    shape)
            else:
                yield grads.ride[e[1]]

    def grad_buffer_norm(self, buf: GradBuffer) -> jax.Array:
        """Global gradient norm from the ZeRO-2 buffer, bit-identical to
        ``train.loop.global_norm`` on the equivalent param-shaped pytree:
        each leaf's square-sum reduces a view reshaped to the original
        param shape, in flatten order.  On a partition mesh the buffer is
        transiently pinned replicated first so the f32 reduction order
        matches the sequential oracle (the replicate_for_scales contract,
        DESIGN.md §12)."""
        blocks = buf.blocks
        if blocks is not None:
            mesh = (self._partition_mesh(self.cfg.partition_shards)
                    if self.cfg.partition_active else None)
            if mesh is not None:
                from repro.sharding import rules as _rules
                (blocks,) = _rules.replicate_for_scales(mesh, (blocks,))
        buf = GradBuffer(blocks=blocks, ride=buf.ride, layout=buf.layout,
                         part=buf.part)
        sums = [jnp.sum(jnp.square(v.astype(jnp.float32)))
                for v in self._grad_views(buf)]
        return jnp.sqrt(jnp.sum(jnp.stack(sums)))

    def grad_buffer_bytes(self, state: OptState) -> dict:
        """Static peak-gradient accounting (DESIGN.md §13): bytes of the
        replicated param-shaped grad pytree (what the sequential
        accumulator holds) vs the per-device ZeRO-2 share — one owned
        span of the block buffer plus the (replicated) ride-along grads."""
        layout = self._grad_layout(state)
        replicated = ride = 0
        for e in layout:
            if e[0] == "arena":
                replicated += e[4] * 4
            else:
                n = int(np.prod(e[2])) if e[2] else 1
                replicated += n * 4
                ride += n * 4
        rows = 0
        arena = state.arena
        part = arena.partition if arena is not None else None
        if arena is not None:
            segs = arena.segments
            total = segs[-1].offset + segs[-1].n_blocks
            rows = (part.span_pad
                    if part is not None and self.cfg.partition_active
                    else (part.padded_total if part is not None else total))
        sharded = rows * self.cfg.block_size * 4 + ride
        return {"replicated_grad_bytes": int(replicated),
                "sharded_grad_bytes": int(sharded),
                "grad_ride_bytes": int(ride),
                "grad_partition_shards": (part.n_shards
                                          if part is not None and
                                          self.cfg.partition_active else 1)}

    # ---------------------------------------------------------------- update
    def _apply_quant8(self, leaf: Quant8Leaf, g: jax.Array, lr, step_f,
                      seed, gnorm_scale):
        cfg = self.cfg
        gb = flatten_to_blocks(g, cfg.block_size, cfg.shard_multiple)
        # Tell SPMD the reshard target up front: the flat block domain is
        # sharded over ALL mesh axes (EXPERIMENTS.md §Perf A1/A2).
        gb = _constrain(gb, "all", None)

        mdt = jnp.dtype(cfg.master_dtype)
        mb = flatten_to_blocks(leaf.master, cfg.block_size, cfg.shard_multiple)
        mb = _constrain(mb, "all", None)

        # One registry entry point for every algorithm and ablation mode;
        # tensor-wise quantization is dispatched to the jnp entry inside.
        res = kops.fused_update(
            self._ew_algo, mb, gb, leaf.codes_m, leaf.absmax_m,
            leaf.codes_r, leaf.absmax_r, self._qmap1, self._qmap2,
            lr=lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, step=step_f,
            trust_coeff=cfg.trust_coeff, gnorm_scale=gnorm_scale,
            blockwise=cfg.blockwise_norm,
            stochastic=cfg.stochastic_rounding, seed=seed, impl=self._impl,
            sentinel=cfg.sentinel)
        new = dataclasses.replace(
            leaf, master=blocks_to_param(res.p, leaf.shape, leaf.n, mdt),
            codes_m=res.codes_m, absmax_m=res.absmax_m)
        if res.codes_r is not None:
            new = dataclasses.replace(new, codes_r=res.codes_r,
                                      absmax_r=res.absmax_r)
        # Sentinel (DESIGN.md §16): per-leaf methods return (leaf, h8)
        # where h8 is the (N_HEALTH,) summed HealthFlags vector.
        if cfg.sentinel:
            return new, jnp.sum(res.health, axis=0)
        return new

    def _apply_full32(self, leaf: Full32Leaf, g: jax.Array, lr, step_f,
                      gnorm_scale):
        graw = g.astype(jnp.float32)
        g = graw * gnorm_scale
        r = leaf.r if leaf.r is not None else None
        m2, r2, p2 = self._math32(g, leaf.master, leaf.m, r, lr, step_f)
        new = Full32Leaf(master=p2, m=m2, r=r2)
        if self.cfg.sentinel:
            # Full32 leaves have no codes/absmax: only the nonfinite
            # grad/update slots are meaningful (counted on the raw grad,
            # pre gnorm_scale — inf*0 would mask a nonfinite grad).
            nf = lambda x: jnp.sum((~jnp.isfinite(x)).astype(jnp.float32))
            h8 = jnp.zeros((kfu.N_HEALTH,), jnp.float32)
            h8 = h8.at[0].set(nf(graw)).at[1].set(nf(p2))
            return new, h8
        return new

    def _apply_pool32(self, pool: Pool32Arena, gflat: jax.Array, lr,
                      step_f) -> Pool32Arena:
        """One jnp update for every pooled small leaf at once.  LAMB/LARS
        trust ratios stay per-tensor: each segment's norms are reduced on a
        view reshaped to the original param shape, so the reduction is
        bit-identical to the per-leaf Full32 path.  Under the partitioned
        dispatch (DESIGN.md §12) the per-element math runs span-by-span —
        scales are finalized globally first, so results are unchanged."""
        cfg = self.cfg
        spec = kfu.ALGO_SPECS[self._ew_algo]
        s = dict(lr=lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
                 weight_decay=cfg.weight_decay, step=step_f,
                 tensor_scale=jnp.float32(1.0))
        if spec.needs_norms:
            def seg_scale(i, off, n):
                shape = pool.segments[i].shape
                view = lambda a: a[off:off + n].reshape(shape)
                return kfu.tensor_scale_for(
                    spec, view(gflat), view(pool.master), view(pool.m),
                    None if pool.r is None else view(pool.r), s,
                    cfg.trust_coeff)

            s["tensor_scale"] = kfu.segment_scale_vector(
                [(seg.offset, seg.n) for seg in pool.segments],
                pool.master.shape[0], seg_scale)
        # The fp32 pool is deliberately NOT span-computed under the
        # partitioned dispatch: its leaves are all sub-min_quant_size, so
        # the whole update is a few KB of elementwise work on replicated
        # storage — splitting it buys nothing and embedding it in a
        # different program shape costs ULP-level bit-exactness (XLA FMA
        # contraction is fusion-context dependent).  Its ArenaPartition
        # governs ownership accounting and interchange only (DESIGN.md
        # §12).
        m2, r2, p2 = kfu.update_math(spec, gflat, pool.master, pool.m,
                                     pool.r, s)
        return dataclasses.replace(pool, master=p2, m=m2, r=r2)

    # ------------------------------------------ partitioned (ZeRO-1) dispatch
    def _partition_mesh(self, n_shards: int):
        """The mesh for the shard_map span execution, or None for the
        statically-unrolled fallback (no mesh configured, or the partition
        axes absent / of mismatched total size — the fallback computes
        identical results on any device count).  ``cfg.partition_axes``
        may name several axes ("pod,data" on multi-pod meshes): their size
        product must equal the shard count."""
        mesh = self._mesh
        axes = self.cfg.partition_axes
        if mesh is None or not axes:
            return None
        names = getattr(mesh, "axis_names", ())
        if any(a not in names for a in axes):
            return None
        size = 1
        for a in axes:
            size *= int(mesh.shape[a])
        if size != n_shards:
            return None
        return mesh

    def _fused_update_partitioned(self, arena: QuantArena, mb, gb,
                                  block_seeds, block_offsets, segs, lr,
                                  step_f, gnorm_scale):
        """ZeRO-1 arena update (DESIGN.md §12): every owner updates ONLY
        its owned block span.  Trust ratios (whole-segment norms — a
        segment may straddle span boundaries) are finalized globally once
        and sliced per span, so each span's update is block-local and the
        stitched result is bit-identical to the unpartitioned dispatch.
        With a matching mesh the spans run under shard_map (one local
        fused launch per device; grads reduce-scatter in, master slices
        all-gather out at their use sites); otherwise the spans unroll
        statically — same math, any device count."""
        cfg = self.cfg
        part = arena.partition
        spec = kfu.ALGO_SPECS[self._ew_algo]
        hyper = dict(lr=lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
                     weight_decay=cfg.weight_decay, step=step_f,
                     trust_coeff=cfg.trust_coeff, gnorm_scale=gnorm_scale)
        mesh = self._partition_mesh(part.n_shards)
        tscale = None
        if spec.needs_norms:
            sm, sg, scm, sam, scr, sar = (mb, gb, arena.codes_m,
                                          arena.absmax_m, arena.codes_r,
                                          arena.absmax_r)
            if mesh is not None:
                # Pin the scale pass to replicated compute: a whole-
                # segment norm is a global reduction, and letting SPMD
                # distribute it would change the f32 reduction order vs
                # the unpartitioned oracle (ULP drift in trust ratios).
                # Replicated, every device runs the oracle's exact
                # single-device reduction.  (The arena is small — codes;
                # a reduce-then-broadcast of partials is the documented
                # future optimization, DESIGN.md §12.)
                from repro.sharding import rules as _rules
                sm, sg, scm, sam, scr, sar = _rules.replicate_for_scales(
                    mesh, (sm, sg, scm, sam, scr, sar))
            tscale = kops.segment_tensor_scales(
                self._ew_algo, sm, sg, scm, sam, scr, sar,
                self._qmap1, self._qmap2, segments=segs, impl=self._impl,
                **hyper)
        if mesh is not None:
            return self._span_update_shard_map(
                mesh, part, arena, mb, gb, block_seeds, block_offsets,
                tscale, hyper)
        # Bucketed overlap (DESIGN.md §13): subdivide each span into the
        # bucket chunks and fire one launch per (span, bucket) piece —
        # block-local math on static contiguous slices, so the stitched
        # result is bit-identical to the one-launch-per-span dispatch.
        pieces = part.spans
        if cfg.overlap_active:
            plan = make_buckets(part, cfg.overlap_buckets,
                                grid=max(cfg.shard_multiple, 1))
            pieces = [(start + k0, min(n, k1) - k0)
                      for start, n in part.spans
                      for k0, k1 in plan.ranges]
        outs = []
        for i, (start, n) in enumerate(pieces):
            if n <= 0:
                continue
            sl = slice(start, start + n)
            with _tracing.annotate(f"bucket{i}"):
                outs.append(kops.fused_update(
                    self._ew_algo, mb[sl], gb[sl],
                    _slice_blocks(arena.codes_m, start, n),
                    arena.absmax_m[sl],
                    None if arena.codes_r is None
                    else _slice_blocks(arena.codes_r, start, n),
                    None if arena.absmax_r is None else arena.absmax_r[sl],
                    self._qmap1, self._qmap2, blockwise=True,
                    stochastic=cfg.stochastic_rounding,
                    block_seeds=block_seeds[sl],
                    block_offsets=block_offsets[sl],
                    tensor_scale_blocks=None if tscale is None
                    else tscale[sl],
                    impl=self._impl, sentinel=cfg.sentinel, **hyper))
        return _concat_span_results(outs)

    def _span_update_shard_map(self, mesh, part: ArenaPartition,
                               arena: QuantArena, mb, gb, block_seeds,
                               block_offsets, tscale, hyper):
        """shard_map execution of the owned spans: the arena's padded
        block domain splits into one span per device on the partition
        axis; each device runs ONE local fused_update over just its span
        (sharding/rules.py owns the span specs)."""
        from repro.sharding import rules as _rules
        cfg = self.cfg
        axis = cfg.partition_axes
        two = arena.codes_r is not None
        has_ts = tscale is not None

        cm, bits_m, nc_m = lowbit_unwrap(arena.codes_m)
        cr, bits_r, nc_r = lowbit_unwrap(arena.codes_r)
        spans = [mb, gb, cm, arena.absmax_m, block_seeds, block_offsets]
        if two:
            spans += [cr, arena.absmax_r]
        if has_ts:
            spans.append(tscale)
        static = {k: v for k, v in hyper.items()
                  if k not in ("lr", "step", "gnorm_scale")}

        def local(args, consts):
            it = iter(args)
            mb_, gb_, cm_, am_, seeds_, offs_ = (next(it)
                                                 for _ in range(6))
            cr_, ar_ = (next(it), next(it)) if two else (None, None)
            ts_ = next(it) if has_ts else None
            qm1, qm2, lr_, step_, gs_ = consts
            res = kops.fused_update(
                self._ew_algo, mb_, gb_,
                PackedCodes(cm_, bits_m, nc_m) if nc_m is not None else cm_,
                am_,
                None if cr_ is None else (
                    PackedCodes(cr_, bits_r, nc_r) if nc_r is not None
                    else cr_),
                ar_, qm1, qm2, lr=lr_, step=step_, gnorm_scale=gs_,
                blockwise=True, stochastic=cfg.stochastic_rounding,
                block_seeds=seeds_, block_offsets=offs_,
                tensor_scale_blocks=ts_, impl=self._impl,
                sentinel=cfg.sentinel, **static)

            def bare(c):
                return c.packed if isinstance(c, PackedCodes) else c
            out = (res.p, bare(res.codes_m), res.absmax_m)
            if two:
                out += (bare(res.codes_r), res.absmax_r)
            if cfg.sentinel:
                # per-block health rows ride the span machinery like every
                # other block-dim output (stitch/unpad are generic)
                out += (res.health,)
            return out

        consts = (self._qmap1, self._qmap2 if two else self._qmap1,
                  hyper["lr"], hyper["step"], hyper["gnorm_scale"])
        plan = None
        if cfg.overlap_active:
            plan = make_buckets(part, cfg.overlap_buckets,
                                grid=max(cfg.shard_multiple, 1))
        if plan is None or len(plan.ranges) <= 1:
            with _tracing.annotate("span_update"):
                outs = _rules.shard_map_over_spans(
                    mesh, axis, part, local, spans, consts)
        else:
            # Bucketed overlap (DESIGN.md §13): bucket k covers local rows
            # [k0, k1) of EVERY owner's span — the same static shape on
            # each device — so each bucket dispatches as its own shard_map
            # over a synthetic full-span partition.  Stitching the bucket
            # outputs back along the local-row axis reconstructs the
            # padded arena exactly (block-local math: bit-identical to
            # the one-launch-per-span dispatch).
            D, span_pad = part.n_shards, part.span_pad
            pad = part.padded_total - part.total

            def bucket_slice(a, k0, k1):
                a = jnp.asarray(a)
                if pad:
                    a = jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
                a3 = a.reshape((D, span_pad) + a.shape[1:])
                return a3[:, k0:k1].reshape((D * (k1 - k0),) + a.shape[1:])

            per_bucket = []
            for i, (k0, k1) in enumerate(plan.ranges):
                ck = k1 - k0
                bpart = ArenaPartition(
                    n_shards=D, total=D * ck, span_pad=ck,
                    spans=tuple((d * ck, ck) for d in range(D)))
                with _tracing.annotate(f"bucket{i}"):
                    per_bucket.append(_rules.shard_map_over_spans(
                        mesh, axis, bpart, local,
                        [bucket_slice(a, k0, k1) for a in spans], consts))
            outs = []
            for pos in range(len(per_bucket[0])):
                chunks = [b[pos].reshape((D, -1) + b[pos].shape[1:])
                          for b in per_bucket]
                stitched = jnp.concatenate(chunks, axis=1)
                outs.append(stitched.reshape(
                    (part.padded_total,) + stitched.shape[2:])[:part.total])
            outs = tuple(outs)
        p2, cm2, am2 = outs[0], outs[1], outs[2]
        if nc_m is not None:
            cm2 = PackedCodes(cm2, bits_m, nc_m)
        cr2 = ar2 = None
        if two:
            cr2, ar2 = outs[3], outs[4]
            if nc_r is not None:
                cr2 = PackedCodes(cr2, bits_r, nc_r)
        health = outs[5 if two else 3] if cfg.sentinel else None
        return kfu.FusedUpdateResult(p2, cm2, am2, cr2, ar2, health)

    def _route_matrix_leaf(self, owner: int, leaf: Quant8Leaf, g, lr,
                           step_f, seed, gnorm_scale):
        """Whole-leaf owner routing for muon matrix leaves under the
        partitioned dispatch (DESIGN.md §12): on a matching mesh, only the
        owner device runs the Newton–Schulz update; the result broadcasts
        to the replicas (exact — codes are small integers in f32).
        Without a mesh every device computes it, identically."""
        part_shards = max(self.cfg.partition_shards, 1)
        mesh = self._partition_mesh(part_shards)
        fn = self._apply_quant8
        if mesh is None:
            return fn(leaf, g, lr, step_f, seed, gnorm_scale)
        from repro.sharding import rules as _rules
        return _rules.owner_routed(
            mesh, self.cfg.partition_axes, owner, fn,
            (leaf, g, lr, step_f, seed, gnorm_scale))

    def _apply_pooled(self, grads: Pytree, state: OptState, lr, step_f,
                      base_seed, gnorm_scale):
        """One fused_update for the whole QuantArena + one jnp update for
        the Pool32Arena; per-leaf Full32 overrides ride along unchanged.
        Seeds, element indices and trust ratios are threaded per block /
        per segment so the result is bit-identical to the per-leaf
        dispatch (tests/test_pooled.py).

        ``grads`` is either the param-shaped grad pytree or a
        :class:`GradBuffer` (ZeRO-2, DESIGN.md §13) — the buffer already
        holds the arena leaves' grads in the flat block domain, so the
        per-leaf flatten/concat (and its replicated materialization) is
        skipped entirely; ride-along leaves read their param-shaped grads
        from ``buf.ride``."""
        cfg = self.cfg
        mdt = jnp.dtype(cfg.master_dtype)
        buf = grads if isinstance(grads, GradBuffer) else None
        # (N_HEALTH,) HealthFlags contributions from every dispatch this
        # step; summed at the end when cfg.sentinel (DESIGN.md §16).
        health_parts: list = []

        # Walk the leaves once, in flatten order — the same order the
        # per-leaf dispatch numbers its leaves, so seed i matches.
        entries: list = []
        idx = [0]

        def collect(leaf, g):
            entries.append((leaf, g, idx[0]))
            idx[0] += 1
            return leaf

        if buf is None:
            jax.tree_util.tree_map(collect, state.leaves, grads,
                                   is_leaf=_is_state_leaf)
        else:
            layout = iter(buf.layout)

            def collect_buf(leaf):
                ent = next(layout)
                g = buf.ride[ent[1]] if ent[0] == "ride" else None
                return collect(leaf, g)

            jax.tree_util.tree_map(collect_buf, state.leaves,
                                   is_leaf=_is_state_leaf)

        new_arena, res_p = state.arena, None
        if state.arena is not None:
            arena = state.arena
            quant = [(l, g, i) for l, g, i in entries
                     if isinstance(l, PooledQuantLeaf)]
            mbs, seeds, offs = [], [], []
            gbs = [] if buf is None else None
            for leaf, g, i in quant:
                if gbs is not None:
                    gbs.append(flatten_to_blocks(g, cfg.block_size,
                                                 cfg.shard_multiple))
                mbs.append(flatten_to_blocks(leaf.master, cfg.block_size,
                                             cfg.shard_multiple))
                seeds.append(jnp.broadcast_to(
                    base_seed + jnp.int32(i * 7919), (leaf.n_blocks,)))
                offs.append(np.arange(leaf.n_blocks, dtype=np.int32))
            mb = _constrain(jnp.concatenate(mbs), "all", None)
            if buf is None:
                gb = _constrain(jnp.concatenate(gbs), "all", None)
            else:
                # already in arena layout, owned-span sharded — never
                # rebuilt replicated (the ZeRO-2 point)
                gb = buf.blocks[:mb.shape[0]]
            block_seeds = jnp.concatenate(seeds)
            block_offsets = jnp.asarray(np.concatenate(offs))
            segs = tuple((s.offset, s.n_blocks) for s in arena.segments)
            if arena.partition is not None and cfg.partition_active:
                res = self._fused_update_partitioned(
                    arena, mb, gb, block_seeds, block_offsets, segs, lr,
                    step_f, gnorm_scale)
            else:
                res = kops.fused_update(
                    self._ew_algo, mb, gb, arena.codes_m, arena.absmax_m,
                    arena.codes_r, arena.absmax_r, self._qmap1, self._qmap2,
                    lr=lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
                    weight_decay=cfg.weight_decay, step=step_f,
                    trust_coeff=cfg.trust_coeff, gnorm_scale=gnorm_scale,
                    blockwise=True, stochastic=cfg.stochastic_rounding,
                    block_seeds=block_seeds, block_offsets=block_offsets,
                    segments=segs, impl=self._impl, sentinel=cfg.sentinel)
            if cfg.sentinel:
                health_parts.append(jnp.sum(res.health, axis=0))
            new_arena = dataclasses.replace(
                arena, codes_m=res.codes_m, absmax_m=res.absmax_m,
                codes_r=res.codes_r if res.codes_r is not None
                else arena.codes_r,
                absmax_r=res.absmax_r if res.absmax_r is not None
                else arena.absmax_r)
            res_p = res.p

        new_pool = state.pool32
        if state.pool32 is not None:
            small_g = [g for l, g, i in entries if isinstance(l, Pool32Leaf)]
            gflat = (jnp.concatenate([g.reshape(-1).astype(jnp.float32)
                                      for g in small_g])
                     if len(small_g) > 1
                     else small_g[0].reshape(-1).astype(jnp.float32))
            new_pool = self._apply_pool32(state.pool32, gflat * gnorm_scale,
                                          lr, step_f)
            if cfg.sentinel:
                # fp32 pool has no codes/absmax; nonfinite grad/update only
                # (raw grads — pre gnorm_scale, as everywhere else).
                nf = lambda x: jnp.sum((~jnp.isfinite(x))
                                       .astype(jnp.float32))
                h8 = jnp.zeros((kfu.N_HEALTH,), jnp.float32)
                health_parts.append(
                    h8.at[0].set(nf(gflat)).at[1].set(nf(new_pool.master)))

        # Second walk re-plays the same flatten order as `collect`, so each
        # ride-along leaf recovers its flatten index i — per-leaf seeds
        # (base + i*7919) therefore match the per-leaf dispatch bit-exactly.
        # Grads come from the entries (works for both pytree and GradBuffer
        # input — the walk is over the leaves alone).
        ent = iter(entries)
        mk = [0]   # matrix-leaf counter: k-th matrix leaf -> owner k % D

        def upd(leaf):
            _, g, i = next(ent)
            if isinstance(leaf, PooledQuantLeaf):
                sl = res_p[leaf.offset:leaf.offset + leaf.n_blocks]
                return dataclasses.replace(
                    leaf, master=blocks_to_param(sl, leaf.shape, leaf.n, mdt))
            if isinstance(leaf, Pool32Leaf):
                return leaf
            if isinstance(leaf, Quant8Leaf):
                # matrix-class (muon) leaves stay per-leaf under the pooled
                # dispatch: each is its own Newton–Schulz problem
                # (DESIGN.md §11).  Partitioned, each is routed whole-leaf
                # to its owner (DESIGN.md §12) — same math, same seed.
                seed = base_seed + jnp.int32(i * 7919)
                if cfg.partition_active:
                    owner = mk[0] % max(cfg.partition_shards, 1)
                    mk[0] += 1
                    out = self._route_matrix_leaf(owner, leaf, g, lr,
                                                  step_f, seed, gnorm_scale)
                else:
                    out = self._apply_quant8(leaf, g, lr, step_f, seed,
                                             gnorm_scale)
            else:
                out = self._apply_full32(leaf, g, lr, step_f, gnorm_scale)
            if cfg.sentinel:
                out, h8 = out
                health_parts.append(h8)
            return out

        new_leaves = jax.tree_util.tree_map(upd, state.leaves,
                                            is_leaf=_is_state_leaf)
        health = _sum_health(health_parts) if cfg.sentinel else None
        return new_leaves, new_arena, new_pool, health

    def apply(self, grads: Pytree, state: OptState, *,
              lr: Optional[jax.Array] = None,
              param_dtype=jnp.float32,
              key: Optional[jax.Array] = None,
              materialize_params: bool = True) -> tuple[Pytree, OptState]:
        """One optimizer step. Returns (new model-shape params, new state).

        ``lr`` overrides cfg.lr (schedules); ``param_dtype`` is the dtype of
        the returned model params (the f32 master stays in the state).
        ``key`` optionally seeds stochastic rounding; when omitted the seed
        is derived from ``state.step``, so restarts from a checkpoint replay
        the same rounding decisions bit-exactly.

        ``grads`` may be a :class:`GradBuffer` (ZeRO-2, DESIGN.md §13;
        pooled layouts only).  ``materialize_params=False`` skips the
        model-shape params reconstruction and returns ``(None, state)`` —
        the deferred-all-gather path: the caller reconstructs via
        :meth:`params_view` at first use (top of the next step), so the
        masters' all-gather overlaps the next forward instead of extending
        this step's tail.

        With ``cfg.sentinel`` (DESIGN.md §16) the return is a 3-tuple
        ``(params, state, health)`` where ``health`` is the summed
        (``kfu.N_HEALTH``,) f32 HealthFlags vector over every dispatch of
        this step (``kfu.HEALTH_SLOTS`` layout).  The OptState pytree is
        unchanged either way — checkpoints and goldens are sentinel-blind.
        """
        cfg = self.cfg
        if isinstance(grads, GradBuffer) and not cfg.pooling_active:
            raise ConfigError(
                "GradBuffer input requires the pooled layout (shard_grads)")
        lr = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)
        step_f = (state.step + 1).astype(jnp.float32)
        gnorm_scale, new_vec = self.percentile_clip(grads, state)

        if cfg.stochastic_rounding and key is not None:
            base_seed = jax.random.randint(key, (), 0, 2**31 - 1,
                                           dtype=jnp.int32)
        else:
            # int32 wraparound is fine: the seed only feeds a hash.
            base_seed = state.step.astype(jnp.int32) * jnp.int32(1000003)

        if cfg.pooling_active:
            new_leaves, new_arena, new_pool, health = self._apply_pooled(
                grads, state, lr, step_f, base_seed, gnorm_scale)
        else:
            leaf_idx = [0]
            health_parts: list = []

            def upd(leaf, g):
                i = leaf_idx[0]
                leaf_idx[0] += 1
                seed = base_seed + jnp.int32(i * 7919)
                if isinstance(leaf, Quant8Leaf):
                    out = self._apply_quant8(leaf, g, lr, step_f, seed,
                                             gnorm_scale)
                else:
                    out = self._apply_full32(leaf, g, lr, step_f,
                                             gnorm_scale)
                if cfg.sentinel:
                    out, h8 = out
                    health_parts.append(h8)
                return out

            new_leaves = jax.tree_util.tree_map(
                upd, state.leaves, grads, is_leaf=_is_state_leaf)
            new_arena, new_pool = state.arena, state.pool32
            health = _sum_health(health_parts) if cfg.sentinel else None

        new_state = OptState(step=state.step + 1, leaves=new_leaves,
                             gnorm_vec=new_vec, arena=new_arena,
                             pool32=new_pool)
        if not materialize_params:
            return (None, new_state, health) if cfg.sentinel \
                else (None, new_state)
        params = self.params_view(new_state, param_dtype)
        return (params, new_state, health) if cfg.sentinel \
            else (params, new_state)

    def params_view(self, state: OptState, param_dtype=jnp.float32) -> Pytree:
        """Model-shape params reconstructed from the (sharded, flat-block)
        master copies — ZeRO-3 style: no persistent model-shape duplicate;
        XLA inserts the all-gather at use sites.  Pooled small leaves are
        sliced out of the Pool32Arena."""
        pool = state.pool32

        def to_param(leaf):
            if isinstance(leaf, Pool32Leaf):
                sl = pool.master[leaf.offset:leaf.offset + leaf.n]
                return sl.reshape(leaf.shape).astype(param_dtype)
            return leaf.master.astype(param_dtype)

        # the deferred all-gather site (DESIGN.md §13d): reconstructing
        # the model-shape view is where sharded masters re-materialize
        with _tracing.annotate("params_allgather"):
            return jax.tree_util.tree_map(to_param, state.leaves,
                                          is_leaf=_is_state_leaf)

    # ------------------------------------------------------------- utilities
    def state_bytes(self, state: OptState) -> dict:
        """Measured memory of optimizer statistics vs 32-bit equivalent.

        Only static shapes are read, so this also works on abstract/traced
        states (the train loop surfaces ``state_bytes_per_param`` as a
        metric from inside the jitted step)."""

        def codes_bytes(c):
            return c.nbytes() if isinstance(c, PackedCodes) else c.size

        stats = master = n_params = 0
        for leaf in jax.tree_util.tree_leaves(state.leaves,
                                              is_leaf=_is_state_leaf):
            if isinstance(leaf, Quant8Leaf):
                stats += codes_bytes(leaf.codes_m) + leaf.absmax_m.size * 4
                if leaf.codes_r is not None:
                    stats += codes_bytes(leaf.codes_r) + leaf.absmax_r.size * 4
                master += leaf.master.size * leaf.master.dtype.itemsize
                n_params += leaf.n
            elif isinstance(leaf, PooledQuantLeaf):
                # quantized statistics counted once via the arena below
                master += leaf.master.size * leaf.master.dtype.itemsize
                n_params += leaf.n
            elif isinstance(leaf, Pool32Leaf):
                pass  # all state counted via the Pool32Arena below
            else:
                stats += leaf.m.size * 4 + (leaf.r.size * 4 if leaf.r is not None else 0)
                master += leaf.master.size * 4
                n_params += leaf.master.size
        arena = getattr(state, "arena", None)
        if arena is not None:
            stats += codes_bytes(arena.codes_m) + arena.absmax_m.size * 4
            if arena.codes_r is not None:
                stats += codes_bytes(arena.codes_r) + arena.absmax_r.size * 4
        pool = getattr(state, "pool32", None)
        if pool is not None:
            stats += pool.m.size * 4 + (pool.r.size * 4
                                        if pool.r is not None else 0)
            master += pool.master.size * 4
            n_params += pool.master.size
        out = {"state_bytes": int(stats), "master_bytes": int(master),
               "n_params": int(n_params)}
        owned = self._owned_state_bytes(state)
        if owned is not None:
            out.update(owned)
        return out

    def _owned_state_bytes(self, state: OptState) -> Optional[dict]:
        """Partitioned (ZeRO-1) per-device accounting (DESIGN.md §12):
        the largest owner's share of the quantized statistics — its arena
        block span plus the matrix leaves it owns — with the (replicated,
        tiny) fp32 pool and any per-leaf Full32 override counted in full.
        None when partitioning is inactive."""
        arena = getattr(state, "arena", None)
        part = getattr(arena, "partition", None) if arena is not None else None
        if part is None or not self.cfg.partition_active:
            return None

        def codes_bytes_per_block(c):
            if isinstance(c, PackedCodes):
                return c.nbytes() // c.packed.shape[0]
            return int(np.prod(c.shape[1:])) or 1

        per_block = codes_bytes_per_block(arena.codes_m) + 4
        if arena.codes_r is not None:
            per_block += codes_bytes_per_block(arena.codes_r) + 4
        owner_bytes = [n * per_block for _, n in part.spans]
        # muon matrix leaves: whole-leaf ownership, k-th leaf -> k % D
        matrix = [l for l in jax.tree_util.tree_leaves(
            state.leaves, is_leaf=_is_state_leaf)
            if isinstance(l, Quant8Leaf)]
        for k, leaf in enumerate(matrix):
            b = (leaf.codes_m.nbytes()
                 if isinstance(leaf.codes_m, PackedCodes)
                 else leaf.codes_m.size) + leaf.absmax_m.size * 4
            if leaf.codes_r is not None:
                b += (leaf.codes_r.nbytes()
                      if isinstance(leaf.codes_r, PackedCodes)
                      else leaf.codes_r.size) + leaf.absmax_r.size * 4
            owner_bytes[k % part.n_shards] += b
        # replicated remainder: fp32 pool + per-leaf Full32 overrides
        rep = 0
        pool = getattr(state, "pool32", None)
        if pool is not None:
            rep += pool.m.size * 4 + (pool.r.size * 4
                                      if pool.r is not None else 0)
        for leaf in jax.tree_util.tree_leaves(state.leaves,
                                              is_leaf=_is_state_leaf):
            if isinstance(leaf, Full32Leaf):
                rep += leaf.m.size * 4 + (leaf.r.size * 4
                                          if leaf.r is not None else 0)
        return {"partition_shards": part.n_shards,
                "owned_blocks": part.max_owned,
                "owned_state_bytes": int(max(owner_bytes) + rep)}


def _sum_health(parts):
    """Sum per-dispatch (N_HEALTH,) HealthFlags vectors.  Counts are f32
    integers, so the addition is exact in any order (DESIGN.md §16)."""
    if not parts:
        return jnp.zeros((kfu.N_HEALTH,), jnp.float32)
    total = parts[0]
    for p in parts[1:]:
        total = total + p
    return total


def _concat_span_results(outs):
    """Stitch per-span FusedUpdateResults back into the arena layout
    (device-side concat along the block dim, PackedCodes-aware)."""
    if not outs:
        raise FormatError("no non-empty spans to stitch")
    if len(outs) == 1:
        return outs[0]

    def cat(field):
        parts = [getattr(o, field) for o in outs]
        if parts[0] is None:
            return None
        if isinstance(parts[0], PackedCodes):
            return PackedCodes(
                jnp.concatenate([p.packed for p in parts]),
                parts[0].bits, parts[0].n_codes)
        return jnp.concatenate(parts)

    return kfu.FusedUpdateResult(*(cat(f)
                                   for f in kfu.FusedUpdateResult._fields))


# ------------------------------------------------ pooled <-> per-leaf views
# Checkpoints always store the per-leaf canonical layout: `unpool_state`
# slices arenas back into Quant8Leaf / Full32Leaf containers (save side),
# `repool_like` concatenates restored per-leaf arrays into the template's
# arena layout (restore side).  Both work leaf-by-leaf from the static
# segment metadata, so the on-disk format is independent of `cfg.pooled`
# and old per-leaf checkpoints restore into pooled states and vice versa.


def _slice_blocks(x, off: int, nb: int):
    """Block-dim slice [off, off+nb) of an arena child; shape-only on
    ShapeDtypeStruct templates, rewrapping PackedCodes containers."""
    if isinstance(x, PackedCodes):
        return PackedCodes(_slice_blocks(x.packed, off, nb), x.bits,
                           x.n_codes)
    if isinstance(x, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct((nb,) + tuple(x.shape[1:]), x.dtype)
    return x[off:off + nb]


def _slice_flat(x, off: int, n: int, shape: tuple):
    if isinstance(x, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct(tuple(shape), x.dtype)
    return x[off:off + n].reshape(shape)


def unpool_state(state: OptState) -> OptState:
    """Pooled layout -> per-leaf canonical layout (identity for per-leaf
    states).  Accepts concrete arrays or ShapeDtypeStruct templates."""
    arena, pool = state.arena, state.pool32
    if arena is None and pool is None:
        return state

    def conv(leaf):
        if isinstance(leaf, PooledQuantLeaf):
            o, nb = leaf.offset, leaf.n_blocks
            return Quant8Leaf(
                master=leaf.master,
                codes_m=_slice_blocks(arena.codes_m, o, nb),
                absmax_m=_slice_blocks(arena.absmax_m, o, nb),
                codes_r=None if arena.codes_r is None
                else _slice_blocks(arena.codes_r, o, nb),
                absmax_r=None if arena.absmax_r is None
                else _slice_blocks(arena.absmax_r, o, nb),
                shape=leaf.shape, n=leaf.n)
        if isinstance(leaf, Pool32Leaf):
            return Full32Leaf(
                master=_slice_flat(pool.master, leaf.offset, leaf.n,
                                   leaf.shape),
                m=_slice_flat(pool.m, leaf.offset, leaf.n, leaf.shape),
                r=None if pool.r is None
                else _slice_flat(pool.r, leaf.offset, leaf.n, leaf.shape))
        return leaf

    leaves = jax.tree_util.tree_map(conv, state.leaves,
                                    is_leaf=_is_state_leaf)
    return OptState(step=state.step, leaves=leaves,
                    gnorm_vec=state.gnorm_vec, arena=None, pool32=None)


def _concat_rows(parts, like):
    """Host-side concat of per-leaf arena rows, honouring PackedCodes."""
    if isinstance(like, PackedCodes):
        return PackedCodes(
            np.concatenate([np.asarray(p.packed) for p in parts]),
            like.bits, like.n_codes)
    return np.concatenate([np.asarray(p) for p in parts])


def repool_like(per_leaf: OptState, template: OptState) -> OptState:
    """Per-leaf state -> the pooled layout of ``template`` (identity when
    the template is per-leaf).  Used by elastic checkpoint restore; array
    data is concatenated on the host, placement happens afterwards."""
    t_arena, t_pool = template.arena, template.pool32
    if t_arena is None and t_pool is None:
        return per_leaf
    by_block: dict = {}
    by_flat: dict = {}

    def onto(tmpl_leaf, got):
        if isinstance(tmpl_leaf, PooledQuantLeaf):
            by_block[tmpl_leaf.offset] = got
            return dataclasses.replace(tmpl_leaf, master=got.master)
        if isinstance(tmpl_leaf, Pool32Leaf):
            by_flat[tmpl_leaf.offset] = got
            return tmpl_leaf
        return got

    leaves = jax.tree_util.tree_map(onto, template.leaves, per_leaf.leaves,
                                    is_leaf=_is_state_leaf)
    arena = None
    if t_arena is not None:
        parts = [by_block[s.offset] for s in t_arena.segments]
        arena = QuantArena(
            codes_m=_concat_rows([p.codes_m for p in parts],
                                 t_arena.codes_m),
            absmax_m=_concat_rows([p.absmax_m for p in parts],
                                  t_arena.absmax_m),
            codes_r=None if t_arena.codes_r is None
            else _concat_rows([p.codes_r for p in parts], t_arena.codes_r),
            absmax_r=None if t_arena.absmax_r is None
            else _concat_rows([p.absmax_r for p in parts],
                              t_arena.absmax_r),
            segments=t_arena.segments, partition=t_arena.partition)
    pool = None
    if t_pool is not None:
        parts = [by_flat[s.offset] for s in t_pool.segments]

        def flat(xs):
            return np.concatenate([np.asarray(x).reshape(-1) for x in xs])

        pool = Pool32Arena(
            master=flat([p.master for p in parts]),
            m=flat([p.m for p in parts]),
            r=None if t_pool.r is None else flat([p.r for p in parts]),
            segments=t_pool.segments, partition=t_pool.partition)
    return OptState(step=per_leaf.step, leaves=leaves,
                    gnorm_vec=per_leaf.gnorm_vec, arena=arena, pool32=pool)


def map_opt_states(tree, fn):
    """Apply ``fn`` to every OptState inside a checkpointable container
    tree (dicts / lists / (named)tuples), leaving everything else alone."""
    if isinstance(tree, OptState):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: map_opt_states(v, fn) for k, v in tree.items()}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return type(tree)(*(map_opt_states(v, fn) for v in tree))
    if isinstance(tree, (list, tuple)):
        return type(tree)(map_opt_states(v, fn) for v in tree)
    return tree


def zip_opt_states(tree, template, fn):
    """Parallel walk of ``tree`` and ``template``; applies ``fn(sub,
    template_sub)`` wherever the template holds an OptState."""
    if isinstance(template, OptState):
        return fn(tree, template)
    if isinstance(template, dict):
        return {k: zip_opt_states(tree[k], v, fn)
                for k, v in template.items()}
    if isinstance(template, tuple) and hasattr(template, "_fields"):
        return type(template)(*(zip_opt_states(t, v, fn)
                                for t, v in zip(tree, template)))
    if isinstance(template, (list, tuple)):
        return type(template)(zip_opt_states(t, v, fn)
                              for t, v in zip(tree, template))
    return tree
