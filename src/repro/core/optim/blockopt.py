"""The paper's 8-bit optimizers (and their 32-bit twins) as one engine.

``Block8bitOptimizer`` implements Adam/AdamW/Momentum/LAMB/LARS/AdaGrad with
per-leaf state that is either block-wise 8-bit quantized (``Quant8Leaf``) or
full 32-bit (``Full32Leaf`` — used for the 32-bit baselines, for leaves below
``min_8bit_size``, and for leaves matched by the stable-embedding override,
paper §2.3).

The update is the paper's §2 procedure: dequantize -> 32-bit math ->
requantize, executed by the fused Pallas kernel on TPU (``impl='pallas'``) or
by the identical jnp math elsewhere.

State signedness per algorithm (paper §2.2: the strictly-positive second
moment uses the unsigned dynamic map with the sign bit re-purposed as an
extra fraction bit):

  adam/adamw/lamb : m -> signed dynamic, r -> unsigned dynamic
  momentum/lars   : m -> signed dynamic
  adagrad         : accumulator -> unsigned dynamic (stored in the m slot)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import qmap as qmap_lib
from repro.core.optim import base
from repro.core.optim.base import (Full32Leaf, OptimConfig, Quant8Leaf,
                                   blocks_to_param, flatten_to_blocks,
                                   path_str)
from repro.models.constrain import constrain as _constrain
from repro.kernels import ops as kops

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array           # int32 scalar, number of updates applied
    leaves: Pytree            # tree of Quant8Leaf / Full32Leaf


def _state1_signed(algo: str) -> bool:
    return algo != "adagrad"


class Block8bitOptimizer:
    """init/apply optimizer owning the f32 master copy of the params."""

    def __init__(self, config: OptimConfig,
                 override_32bit: Optional[Callable[[str], bool]] = None):
        self.cfg = config
        self.override_32bit = override_32bit or (lambda path: False)
        signed1 = _state1_signed(config.algo)
        self._qmap1 = jnp.asarray(
            qmap_lib.get_qmap(config.qmap_m if signed1 else config.qmap_r, signed1))
        self._qmap2 = jnp.asarray(qmap_lib.get_qmap(config.qmap_r, False))
        self._impl = config.impl or kops.default_impl()

    # ------------------------------------------------------------------ init
    def _leaf_is_8bit(self, path: str, param: jax.Array) -> bool:
        if self.cfg.bits == 32:
            return False
        if param.size < self.cfg.min_8bit_size:
            return False
        return not self.override_32bit(path)

    def init(self, params: Pytree) -> OptState:
        cfg = self.cfg

        def init_leaf(path, p):
            path = path_str(path)
            if self._leaf_is_8bit(path, p):
                # master stays in PARAM SHAPE (sharded like the param) so the
                # fwd/bwd sees per-layer gathers inside the scan; only the
                # 8-bit statistics live in the flat block domain.  (The
                # flat-master variant all-gathered the whole tensor per step:
                # EXPERIMENTS.md §Perf iteration A2.)
                master = p.astype(jnp.dtype(cfg.master_dtype))
                nb = base.n_blocks_for(p.shape, cfg.block_size,
                                       cfg.shard_multiple)
                bs = cfg.block_size
                zc1 = jnp.asarray(jnp.argmin(jnp.abs(self._qmap1)), jnp.uint8)
                zc2 = jnp.asarray(jnp.argmin(jnp.abs(self._qmap2)), jnp.uint8)
                second = cfg.has_second_moment
                return Quant8Leaf(
                    master=master,
                    codes_m=jnp.full((nb, bs), zc1, jnp.uint8),
                    absmax_m=jnp.zeros((nb,), jnp.float32),
                    codes_r=jnp.full((nb, bs), zc2, jnp.uint8) if second else None,
                    absmax_r=jnp.zeros((nb,), jnp.float32) if second else None,
                    shape=tuple(p.shape), n=int(p.size))
            master = p.astype(jnp.float32)
            return Full32Leaf(
                master=master,
                m=jnp.zeros_like(master),
                r=jnp.zeros_like(master) if cfg.has_second_moment else None)

        leaves = jax.tree_util.tree_map_with_path(init_leaf, params)
        return OptState(step=jnp.zeros((), jnp.int32), leaves=leaves)

    # ------------------------------------------------------------- algorithms
    def _math32(self, g, p, m, r, lr, step_f):
        """Shared 32-bit update math; returns (m', r', p')."""
        cfg = self.cfg
        algo = cfg.algo
        if algo in ("adam", "adamw", "lamb"):
            m2 = cfg.beta1 * m + (1.0 - cfg.beta1) * g
            r2 = cfg.beta2 * r + (1.0 - cfg.beta2) * g * g
            c1 = 1.0 - cfg.beta1 ** step_f
            c2 = 1.0 - cfg.beta2 ** step_f
            upd = (m2 / c1) / (jnp.sqrt(r2 / c2) + cfg.eps) + cfg.weight_decay * p
            if algo == "lamb":
                pn = jnp.sqrt(jnp.sum(p * p))
                un = jnp.sqrt(jnp.sum(upd * upd))
                trust = jnp.where((pn > 0) & (un > 0), pn / jnp.where(un > 0, un, 1.0), 1.0)
                upd = trust * upd
            return m2, r2, p - lr * upd
        if algo == "momentum":
            m2 = cfg.beta1 * m + (g + cfg.weight_decay * p)
            return m2, None, p - lr * m2
        if algo == "lars":
            pn = jnp.sqrt(jnp.sum(p * p))
            gn = jnp.sqrt(jnp.sum(g * g))
            denom = gn + cfg.weight_decay * pn + 1e-12
            local = jnp.where(pn > 0, cfg.trust_coeff * pn / denom, 1.0)
            m2 = cfg.beta1 * m + local * (g + cfg.weight_decay * p)
            return m2, None, p - lr * m2
        if algo == "adagrad":
            # accumulator lives in the m slot (unsigned map)
            m2 = m + g * g
            upd = g / (jnp.sqrt(m2) + cfg.eps) + cfg.weight_decay * p
            return m2, None, p - lr * upd
        raise ValueError(self.cfg.algo)

    # ---------------------------------------------------------------- update
    def _apply_quant8(self, leaf: Quant8Leaf, g: jax.Array, lr, step_f, key):
        cfg = self.cfg
        gb = flatten_to_blocks(g, cfg.block_size, cfg.shard_multiple)
        # Tell SPMD the reshard target up front: the flat block domain is
        # sharded over ALL mesh axes (EXPERIMENTS.md §Perf A1/A2).
        gb = _constrain(gb, "all", None)

        mdt = jnp.dtype(cfg.master_dtype)
        mb = flatten_to_blocks(leaf.master, cfg.block_size, cfg.shard_multiple)
        mb = _constrain(mb, "all", None)

        def back(p2_flat):
            return blocks_to_param(p2_flat, leaf.shape, leaf.n, mdt)

        use_kernel = (self._impl != "jnp" and cfg.algo in ("adam", "adamw", "momentum")
                      and cfg.blockwise_norm and not cfg.stochastic_rounding)
        if use_kernel and cfg.algo in ("adam", "adamw"):
            p2, cm, am, cr, ar = kops.adam8_update(
                mb, gb, leaf.codes_m, leaf.absmax_m, leaf.codes_r,
                leaf.absmax_r, self._qmap1, self._qmap2, lr=lr, beta1=cfg.beta1,
                beta2=cfg.beta2, eps=cfg.eps, weight_decay=cfg.weight_decay,
                step=step_f, impl=self._impl)
            return dataclasses.replace(leaf, master=back(p2), codes_m=cm,
                                       absmax_m=am, codes_r=cr, absmax_r=ar)
        if use_kernel and cfg.algo == "momentum":
            p2, cm, am = kops.momentum8_update(
                mb, gb, leaf.codes_m, leaf.absmax_m,
                self._qmap1, lr=lr, beta1=cfg.beta1,
                weight_decay=cfg.weight_decay, step=step_f, impl=self._impl)
            return dataclasses.replace(leaf, master=back(p2), codes_m=cm,
                                       absmax_m=am)

        # jnp path (also used for lamb/lars/adagrad and all ablation modes)
        from repro.core import blockwise as bw
        m = bw.dequantize_blocks(leaf.codes_m, leaf.absmax_m, self._qmap1)
        r = (bw.dequantize_blocks(leaf.codes_r, leaf.absmax_r, self._qmap2)
             if leaf.codes_r is not None else None)
        m2, r2, p2 = self._math32(gb, mb.astype(jnp.float32), m, r,
                                  lr, step_f)
        p2 = back(p2)

        def requant(x, cb, key):
            if cfg.blockwise_norm:
                return bw.quantize_blocks(
                    x, cb, stochastic_rounding=cfg.stochastic_rounding, key=key)
            # tensor-wise ablation: single absmax for the whole tensor
            gmax = jnp.max(jnp.abs(x))
            scale = jnp.where(gmax > 0, gmax, 1.0)
            bounds = (cb[1:] + cb[:-1]) * 0.5
            codes = jnp.searchsorted(bounds, x / scale, side="right").astype(jnp.uint8)
            absmax = jnp.full((x.shape[0],), gmax, jnp.float32)
            return codes, absmax

        k1 = k2 = None
        if cfg.stochastic_rounding and key is not None:
            k1, k2 = jax.random.split(key)
        cm, am = requant(m2, self._qmap1, k1)
        new = dataclasses.replace(leaf, master=p2, codes_m=cm, absmax_m=am)
        if r2 is not None:
            cr, ar = requant(r2, self._qmap2, k2)
            new = dataclasses.replace(new, codes_r=cr, absmax_r=ar)
        return new

    def _apply_full32(self, leaf: Full32Leaf, g: jax.Array, lr, step_f):
        g = g.astype(jnp.float32)
        r = leaf.r if leaf.r is not None else None
        m2, r2, p2 = self._math32(g, leaf.master, leaf.m, r, lr, step_f)
        return Full32Leaf(master=p2, m=m2, r=r2)

    def apply(self, grads: Pytree, state: OptState, *,
              lr: Optional[jax.Array] = None,
              param_dtype=jnp.float32,
              key: Optional[jax.Array] = None) -> tuple[Pytree, OptState]:
        """One optimizer step. Returns (new model-shape params, new state).

        ``lr`` overrides cfg.lr (schedules); ``param_dtype`` is the dtype of
        the returned model params (the f32 master stays in the state).
        """
        lr = jnp.asarray(self.cfg.lr if lr is None else lr, jnp.float32)
        step_f = (state.step + 1).astype(jnp.float32)

        leaf_idx = [0]

        def upd(leaf, g):
            i = leaf_idx[0]
            leaf_idx[0] += 1
            k = jax.random.fold_in(key, i) if key is not None else None
            if isinstance(leaf, Quant8Leaf):
                return self._apply_quant8(leaf, g, lr, step_f, k)
            return self._apply_full32(leaf, g, lr, step_f)

        new_leaves = jax.tree_util.tree_map(
            upd, state.leaves, grads,
            is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf)))

        def to_param(leaf):
            return leaf.master.astype(param_dtype)

        new_params = jax.tree_util.tree_map(
            to_param, new_leaves,
            is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf)))
        return new_params, OptState(step=state.step + 1, leaves=new_leaves)

    def params_view(self, state: OptState, param_dtype=jnp.float32) -> Pytree:
        """Model-shape params reconstructed from the (sharded, flat-block)
        master copies — ZeRO-3 style: no persistent model-shape duplicate;
        XLA inserts the all-gather at use sites."""
        def to_param(leaf):
            return leaf.master.astype(param_dtype)
        return jax.tree_util.tree_map(
            to_param, state.leaves,
            is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf)))

    # ------------------------------------------------------------- utilities
    def state_bytes(self, state: OptState) -> dict:
        """Measured memory of optimizer statistics vs 32-bit equivalent."""
        stats = master = 0
        for leaf in jax.tree_util.tree_leaves(
                state.leaves,
                is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf))):
            if isinstance(leaf, Quant8Leaf):
                stats += leaf.codes_m.size + leaf.absmax_m.size * 4
                if leaf.codes_r is not None:
                    stats += leaf.codes_r.size + leaf.absmax_r.size * 4
                master += leaf.master.size * leaf.master.dtype.itemsize
            else:
                stats += leaf.m.size * 4 + (leaf.r.size * 4 if leaf.r is not None else 0)
                master += leaf.master.size * 4
        return {"state_bytes": int(stats), "master_bytes": int(master)}
