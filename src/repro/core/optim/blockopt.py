"""The paper's 8-bit optimizers (and their 32-bit twins) as one engine.

``Block8bitOptimizer`` implements Adam/AdamW/Momentum/LAMB/LARS/AdaGrad with
per-leaf state that is either block-wise 8-bit quantized (``Quant8Leaf``) or
full 32-bit (``Full32Leaf`` — used for the 32-bit baselines, for leaves below
``min_8bit_size``, and for leaves matched by the stable-embedding override,
paper §2.3).

The update is the paper's §2 procedure: dequantize -> 32-bit math ->
requantize, executed through the ``(algo, impl)`` registry behind
``repro.kernels.ops.fused_update``: one fused Pallas pass per state tensor
on TPU (``impl='pallas'``), the same kernels interpreted on CPU
(``impl='interpret'``), or the parameterized jnp oracle (``impl='jnp'``).
Every algorithm and every ablation mode (stochastic rounding, tensor-wise
quantization) takes this one path — there is no separate multi-pass
fallback anymore (DESIGN.md §3).

State signedness per algorithm (paper §2.2: the strictly-positive second
moment uses the unsigned dynamic map with the sign bit re-purposed as an
extra fraction bit):

  adam/adamw/lamb : m -> signed dynamic, r -> unsigned dynamic
  momentum/lars   : m -> signed dynamic
  adagrad         : accumulator -> unsigned dynamic (stored in the m slot)

Storage bitwidth is per state slot (``cfg.state_bits``; DESIGN.md §9): each
slot gets a :class:`~repro.core.lowbit.CodeFormat` whose 2^bits-entry
codebook and (for sub-byte widths) bit-packed ``PackedCodes`` container
flow through the same fused kernels — e.g. ``state_bits=(4, 8)`` stores a
4-bit first moment next to an 8-bit second moment (Li et al. 2023).

Optional percentile clipping (``cfg.percentile_clipping < 100``) maintains a
per-optimizer history of squared global gradient norms in
``OptState.gnorm_vec`` (bitsandbytes-style; DESIGN.md §7) and scales
gradients by a scalar inside the fused kernel — no extra pass over the
states.  The history is ordinary optimizer state: it is checkpointed and
restored like every other leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.lowbit import CodeFormat, PackedCodes
from repro.core.optim import base
from repro.core.optim.base import (Full32Leaf, OptimConfig, Quant8Leaf,
                                   blocks_to_param, flatten_to_blocks,
                                   path_str)
from repro.models.constrain import constrain as _constrain
from repro.kernels import fused_update as kfu
from repro.kernels import ops as kops

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array           # int32 scalar, number of updates applied
    leaves: Pytree            # tree of Quant8Leaf / Full32Leaf
    # (pclip_history,) f32 squared-gnorm history, or None when percentile
    # clipping is off (cfg.percentile_clipping == 100).
    gnorm_vec: Optional[jax.Array] = None


def _state1_signed(algo: str) -> bool:
    return algo != "adagrad"


class Block8bitOptimizer:
    """init/apply optimizer owning the f32 master copy of the params."""

    def __init__(self, config: OptimConfig,
                 override_32bit: Optional[Callable[[str], bool]] = None):
        self.cfg = config
        self.override_32bit = override_32bit or (lambda path: False)
        signed1 = _state1_signed(config.algo)
        bits1, bits2 = config.state_bits_pair
        self._fmt1 = CodeFormat(
            bits=bits1, signed=signed1,
            qmap_name=config.qmap_m if signed1 else config.qmap_r)
        self._fmt2 = CodeFormat(bits=bits2, signed=False,
                                qmap_name=config.qmap_r)
        self._qmap1 = jnp.asarray(self._fmt1.codebook())
        self._qmap2 = jnp.asarray(self._fmt2.codebook())
        self._impl = config.impl or kops.default_impl()

    # ------------------------------------------------------------------ init
    def _leaf_is_quantized(self, path: str, param: jax.Array) -> bool:
        if self.cfg.bits == 32:
            return False
        if param.size < self.cfg.min_quant_size:
            return False
        return not self.override_32bit(path)

    def init(self, params: Pytree) -> OptState:
        cfg = self.cfg

        def init_leaf(path, p):
            path = path_str(path)
            if self._leaf_is_quantized(path, p):
                # master stays in PARAM SHAPE (sharded like the param) so the
                # fwd/bwd sees per-layer gathers inside the scan; only the
                # quantized statistics live in the flat block domain.  (The
                # flat-master variant all-gathered the whole tensor per step:
                # EXPERIMENTS.md §Perf iteration A2.)
                master = p.astype(jnp.dtype(cfg.master_dtype))
                nb = base.n_blocks_for(p.shape, cfg.block_size,
                                       cfg.shard_multiple)
                bs = cfg.block_size
                second = cfg.has_second_moment
                return Quant8Leaf(
                    master=master,
                    codes_m=self._fmt1.init_codes(nb, bs),
                    absmax_m=jnp.zeros((nb,), jnp.float32),
                    codes_r=self._fmt2.init_codes(nb, bs) if second else None,
                    absmax_r=jnp.zeros((nb,), jnp.float32) if second else None,
                    shape=tuple(p.shape), n=int(p.size))
            master = p.astype(jnp.float32)
            return Full32Leaf(
                master=master,
                m=jnp.zeros_like(master),
                r=jnp.zeros_like(master) if cfg.has_second_moment else None)

        leaves = jax.tree_util.tree_map_with_path(init_leaf, params)
        gnorm_vec = (jnp.zeros((cfg.pclip_history,), jnp.float32)
                     if cfg.percentile_clipping < 100 else None)
        return OptState(step=jnp.zeros((), jnp.int32), leaves=leaves,
                        gnorm_vec=gnorm_vec)

    # ------------------------------------------------------------- algorithms
    def _math32(self, g, p, m, r, lr, step_f):
        """32-bit update math for Full32 leaves — the same parameterized
        update the fused kernels run (kernels/fused_update.update_math),
        with per-tensor norms computed inline.  Returns (m', r', p')."""
        cfg = self.cfg
        spec = kfu.ALGO_SPECS[cfg.algo]
        s = dict(lr=lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
                 weight_decay=cfg.weight_decay, step=step_f,
                 tensor_scale=jnp.float32(1.0))
        s["tensor_scale"] = kfu.tensor_scale_for(spec, g, p, m, r, s,
                                                 cfg.trust_coeff)
        return kfu.update_math(spec, g, p, m, r, s)

    # -------------------------------------------------------------- clipping
    def percentile_clip(self, grads: Pytree, state: OptState):
        """Percentile-clipping scale for this step (DESIGN.md §7).

        Returns ``(gnorm_scale, new_gnorm_vec)``: the scalar every gradient
        is multiplied by inside the fused kernel, and the updated squared-
        gnorm history.  No-op (scale 1, vec unchanged) when disabled.  The
        history (including the current step's norm) must fill before
        clipping engages, so the first ``pclip_history - 1`` steps are
        never clipped; a spike on the step that fills it can be."""
        cfg = self.cfg
        if cfg.percentile_clipping >= 100 or state.gnorm_vec is None:
            return jnp.float32(1.0), state.gnorm_vec
        gn2 = jnp.zeros((), jnp.float32)
        for leaf in jax.tree_util.tree_leaves(grads):
            gn2 = gn2 + jnp.sum(jnp.square(leaf.astype(jnp.float32)))
        hist = state.gnorm_vec
        new_vec = hist.at[jnp.mod(state.step, hist.shape[0])].set(gn2)
        clip2 = jnp.percentile(new_vec, cfg.percentile_clipping)
        warm = (state.step + 1) >= hist.shape[0]
        scale = jnp.where(
            warm & (gn2 > clip2),
            jnp.sqrt(jnp.maximum(clip2, 0.0) / jnp.maximum(gn2, 1e-30)), 1.0)
        return scale.astype(jnp.float32), new_vec

    # ---------------------------------------------------------------- update
    def _apply_quant8(self, leaf: Quant8Leaf, g: jax.Array, lr, step_f,
                      seed, gnorm_scale):
        cfg = self.cfg
        gb = flatten_to_blocks(g, cfg.block_size, cfg.shard_multiple)
        # Tell SPMD the reshard target up front: the flat block domain is
        # sharded over ALL mesh axes (EXPERIMENTS.md §Perf A1/A2).
        gb = _constrain(gb, "all", None)

        mdt = jnp.dtype(cfg.master_dtype)
        mb = flatten_to_blocks(leaf.master, cfg.block_size, cfg.shard_multiple)
        mb = _constrain(mb, "all", None)

        # One registry entry point for every algorithm and ablation mode;
        # tensor-wise quantization is dispatched to the jnp entry inside.
        res = kops.fused_update(
            cfg.algo, mb, gb, leaf.codes_m, leaf.absmax_m,
            leaf.codes_r, leaf.absmax_r, self._qmap1, self._qmap2,
            lr=lr, beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, step=step_f,
            trust_coeff=cfg.trust_coeff, gnorm_scale=gnorm_scale,
            blockwise=cfg.blockwise_norm,
            stochastic=cfg.stochastic_rounding, seed=seed, impl=self._impl)
        new = dataclasses.replace(
            leaf, master=blocks_to_param(res.p, leaf.shape, leaf.n, mdt),
            codes_m=res.codes_m, absmax_m=res.absmax_m)
        if res.codes_r is not None:
            new = dataclasses.replace(new, codes_r=res.codes_r,
                                      absmax_r=res.absmax_r)
        return new

    def _apply_full32(self, leaf: Full32Leaf, g: jax.Array, lr, step_f,
                      gnorm_scale):
        g = g.astype(jnp.float32) * gnorm_scale
        r = leaf.r if leaf.r is not None else None
        m2, r2, p2 = self._math32(g, leaf.master, leaf.m, r, lr, step_f)
        return Full32Leaf(master=p2, m=m2, r=r2)

    def apply(self, grads: Pytree, state: OptState, *,
              lr: Optional[jax.Array] = None,
              param_dtype=jnp.float32,
              key: Optional[jax.Array] = None) -> tuple[Pytree, OptState]:
        """One optimizer step. Returns (new model-shape params, new state).

        ``lr`` overrides cfg.lr (schedules); ``param_dtype`` is the dtype of
        the returned model params (the f32 master stays in the state).
        ``key`` optionally seeds stochastic rounding; when omitted the seed
        is derived from ``state.step``, so restarts from a checkpoint replay
        the same rounding decisions bit-exactly.
        """
        cfg = self.cfg
        lr = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)
        step_f = (state.step + 1).astype(jnp.float32)
        gnorm_scale, new_vec = self.percentile_clip(grads, state)

        if cfg.stochastic_rounding and key is not None:
            base_seed = jax.random.randint(key, (), 0, 2**31 - 1,
                                           dtype=jnp.int32)
        else:
            # int32 wraparound is fine: the seed only feeds a hash.
            base_seed = state.step.astype(jnp.int32) * jnp.int32(1000003)

        leaf_idx = [0]

        def upd(leaf, g):
            i = leaf_idx[0]
            leaf_idx[0] += 1
            seed = base_seed + jnp.int32(i * 7919)
            if isinstance(leaf, Quant8Leaf):
                return self._apply_quant8(leaf, g, lr, step_f, seed,
                                          gnorm_scale)
            return self._apply_full32(leaf, g, lr, step_f, gnorm_scale)

        new_leaves = jax.tree_util.tree_map(
            upd, state.leaves, grads,
            is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf)))

        def to_param(leaf):
            return leaf.master.astype(param_dtype)

        new_params = jax.tree_util.tree_map(
            to_param, new_leaves,
            is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf)))
        return new_params, OptState(step=state.step + 1, leaves=new_leaves,
                                    gnorm_vec=new_vec)

    def params_view(self, state: OptState, param_dtype=jnp.float32) -> Pytree:
        """Model-shape params reconstructed from the (sharded, flat-block)
        master copies — ZeRO-3 style: no persistent model-shape duplicate;
        XLA inserts the all-gather at use sites."""
        def to_param(leaf):
            return leaf.master.astype(param_dtype)
        return jax.tree_util.tree_map(
            to_param, state.leaves,
            is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf)))

    # ------------------------------------------------------------- utilities
    def state_bytes(self, state: OptState) -> dict:
        """Measured memory of optimizer statistics vs 32-bit equivalent.

        Only static shapes are read, so this also works on abstract/traced
        states (the train loop surfaces ``state_bytes_per_param`` as a
        metric from inside the jitted step)."""

        def codes_bytes(c):
            return c.nbytes() if isinstance(c, PackedCodes) else c.size

        stats = master = n_params = 0
        for leaf in jax.tree_util.tree_leaves(
                state.leaves,
                is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf))):
            if isinstance(leaf, Quant8Leaf):
                stats += codes_bytes(leaf.codes_m) + leaf.absmax_m.size * 4
                if leaf.codes_r is not None:
                    stats += codes_bytes(leaf.codes_r) + leaf.absmax_r.size * 4
                master += leaf.master.size * leaf.master.dtype.itemsize
                n_params += leaf.n
            else:
                stats += leaf.m.size * 4 + (leaf.r.size * 4 if leaf.r is not None else 0)
                master += leaf.master.size * 4
                n_params += leaf.master.size
        return {"state_bytes": int(stats), "master_bytes": int(master),
                "n_params": int(n_params)}
