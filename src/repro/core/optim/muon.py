"""Muon: the repo's first matrix optimizer, with k-bit quantized momentum.

``MuonOptimizer`` (Jordan et al. 2024; quantized states: Gupta et al. 2025,
"Effective Quantization of Muon Optimizer States") is a
``Block8bitOptimizer`` with a **per-leaf algorithm-routing split**
(DESIGN.md §11):

  * **matrix-class leaves** — 2-D params not matched by the 32-bit
    override — keep a single block-wise quantized momentum state
    (``Quant8Leaf`` with ``codes_r=None``; ``PackedCodes`` for sub-byte
    ``state_bits``).  Each step runs dequantize → nesterov momentum EMA →
    Newton–Schulz(5) orthogonalization (``kernels/newton_schulz.py``) →
    param update → blockwise requantize, through the same
    ``(algo, impl)`` registry entry point as every other algorithm
    (``ops.fused_update("muon", ...)``).  Muon is the hard single-state
    low-bit case (SOLO, Xu et al. 2025): there is no second moment to
    average out rounding error, so stochastic rounding matters most here.
  * **element-wise leaves** — 1-D/0-D params, embeddings (the stable-
    embedding override, which Muon excludes by convention anyway), and
    anything else — fall through to the existing fused **adamw** path,
    including the pooled ``QuantArena`` single dispatch (DESIGN.md §10):
    one fused launch covers all of them, with the matrix leaves dispatched
    per leaf alongside (each is its own Newton–Schulz problem).

Everything downstream is inherited unchanged: block-domain sharding (the
momentum leaf is a ``Quant8Leaf``, whose block dim shards over all mesh
axes), elastic checkpoint save/restore (per-leaf canonical layout),
``state_bytes`` metrics, percentile clipping, and the pooled ↔ per-leaf
bit-exactness contract (matrix leaves take identical per-leaf code paths
and flatten-order seeds in both layouts).

Matrix leaves below ``min_quant_size`` (or under ``bits=32`` — the
fp32-Muon baseline) keep fp32 momentum in a ``Full32Leaf`` with ``r=None``
and run the same Muon math in fp32; the state container thus encodes the
routing (a one-state 2-D leaf is a Muon leaf, a two-state leaf is adamw).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.optim import base
from repro.core.optim.base import Full32Leaf, OptimConfig, Quant8Leaf
from repro.errors import ConfigError
from repro.core.optim.blockopt import Block8bitOptimizer
from repro.kernels import newton_schulz as kns
from repro.kernels import ops as kops


class MuonOptimizer(Block8bitOptimizer):
    """Block8bitOptimizer whose 2-D leaves get Newton–Schulz-orthogonalized
    (quantized) momentum updates; all other leaves run fused adamw."""

    def __init__(self, config: OptimConfig,
                 override_32bit: Optional[Callable[[str], bool]] = None,
                 mesh=None):
        if config.algo != "muon":
            raise ConfigError(f"MuonOptimizer requires algo='muon', got "
                              f"{config.algo!r}")
        if not config.blockwise_norm:
            raise ValueError(
                "muon serves block-wise quantization only; the tensor-wise "
                "ablation is element-wise (DESIGN.md §11)")
        super().__init__(config, override_32bit=override_32bit, mesh=mesh)

    # ------------------------------------------------------------- routing
    def _elementwise_algo(self, algo: str) -> str:
        # Element-wise fallback leaves run adamw through the fused
        # registry / pooled arena; cfg.beta1/beta2/eps/weight_decay are
        # shared between the two classes.
        return "adamw"
    def _leaf_class(self, path: str, param: jax.Array) -> str:
        if param.ndim == 2 and not self.override_32bit(path):
            return "matrix"
        return "ew"

    def _init_matrix_leaf(self, path: str, param: jax.Array):
        cfg = self.cfg
        if self._leaf_is_quantized(path, param):
            nb = base.n_blocks_for(param.shape, cfg.block_size,
                                   cfg.shard_multiple)
            return Quant8Leaf(
                master=param.astype(jnp.dtype(cfg.master_dtype)),
                codes_m=self._fmt1.init_codes(nb, cfg.block_size),
                absmax_m=jnp.zeros((nb,), jnp.float32),
                codes_r=None, absmax_r=None,
                shape=tuple(param.shape), n=int(param.size))
        # fp32 momentum (sub-min_quant_size leaves and the bits=32
        # fp32-Muon baseline): one-state Full32Leaf, same Muon math.
        master = param.astype(jnp.float32)
        return Full32Leaf(master=master, m=jnp.zeros_like(master), r=None)

    # ------------------------------------------------------------- updates
    def _apply_quant8(self, leaf: Quant8Leaf, g: jax.Array, lr, step_f,
                      seed, gnorm_scale):
        if leaf.codes_r is None and len(leaf.shape) == 2:
            return self._apply_muon_leaf(leaf, g, lr, seed, gnorm_scale)
        return super()._apply_quant8(leaf, g, lr, step_f, seed, gnorm_scale)

    def _apply_muon_leaf(self, leaf: Quant8Leaf, g: jax.Array, lr, seed,
                         gnorm_scale):
        """One fused Muon step for a quantized matrix leaf: p/g stay in
        param (matrix) shape, the momentum state in the flat block domain
        (ops.fused_update handles the reshape at the requant boundary).
        Under ``cfg.sentinel`` returns ``(leaf, h8)`` like every per-leaf
        update (DESIGN.md §16)."""
        cfg = self.cfg
        res = kops.fused_update(
            "muon", leaf.master, g, leaf.codes_m, leaf.absmax_m,
            qmap_m=self._qmap1, lr=lr, beta1=cfg.beta1,
            weight_decay=cfg.weight_decay, gnorm_scale=gnorm_scale,
            stochastic=cfg.stochastic_rounding, seed=seed,
            ns_steps=cfg.ns_steps, impl=self._impl, sentinel=cfg.sentinel)
        new = dataclasses.replace(
            leaf, master=res.p.astype(jnp.dtype(cfg.master_dtype)),
            codes_m=res.codes_m, absmax_m=res.absmax_m)
        if cfg.sentinel:
            return new, jnp.sum(res.health, axis=0)
        return new

    def _math32(self, g, p, m, r, lr, step_f):
        """fp32 Muon math for one-state 2-D leaves (the same shared
        ``muon_math`` the quantized registry entry runs, so muon32 and
        muon8 cannot drift apart); everything else (the 2-state
        override/fallback leaves) is the inherited adamw math."""
        if r is None and p.ndim == 2:
            cfg = self.cfg
            m2, p2 = kns.muon_math(g, p, m, beta1=cfg.beta1, lr=lr,
                                   weight_decay=cfg.weight_decay,
                                   steps=cfg.ns_steps, impl=self._impl)
            return m2, None, p2
        return super()._math32(g, p, m, r, lr, step_f)
