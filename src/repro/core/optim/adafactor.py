"""Adafactor (Shazeer & Stern, 2018) — the paper's 32-bit memory-efficient
baseline, in the time-independent-beta2 formulation the paper compares against
(fixed beta2, first moment enabled, externally supplied lr).

Second moment is factored over the last two dims for ndim>=2 leaves
(row/col means), full for 1-D leaves.  First moment is full f32 (beta1>0,
matching the paper's comparison setting).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AdafactorLeaf:
    master: jax.Array                 # f32, model shape
    m: jax.Array                      # f32 first moment
    v_row: Optional[jax.Array]        # (..., rows) for ndim>=2
    v_col: Optional[jax.Array]        # (..., cols)
    v_full: Optional[jax.Array]       # for 1-D/0-D leaves

    def tree_flatten(self):
        return ((self.master, self.m, self.v_row, self.v_col, self.v_full), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class AdafactorState(NamedTuple):
    step: jax.Array
    leaves: Pytree


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps1: float = 1e-30     # regularization inside the factored moment
    eps2: float = 1e-3      # rms floor
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


class Adafactor:
    def __init__(self, config: AdafactorConfig):
        self.cfg = config

    def init(self, params: Pytree) -> AdafactorState:
        def leaf(p):
            p32 = p.astype(jnp.float32)
            if p.ndim >= 2:
                return AdafactorLeaf(
                    master=p32, m=jnp.zeros_like(p32),
                    v_row=jnp.zeros(p.shape[:-1], jnp.float32),
                    v_col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    v_full=None)
            return AdafactorLeaf(master=p32, m=jnp.zeros_like(p32),
                                 v_row=None, v_col=None,
                                 v_full=jnp.zeros_like(p32))
        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              leaves=jax.tree_util.tree_map(
                                  leaf, params))

    def apply(self, grads: Pytree, state: AdafactorState, *,
              lr: Optional[jax.Array] = None, param_dtype=jnp.float32):
        cfg = self.cfg
        lr = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)
        step_f = (state.step + 1).astype(jnp.float32)

        def upd(leaf: AdafactorLeaf, g):
            g = g.astype(jnp.float32)
            g2 = g * g + cfg.eps1
            if leaf.v_row is not None:
                vr = cfg.beta2 * leaf.v_row + (1 - cfg.beta2) * jnp.mean(g2, axis=-1)
                vc = cfg.beta2 * leaf.v_col + (1 - cfg.beta2) * jnp.mean(g2, axis=-2)
                # v̂ = outer(vr, vc) / mean(vr): rank-1 reconstruction
                denom = jnp.clip(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                vhat = (vr / denom)[..., :, None] * vc[..., None, :]
                u = g / (jnp.sqrt(vhat / (1 - cfg.beta2 ** step_f)) + cfg.eps2)
                new = dataclasses.replace(leaf, v_row=vr, v_col=vc)
            else:
                vf = cfg.beta2 * leaf.v_full + (1 - cfg.beta2) * g2
                u = g / (jnp.sqrt(vf / (1 - cfg.beta2 ** step_f)) + cfg.eps2)
                new = dataclasses.replace(leaf, v_full=vf)
            # update clipping (d=1) per Adafactor alg. 4
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
            m2 = cfg.beta1 * new.m + (1 - cfg.beta1) * u
            p2 = new.master - lr * (m2 + cfg.weight_decay * new.master)
            return dataclasses.replace(new, m=m2, master=p2)

        new_leaves = jax.tree_util.tree_map(
            upd, state.leaves, grads,
            is_leaf=lambda x: isinstance(x, AdafactorLeaf))
        new_params = jax.tree_util.tree_map(
            lambda l: l.master.astype(param_dtype), new_leaves,
            is_leaf=lambda x: isinstance(x, AdafactorLeaf))
        return new_params, AdafactorState(step=state.step + 1, leaves=new_leaves)

    def params_view(self, state: AdafactorState, param_dtype=jnp.float32):
        return jax.tree_util.tree_map(
            lambda l: l.master.astype(param_dtype), state.leaves,
            is_leaf=lambda x: isinstance(x, AdafactorLeaf))

    def state_bytes(self, state: AdafactorState) -> dict:
        # n_params is part of the contract shared with Block8bitOptimizer:
        # train/loop.py gates its state_bytes_per_param metric on it, and
        # that metric is exactly the paper's Table 1 comparison against
        # this 32-bit memory-efficient baseline.
        stats = master = n_params = 0
        for leaf in jax.tree_util.tree_leaves(
                state.leaves, is_leaf=lambda x: isinstance(x, AdafactorLeaf)):
            stats += leaf.m.size * 4
            for v in (leaf.v_row, leaf.v_col, leaf.v_full):
                if v is not None:
                    stats += v.size * 4
            master += leaf.master.size * 4
            n_params += leaf.master.size
        return {"state_bytes": int(stats), "master_bytes": int(master),
                "n_params": int(n_params)}
