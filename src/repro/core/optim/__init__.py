"""8-bit optimizers (paper core) + 32-bit baselines + matrix optimizers.

Factory usage (the "two-line change" of the paper):

    opt = make_optimizer("adam8", lr=1e-3)      # instead of "adam32"
    state = opt.init(params)
    params, state = opt.apply(grads, state)

``make_optimizer`` is the single construction entry point: it accepts a
registered *name* ("adam8", "muon8", "adafactor32", ...) or a ready
*config object* (``OptimConfig`` / ``AdafactorConfig``) and dispatches to
the right engine class (``Block8bitOptimizer``, ``MuonOptimizer``,
``Adafactor``) — train/launch/serve construct every optimizer through it
instead of per-module conditionals.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

from repro.core.optim.adafactor import Adafactor, AdafactorConfig
from repro.core.optim.base import (ALGOS, ArenaPartition, FlatSegment,
                                   Full32Leaf, OptimConfig, Pool32Arena,
                                   Pool32Leaf, PooledQuantLeaf, Quant8Leaf,
                                   QuantArena, QuantSegment,
                                   default_override_32bit, make_partition)
from repro.core.optim.blockopt import (Block8bitOptimizer, OptState,
                                       repool_like, unpool_state)
from repro.core.optim.muon import MuonOptimizer
from repro.errors import ConfigError

# name: (algo, bits) — every registered algorithm gets an "<algo>8" and an
# "<algo>32" name, so new algorithms are CLI-runnable without extra wiring.
_NAMES = {f"{algo}{bits}": (algo, bits) for algo in ALGOS for bits in (8, 32)}


def optimizer_names() -> list:
    """Every constructible optimizer name (quickstart/launch CLI choices)."""
    return sorted(_NAMES) + ["adafactor32"]


def _from_config(cfg, override_32bit=None, mesh=None):
    """Config object -> engine instance (the one dispatch point)."""
    if isinstance(cfg, AdafactorConfig):
        return Adafactor(cfg)
    if not isinstance(cfg, OptimConfig):
        raise ConfigError(f"expected OptimConfig or AdafactorConfig, got "
                          f"{type(cfg).__name__}")
    if cfg.algo == "muon":
        return MuonOptimizer(cfg, override_32bit=override_32bit, mesh=mesh)
    return Block8bitOptimizer(cfg, override_32bit=override_32bit, mesh=mesh)


def make_optimizer(name_or_config: Union[str, OptimConfig, AdafactorConfig],
                   override_32bit: Optional[Callable[[str], bool]] = None,
                   mesh=None,
                   **kwargs):
    """Build an optimizer from a name or a config object.

    Names: ``adafactor32`` or ``<algo>8``/``<algo>32`` for any registered
    algorithm (adam/adamw/momentum/lamb/lars/adagrad/muon).  Config
    objects (``OptimConfig``/``AdafactorConfig``) construct directly —
    ``**kwargs`` are applied as ``dataclasses.replace`` overrides.

    ``override_32bit``: path predicate forcing 32-bit state for matching
    leaves (defaults to the paper's stable-embedding rule when quantized
    state is requested; pass ``lambda p: False`` to disable).  For muon the
    override additionally routes matched 2-D leaves to the element-wise
    adamw fallback (DESIGN.md §11) — Muon's usual embedding/head exclusion.

    Sub-byte state storage (DESIGN.md §9) is a config field:
    ``make_optimizer("adam8", state_bits=(4, 8))`` stores a packed 4-bit
    first moment and an 8-bit second moment; the same knob packs Muon's
    matrix momentum (``make_optimizer("muon8", state_bits=(4, 8))``).

    ``mesh``: device mesh for the partitioned (ZeRO-1) dispatch's
    shard_map path (DESIGN.md §12).  When the mesh has the
    ``cfg.partition_axes`` ("data"; "pod,data" on multi-pod meshes) with
    a combined size > 1 and ``partition_shards`` was left at its
    default, the shard count is derived from the mesh — so partitioning
    turns on automatically on data-parallel meshes, and
    ``partition=False`` opts out."""
    if isinstance(name_or_config, (OptimConfig, AdafactorConfig)):
        cfg = name_or_config
        if kwargs:
            cfg = dataclasses.replace(cfg, **kwargs)
        if isinstance(cfg, OptimConfig) and mesh is not None \
                and cfg.partition_shards == 1:
            names = getattr(mesh, "axis_names", ())
            axes = cfg.partition_axes
            if axes and all(a in names for a in axes):
                size = 1
                for a in axes:
                    size *= int(mesh.shape[a])
                cfg = dataclasses.replace(cfg, partition_shards=size)
        if isinstance(cfg, OptimConfig) and override_32bit is None \
                and (cfg.bits == 8 or cfg.algo == "muon"):
            # For muon the override doubles as the algorithm routing
            # (matched 2-D leaves run adamw, DESIGN.md §11), so the
            # embedding exclusion applies to the fp32 baseline too —
            # muon32 and muon8 must route identically to be comparable.
            override_32bit = default_override_32bit
        return _from_config(cfg, override_32bit, mesh=mesh)
    name = name_or_config
    if name == "adafactor32":
        fields = {f.name for f in dataclasses.fields(AdafactorConfig)}
        return _from_config(AdafactorConfig(
            **{k: v for k, v in kwargs.items() if k in fields}))
    if name not in _NAMES:
        raise ValueError(f"unknown optimizer '{name}'; have "
                         f"{optimizer_names()}")
    algo, bits = _NAMES[name]
    return make_optimizer(OptimConfig(algo=algo, bits=bits, **kwargs),
                          override_32bit=override_32bit, mesh=mesh)


__all__ = [
    "Adafactor", "AdafactorConfig", "ArenaPartition", "Block8bitOptimizer",
    "FlatSegment", "Full32Leaf", "MuonOptimizer", "OptimConfig", "OptState",
    "Pool32Arena", "Pool32Leaf", "PooledQuantLeaf", "Quant8Leaf",
    "QuantArena", "QuantSegment", "default_override_32bit",
    "make_optimizer", "make_partition", "optimizer_names", "repool_like",
    "unpool_state",
]
