"""8-bit optimizers (paper core) + 32-bit baselines.

Factory usage (the "two-line change" of the paper):

    opt = make_optimizer("adam8", lr=1e-3)      # instead of "adam32"
    state = opt.init(params)
    params, state = opt.apply(grads, state)
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.optim.adafactor import Adafactor, AdafactorConfig
from repro.core.optim.base import (FlatSegment, Full32Leaf, OptimConfig,
                                   Pool32Arena, Pool32Leaf, PooledQuantLeaf,
                                   Quant8Leaf, QuantArena, QuantSegment,
                                   default_override_32bit)
from repro.core.optim.blockopt import (Block8bitOptimizer, OptState,
                                       repool_like, unpool_state)

_NAMES = {
    # name: (algo, bits)
    "adam8": ("adam", 8), "adamw8": ("adamw", 8), "momentum8": ("momentum", 8),
    "lamb8": ("lamb", 8), "lars8": ("lars", 8), "adagrad8": ("adagrad", 8),
    "adam32": ("adam", 32), "adamw32": ("adamw", 32),
    "momentum32": ("momentum", 32), "lamb32": ("lamb", 32),
    "lars32": ("lars", 32), "adagrad32": ("adagrad", 32),
}


def make_optimizer(name: str,
                   override_32bit: Optional[Callable[[str], bool]] = None,
                   **kwargs):
    """Build an optimizer by name. ``adafactor32`` or any of
    adam8/adamw8/momentum8/lamb8/lars8/adagrad8 and their 32-bit twins.

    ``override_32bit``: path predicate forcing 32-bit state for matching
    leaves (defaults to the paper's stable-embedding rule when the name ends
    in '8'; pass ``lambda p: False`` to disable).

    Sub-byte state storage (DESIGN.md §9) is a kwarg on the quantized
    names: ``make_optimizer("adam8", state_bits=(4, 8))`` stores a packed
    4-bit first moment and an 8-bit second moment."""
    if name == "adafactor32":
        import dataclasses
        fields = {f.name for f in dataclasses.fields(AdafactorConfig)}
        return Adafactor(AdafactorConfig(
            **{k: v for k, v in kwargs.items() if k in fields}))
    if name not in _NAMES:
        raise ValueError(f"unknown optimizer '{name}'; have "
                         f"{sorted(_NAMES) + ['adafactor32']}")
    algo, bits = _NAMES[name]
    cfg = OptimConfig(algo=algo, bits=bits, **kwargs)
    if bits == 8 and override_32bit is None:
        override_32bit = default_override_32bit
    return Block8bitOptimizer(cfg, override_32bit=override_32bit)


__all__ = [
    "Adafactor", "AdafactorConfig", "Block8bitOptimizer", "FlatSegment",
    "Full32Leaf", "OptimConfig", "OptState", "Pool32Arena", "Pool32Leaf",
    "PooledQuantLeaf", "Quant8Leaf", "QuantArena", "QuantSegment",
    "default_override_32bit", "make_optimizer", "repool_like",
    "unpool_state",
]
