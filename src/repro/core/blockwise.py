"""Block-wise 8-bit quantization (paper §2.1) — pure-JAX reference path.

A tensor is treated as a flat 1-D sequence, padded to a multiple of the block
size B (paper default 2048), reshaped to ``(n_blocks, B)``, and each block is
normalized by its own absmax before nearest-code lookup in a 256-entry
codebook.  Outliers are confined to a single block and the per-block max is
representable with zero quantization error (for the +1.0 code).

This module is the numerical source of truth; ``repro.kernels`` provides the
Pallas TPU implementations which are tested against these functions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qmap as qmap_lib

DEFAULT_BLOCK_SIZE = 2048


def pad_to_blocks(flat: jax.Array, block_size: int) -> jax.Array:
    """Pad a flat array with zeros to a whole number of blocks."""
    n = flat.shape[0]
    n_blocks = -(-n // block_size)
    pad = n_blocks * block_size - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n_blocks, block_size)


def nearest_code(x_norm: jax.Array, bounds: jax.Array) -> jax.Array:
    """Nearest-neighbour code via the 255 midpoint boundaries.

    ``code = sum_j [x > b_j]`` — identical to argmin over |q - x| for a sorted
    codebook; branchless and gather-free (the form our TPU kernel uses).
    On the XLA path we use searchsorted (binary search) which is O(log n).
    """
    return jnp.searchsorted(bounds, x_norm, side="right").astype(jnp.uint8)


def quantize_blocks(
    blocks: jax.Array,
    codebook: jax.Array,
    *,
    stochastic_rounding: bool = False,
    key: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantize ``(n_blocks, B)`` f32 -> (codes uint8, absmax f32 (n_blocks,)).

    ``stochastic_rounding`` rounds to one of the two neighbouring codes with
    probability proportional to proximity (paper App H notes this helps
    AdaGrad-style wide-range states).
    """
    blocks = blocks.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    x = blocks / scale[:, None]
    bounds = (codebook[1:] + codebook[:-1]) * 0.5
    codes = jnp.searchsorted(bounds, x, side="right").astype(jnp.int32)
    if stochastic_rounding:
        if key is None:
            raise ValueError("stochastic_rounding requires a PRNG key")
        # Neighbouring code on the far side of x (k-bit maps have
        # codebook.shape[0] = 2^bits levels).
        q_near = codebook[codes]
        direction = jnp.where(x > q_near, 1, -1)
        other = jnp.clip(codes + direction, 0, codebook.shape[0] - 1)
        q_other = codebook[other]
        span = jnp.abs(q_other - q_near)
        p_other = jnp.where(span > 0, jnp.abs(x - q_near) / jnp.where(span > 0, span, 1.0), 0.0)
        u = jax.random.uniform(key, x.shape)
        codes = jnp.where(u < p_other, other, codes)
    return codes.astype(jnp.uint8), absmax


def dequantize_blocks(codes: jax.Array, absmax: jax.Array, codebook: jax.Array) -> jax.Array:
    """Dequantize (codes, absmax) -> f32 blocks."""
    return codebook[codes.astype(jnp.int32)] * absmax[:, None]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """8-bit block-wise quantized tensor in the flat block domain.

    codes:  uint8 ``(n_blocks, B)``
    absmax: f32  ``(n_blocks,)``
    The logical (unpadded) element count and original shape are static
    metadata so the tensor can be restored exactly.
    """

    codes: jax.Array
    absmax: jax.Array
    shape: tuple  # original shape (static)
    qmap_name: str  # static
    signed: bool  # static

    def tree_flatten(self):
        return (self.codes, self.absmax), (self.shape, self.qmap_name, self.signed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, absmax = children
        shape, qmap_name, signed = aux
        return cls(codes=codes, absmax=absmax, shape=shape, qmap_name=qmap_name, signed=signed)

    @property
    def block_size(self) -> int:
        return self.codes.shape[-1]

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape)) if len(self.shape) else 1

    def nbytes(self) -> int:
        return self.codes.size + self.absmax.size * 4


def quantize(
    x: jax.Array,
    *,
    qmap_name: str = "dynamic",
    signed: bool = True,
    block_size: int = DEFAULT_BLOCK_SIZE,
    pad_blocks_to: int = 1,
    stochastic_rounding: bool = False,
    key: Optional[jax.Array] = None,
) -> QuantizedTensor:
    """Quantize an arbitrary-shape tensor into the flat block domain.

    ``pad_blocks_to``: pad n_blocks up to a multiple (so the block dim can be
    sharded evenly over a device axis — see DESIGN.md §4).
    """
    shape = tuple(x.shape)
    codebook = jnp.asarray(qmap_lib.get_qmap(qmap_name, signed))
    blocks = pad_to_blocks(x.reshape(-1), block_size)
    if pad_blocks_to > 1:
        nb = blocks.shape[0]
        target = -(-nb // pad_blocks_to) * pad_blocks_to
        if target != nb:
            blocks = jnp.pad(blocks, ((0, target - nb), (0, 0)))
    codes, absmax = quantize_blocks(
        blocks, codebook, stochastic_rounding=stochastic_rounding, key=key
    )
    return QuantizedTensor(codes=codes, absmax=absmax, shape=shape,
                           qmap_name=qmap_name, signed=signed)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """Restore the original-shape tensor (f32 by default)."""
    codebook = jnp.asarray(qmap_lib.get_qmap(qt.qmap_name, qt.signed))
    flat = dequantize_blocks(qt.codes, qt.absmax, codebook).reshape(-1)
    n = int(np.prod(qt.shape)) if qt.shape else 1
    return flat[:n].reshape(qt.shape).astype(dtype)


def zeros_like_quantized(
    x: jax.Array,
    *,
    qmap_name: str = "dynamic",
    signed: bool = True,
    block_size: int = DEFAULT_BLOCK_SIZE,
    pad_blocks_to: int = 1,
) -> QuantizedTensor:
    """Zero-initialized quantized state for a parameter of x's shape.

    The zero code index is where 0.0 sits in the codebook; absmax is 0.
    """
    n = int(np.prod(x.shape)) if x.shape else 1
    n_blocks = -(-n // block_size)
    if pad_blocks_to > 1:
        n_blocks = -(-n_blocks // pad_blocks_to) * pad_blocks_to
    codebook = qmap_lib.get_qmap(qmap_name, signed)
    zero_code = int(np.argmin(np.abs(codebook)))
    codes = jnp.full((n_blocks, block_size), zero_code, dtype=jnp.uint8)
    absmax = jnp.zeros((n_blocks,), dtype=jnp.float32)
    return QuantizedTensor(codes=codes, absmax=absmax, shape=tuple(x.shape),
                           qmap_name=qmap_name, signed=signed)


def quantization_error(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Mean absolute dequantization error (for analysis benchmarks)."""
    return jnp.mean(jnp.abs(dequantize(qt) - x))
