"""Deterministic synthetic LM data pipeline.

Sequences are sampled from a fixed random bigram chain (seeded), so the task
has learnable structure (a transformer quickly beats the unigram entropy) and
every batch is a pure function of ``(seed, step)`` — which is what makes
checkpoint/restart and elastic resharding exact: resume at step k regenerates
exactly the batches a non-preempted run would have seen.

Batches are produced as numpy on host; the caller device_puts with the data
sharding (repro.launch.train).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    branching: int = 16      # out-degree of the bigram chain (entropy knob)


class SyntheticLMPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        v, b = cfg.vocab_size, min(cfg.branching, cfg.vocab_size)
        # per-token successor sets + their (unnormalized) preference weights
        self.succ = rng.randint(0, v, size=(v, b)).astype(np.int32)
        w = rng.dirichlet(np.ones(b) * 0.5, size=v).astype(np.float32)
        self.cum_w = np.cumsum(w, axis=1)

    def batch_at(self, step: int) -> dict:
        """-> {'tokens': (B, S+1) int32} ; inputs are [:, :-1], labels [:, 1:]."""
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2**31 - 1))
        B, S = cfg.global_batch, cfg.seq_len + 1
        toks = np.empty((B, S), dtype=np.int32)
        toks[:, 0] = rng.randint(0, cfg.vocab_size, size=B)
        u = rng.random_sample((B, S - 1)).astype(np.float32)
        for t in range(1, S):
            prev = toks[:, t - 1]
            # inverse-CDF sample from each token's successor distribution
            idx = (self.cum_w[prev] < u[:, t - 1: t]).sum(axis=1)
            idx = np.minimum(idx, self.succ.shape[1] - 1)
            toks[:, t] = self.succ[prev, idx]
        return {"tokens": toks}

    def bigram_entropy(self) -> float:
        """Per-token entropy of the chain (nats) — the loss floor."""
        w = np.diff(np.concatenate([np.zeros((self.cum_w.shape[0], 1),
                                             np.float32), self.cum_w], axis=1))
        w = np.clip(w, 1e-12, 1.0)
        return float(-(w * np.log(w)).sum(axis=1).mean())
