"""Typed exceptions for user-reachable validation (DESIGN.md §15c).

Library validation must not ride ``assert``: asserts vanish under
``python -O`` (serve/engine.py documents the incident), and an
AssertionError tells the caller nothing about which knob to fix.  The
lint gate (``repro.analysis.lint``, rule ``bare-assert``) enforces the
burn-down; config- and data-shape validation raises these instead.

Both derive from ValueError so existing ``except ValueError`` callers
(and pytest.raises(ValueError) tests) keep working.
"""
from __future__ import annotations


class ConfigError(ValueError):
    """An invalid optimizer / training configuration value — wrong knob
    combination, unsupported bit-width, out-of-range hyperparameter."""


class FormatError(ValueError):
    """Malformed quantized-state data — shape/dtype/packing mismatches in
    codes, absmax, codebooks, or serialized state containers."""
