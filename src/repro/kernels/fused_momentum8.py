"""Pallas TPU kernel: fused 8-bit SGD-with-Momentum update (paper Eq. 1)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import common

DEFAULT_ROWS = 4
N_SCALARS = 8  # [lr, beta1, _, _, weight_decay, step, 0, 0] (layout shared with adam)


def _momentum8_kernel(scal_ref, qm_ref, bm_ref, p_ref, g_ref, cm_ref, am_ref,
                      p_out, cm_out, am_out):
    lr = scal_ref[0, 0]
    b1 = scal_ref[0, 1]
    wd = scal_ref[0, 4]

    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32) + wd * p
    m = common.decode(cm_ref[...].astype(jnp.int32), qm_ref[...]) * am_ref[...]
    m = b1 * m + g
    p_out[...] = (p - lr * m).astype(p_out.dtype)
    cm_new, am_new = common.block_requantize(m, bm_ref[...])
    cm_out[...] = cm_new.astype(jnp.uint8)
    am_out[...] = am_new


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def momentum8_update(
    p: jax.Array,
    g: jax.Array,
    codes_m: jax.Array,
    absmax_m: jax.Array,
    qmap_m: jax.Array,
    scalars: jax.Array,
    *,
    rows: int = DEFAULT_ROWS,
    interpret: bool = True,
):
    n_blocks, bsz = p.shape
    assert n_blocks % rows == 0, (n_blocks, rows)
    qm = qmap_m
    grid = (n_blocks // rows,)
    row_spec = pl.BlockSpec((rows, bsz), lambda i: (i, 0))
    one_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    const_spec = pl.BlockSpec((1, common.CODEBOOK_SIZE), lambda i: (0, 0))
    scal_spec = pl.BlockSpec((1, N_SCALARS), lambda i: (0, 0))
    outs = pl.pallas_call(
        _momentum8_kernel,
        grid=grid,
        in_specs=[scal_spec, const_spec, const_spec,
                  row_spec, row_spec, row_spec, one_spec],
        out_specs=[row_spec, row_spec, one_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, bsz), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, bsz), jnp.uint8),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        ],
        interpret=interpret,
    )(scalars.reshape(1, N_SCALARS),
      common.padded_qmap(qm), common.padded_bounds(qm),
      p, g, codes_m, absmax_m[:, None])
    p_new, cm, am = outs
    return p_new, cm, am[:, 0]
