"""Public jit'd wrappers around the Pallas kernels, plus the fused-update
registry.

``impl`` selects the execution path:
  * "pallas"   — pl.pallas_call, compiled for TPU (interpret=False).
  * "interpret"— pl.pallas_call with interpret=True (CPU validation path).
  * "jnp"      — the pure-jnp oracle from ref.py (XLA codegen; used inside the
                 distributed train step so the 512-device dry-run doesn't have
                 to lower the interpreter graph — see DESIGN.md §3).

``default_impl()`` picks "pallas" on TPU and "jnp" elsewhere.

The fused optimizer update is a **registry** keyed by ``(algo, impl)`` with
one public entry point, :func:`fused_update` — the analogue of bitsandbytes'
single ``optimizer_update_8bit_blockwise`` routing every optimizer through
one kernel family.  All six algorithms (adam/adamw/momentum/lamb/lars/
adagrad) and all ablation modes (stochastic rounding, tensor-wise
quantization) go through it; the old per-algorithm wrappers and the
multi-pass jnp fallback are gone.  Register new backends with
:func:`register`.

Sub-byte state bitwidths (4/5/6-bit, DESIGN.md §9) ride through the same
entry point: callers pass :class:`~repro.core.lowbit.PackedCodes`
containers instead of plain uint8 code arrays.  ``fused_update`` unwraps
them, threads the static per-slot bitwidths to the backend (the Pallas
kernels unpack/re-pack in VMEM; the jnp oracle unpacks at the XLA level),
and re-wraps the results, so the optimizer engine is bitwidth-agnostic.

Matrix-class algorithms (``muon``, DESIGN.md §11) register under the same
keys: their entries take ``p``/``g`` in the leaf's 2-D param shape and run
the Newton–Schulz matmul chain (``kernels/newton_schulz.py``) between
dequantize and requantize.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.analysis import contracts as _contracts
from repro.analysis import mutations as _mutations
from repro.core.lowbit import (PackedCodes, pack_codes, unpack_codes,
                               unwrap_codes)
from repro.telemetry import tracing as _tracing
from repro.kernels import common, ref
from repro.kernels import fused_update as _fu
from repro.kernels import newton_schulz as _ns
from repro.kernels.blockwise_dequant import dequantize_blockwise as _dequant_pallas
from repro.kernels.blockwise_quant import quantize_blockwise as _quant_pallas

DEFAULT_ROWS = common.DEFAULT_ROWS
ALGOS = tuple(_fu.ALGO_SPECS)
IMPLS = ("pallas", "interpret", "jnp")


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _pad_rows(arrs, n_blocks: int, rows: int):
    """Pad the block dim of each (n_blocks, ...) array to a multiple of rows."""
    target = -(-n_blocks // rows) * rows
    if target == n_blocks:
        return arrs, n_blocks
    pad = target - n_blocks
    out = []
    for a in arrs:
        cfg = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, cfg))
    return out, target


def quantize_blockwise(x, codebook, *, impl: str | None = None,
                       rows: int = DEFAULT_ROWS):
    with _tracing.annotate("quantize"):
        impl = impl or default_impl()
        if impl == "jnp":
            return ref.quantize_ref(x, codebook)
        nb = x.shape[0]
        (x,), _ = _pad_rows([x], nb, rows)
        codes, absmax = _quant_pallas(x, codebook, rows=rows,
                                      interpret=(impl == "interpret"))
        return codes[:nb], absmax[:nb]


def dequantize_blockwise(codes, absmax, codebook, *, impl: str | None = None,
                         rows: int = DEFAULT_ROWS, dtype=jnp.float32):
    with _tracing.annotate("dequantize"):
        impl = impl or default_impl()
        if impl == "jnp":
            return ref.dequantize_ref(codes, absmax, codebook, dtype)
        nb = codes.shape[0]
        (codes, absmax), _ = _pad_rows([codes, absmax], nb, rows)
        out = _dequant_pallas(codes, absmax, codebook, rows=rows,
                              interpret=(impl == "interpret"), dtype=dtype)
        return out[:nb]


# ----------------------------------------------------- fused-update registry
_REGISTRY: dict[tuple[str, str], Callable] = {}

# Dispatch counter: incremented once per fused_update() call.  Under jit the
# count advances at trace time, so "calls recorded while tracing one train
# step" == "fused launches baked into the compiled step" — what
# benchmarks/bench_speed.py reports as launches_per_step for the pooled
# dispatch (DESIGN.md §10).
_FUSED_UPDATE_CALLS = [0]


def reset_fused_update_count() -> None:
    _FUSED_UPDATE_CALLS[0] = 0


def fused_update_count() -> int:
    return _FUSED_UPDATE_CALLS[0]


@contextlib.contextmanager
def dispatch_count_paused():
    """Suspend the dispatch counter for shape-only traces (e.g. the
    eval_shape out-spec inference in sharding/rules.py): fused_update
    calls made inside the block do not count as launches."""
    n0 = _FUSED_UPDATE_CALLS[0]
    try:
        yield
    finally:
        _FUSED_UPDATE_CALLS[0] = n0


def register(algo: str, impl: str, fn: Callable) -> None:
    """Register a fused-update backend under ``(algo, impl)``.  ``fn`` takes
    (p, g, codes_m, absmax_m, codes_r, absmax_r, qmap_m, qmap_r, **hyper)
    and returns a :class:`~repro.kernels.fused_update.FusedUpdateResult`."""
    _REGISTRY[(algo, impl)] = fn


def registered(algo: str | None = None) -> list[tuple[str, str]]:
    """Registry keys, optionally filtered by algorithm."""
    return sorted(k for k in _REGISTRY if algo is None or k[0] == algo)


def _scalars_vec(lr, beta1, beta2, eps, weight_decay, step, gnorm_scale,
                 trust_coeff) -> jax.Array:
    """The (N_SCALARS,) f32 hyperparameter vector in the kernel's fixed
    slot order (fused_update.N_SCALARS layout)."""
    return jnp.stack([jnp.asarray(x, jnp.float32)
                      for x in (lr, beta1, beta2, eps, weight_decay, step,
                                gnorm_scale, trust_coeff)])


def _pallas_entry(algo: str, interpret: bool) -> Callable:
    def run(p, g, cm, am, cr, ar, qmap_m, qmap_r, *,
            lr, beta1, beta2, eps, weight_decay, step, trust_coeff,
            gnorm_scale, stochastic, seed, rows, bits_m=8, bits_r=8,
            block_seeds=None, block_offsets=None, segments=None,
            tensor_scale_blocks=None, sentinel=False):
        scalars = _scalars_vec(lr, beta1, beta2, eps, weight_decay, step,
                               gnorm_scale, trust_coeff)
        two = _fu.ALGO_SPECS[algo].n_states == 2
        nb = p.shape[0]
        # Single-tensor defaults: one segment, a shared seed, arange block
        # offsets — bit-identical to the historical per-leaf behaviour.
        if block_seeds is None:
            block_seeds = jnp.broadcast_to(
                jnp.asarray(seed, jnp.int32), (nb,))
        if block_offsets is None:
            block_offsets = jnp.arange(nb, dtype=jnp.int32)
        segments = tuple(segments) if segments else ((0, nb),)
        arrs = [p, g, cm, am, block_seeds, block_offsets] \
            + ([cr, ar] if two else [])
        arrs, _ = _pad_rows(arrs, nb, rows)
        p, g, cm, am, block_seeds, block_offsets = arrs[:6]
        cr, ar = (arrs[6], arrs[7]) if two else (None, None)
        if tensor_scale_blocks is not None:
            (tensor_scale_blocks,), _ = _pad_rows(
                [tensor_scale_blocks], nb, rows)
        res = _fu.fused_update_pallas(
            p, g, cm, am, cr, ar, qmap_m, qmap_r if two else None, scalars,
            block_seeds, block_offsets, tensor_scale_blocks, algo=algo,
            rows=rows, stochastic=stochastic, interpret=interpret,
            bits_m=bits_m, bits_r=bits_r, segments=segments,
            sentinel=sentinel)
        return _fu.FusedUpdateResult(
            res.p[:nb], res.codes_m[:nb], res.absmax_m[:nb],
            res.codes_r[:nb] if two else None,
            res.absmax_r[:nb] if two else None,
            res.health[:nb] if sentinel else None)
    return run


def _jnp_entry(algo: str) -> Callable:
    def run(p, g, cm, am, cr, ar, qmap_m, qmap_r, *,
            blockwise=True, rows=DEFAULT_ROWS, bits_m=8, bits_r=8, **hyper):
        del rows  # no tiling on the XLA path
        sentinel = hyper.pop("sentinel", False)
        # Sub-byte codes arrive packed; the oracle works on unpacked codes
        # and re-packs at the boundary (XLA fuses the shifts either way).
        cm = unpack_codes(cm, bits_m).astype(jnp.uint8)
        if cr is not None:
            cr = unpack_codes(cr, bits_r).astype(jnp.uint8)
        res = ref.fused_update_ref(p, g, cm, am, cr, ar, qmap_m, qmap_r,
                                   algo=algo, blockwise=blockwise, **hyper)
        health = None
        if sentinel:
            # Post-hoc on the oracle's unpacked codes — same raw-grad /
            # pre-pack operands as the in-kernel path, so the counts agree
            # by construction.
            health = _fu.health_rows(g, res.p, res.codes_m, res.absmax_m,
                                     res.codes_r, res.absmax_r,
                                     bits_m, bits_r)
        return _fu.FusedUpdateResult(
            res.p, pack_codes(res.codes_m, bits_m), res.absmax_m,
            None if res.codes_r is None else pack_codes(res.codes_r, bits_r),
            res.absmax_r, health)
    return run


def _muon_entry(impl: str) -> Callable:
    """Matrix-class (muon) fused update (DESIGN.md §11): p/g arrive in the
    leaf's 2-D param shape, the single quantized momentum state in the flat
    block domain.  dequant → momentum EMA → Newton–Schulz orthogonalization
    (kernels/newton_schulz.py, routed by ``impl``) → param update →
    blockwise requant.  Quantization mechanics ride the XLA level for every
    impl (they are element-wise and fuse there); the matmul chain is the
    kernel.  Stochastic rounding draws the same counter-hash uniforms as
    the element-wise family, so restarts and impl-parity stay bit-exact.
    """
    def run(p, g, cm, am, cr, ar, qmap_m, qmap_r, *,
            lr, beta1, weight_decay, gnorm_scale, stochastic, seed,
            bits_m=8, ns_steps=_ns.DEFAULT_NS_STEPS, blockwise=True,
            sentinel=False, **_unused):
        del cr, ar, qmap_r, _unused
        if not blockwise:
            raise NotImplementedError(
                "muon serves block-wise quantization only (the tensor-wise "
                "ablation is element-wise; DESIGN.md §11)")
        if p.ndim != 2:
            raise ValueError(
                f"muon takes the leaf in its 2-D param shape, got {p.shape} "
                f"(DESIGN.md §11)")
        shape = p.shape
        n = shape[0] * shape[1]
        nb = cm.shape[0]
        codes = unpack_codes(cm, bits_m).astype(jnp.uint8)
        bsz = codes.shape[1]
        m = ref.dequantize_ref(codes, am, qmap_m)
        m = m.reshape(-1)[:n].reshape(shape)
        g32 = g.astype(jnp.float32) * jnp.asarray(gnorm_scale, jnp.float32)
        m2, p2 = _ns.muon_math(g32, p.astype(jnp.float32), m, beta1=beta1,
                               lr=lr, weight_decay=weight_decay,
                               steps=ns_steps, impl=impl)
        blocks = jnp.pad(m2.reshape(-1), (0, nb * bsz - n)).reshape(nb, bsz)
        u1 = None
        if stochastic:
            idx = common.element_indices(nb, bsz, 0)
            u1 = common.hash_uniform(
                idx, jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
                + jnp.uint32(common.STATE1_SEED_SALT))
        cm2, am2 = ref._requantize(blocks, qmap_m, blockwise=True,
                                   random_u=u1)
        health = None
        if sentinel:
            # Health on block-domain views of the raw grad and the updated
            # param (padding is finite zeros, so counts are unaffected).
            gb = jnp.pad(g.astype(jnp.float32).reshape(-1),
                         (0, nb * bsz - n)).reshape(nb, bsz)
            pb = jnp.pad(p2.astype(jnp.float32).reshape(-1),
                         (0, nb * bsz - n)).reshape(nb, bsz)
            health = _fu.health_rows(gb, pb, cm2, am2, None, None,
                                     bits_m, 8)
        return _fu.FusedUpdateResult(p2, pack_codes(cm2, bits_m), am2,
                                     None, None, health)
    return run


for _algo in ALGOS:
    if _fu.ALGO_SPECS[_algo].matrix:
        for _impl in IMPLS:
            register(_algo, _impl, _muon_entry(_impl))
        continue
    register(_algo, "pallas", _pallas_entry(_algo, interpret=False))
    register(_algo, "interpret", _pallas_entry(_algo, interpret=True))
    register(_algo, "jnp", _jnp_entry(_algo))


def fused_update(
    algo: str,
    p, g, codes_m, absmax_m, codes_r=None, absmax_r=None,
    qmap_m=None, qmap_r=None,
    *,
    lr, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0, step=1.0,
    trust_coeff=0.001, gnorm_scale=1.0,
    blockwise: bool = True,
    stochastic: bool = False,
    seed=0,
    block_seeds=None,
    block_offsets=None,
    segments=None,
    tensor_scale_blocks=None,
    ns_steps: int = _ns.DEFAULT_NS_STEPS,
    impl: Optional[str] = None,
    rows: int = DEFAULT_ROWS,
    sentinel: bool = False,
) -> _fu.FusedUpdateResult:
    """One fused k-bit optimizer step in the flat block domain.

    Single entry point for every algorithm and ablation mode; dispatches on
    the ``(algo, impl)`` registry.  Tensor-wise quantization
    (``blockwise=False``) is an accuracy ablation, not a perf path, and is
    served by the "jnp" entry regardless of ``impl``.  ``codes_m`` /
    ``codes_r`` may be plain uint8 arrays (8-bit states) or
    :class:`~repro.core.lowbit.PackedCodes` (sub-byte states); results come
    back in the same container type.

    Pooled dispatch (DESIGN.md §10): when the input concatenates several
    logical tensors, pass ``block_seeds`` (per-block int32 rounding seeds —
    each leaf's seed repeated over its blocks), ``block_offsets``
    (per-block int32 index of each block *within its leaf*) and static
    ``segments`` (contiguous ``(block_offset, n_blocks)`` per-tensor
    ranges, used by the lamb/lars per-tensor norm finalization).  Left at
    None they default to the single-tensor interpretation (shared ``seed``,
    ``arange`` offsets, one segment).  ``tensor_scale_blocks`` (partitioned
    dispatch, DESIGN.md §12) bypasses the norm machinery entirely with a
    precomputed per-block trust-ratio vector — see
    :func:`segment_tensor_scales`.  Returns a
    :class:`~repro.kernels.fused_update.FusedUpdateResult` whose
    codes_r/absmax_r are None for one-state algorithms.

    ``sentinel=True`` (DESIGN.md §16) additionally fills
    ``FusedUpdateResult.health`` with per-block f32 count rows in the
    ``fused_update.HEALTH_SLOTS`` layout, computed on the values already
    in VMEM on the Pallas path and post-hoc (identical operands) on the
    jnp/muon paths; off, the field is None and the lowering is
    byte-identical to a sentinel-free build.

    Matrix-class algorithms (``muon``, DESIGN.md §11) take ``p``/``g`` in
    the leaf's 2-D *param shape* (not the flat block domain); ``codes_m``/
    ``absmax_m`` stay block-domain.  ``ns_steps`` sets the Newton–Schulz
    iteration count and is ignored by element-wise algorithms.
    """
    impl = impl or default_impl()
    if not blockwise:
        impl = "jnp"
    fn = _REGISTRY.get((algo, impl))
    if fn is None:
        raise KeyError(f"no fused_update backend for (algo={algo!r}, "
                       f"impl={impl!r}); registered: {registered()}")

    has_second = codes_r is not None
    codes_m, bits_m, ncodes_m = unwrap_codes(codes_m)
    codes_r, bits_r, ncodes_r = unwrap_codes(codes_r)
    checks = [(qmap_m, bits_m, "qmap_m")]
    if has_second:
        checks.append((qmap_r, bits_r, "qmap_r"))
    for qm, bits, nm in checks:
        if qm is not None and qm.shape[-1] != (1 << bits):
            raise ValueError(f"{nm} has {qm.shape[-1]} levels; "
                             f"{bits}-bit codes need {1 << bits}")

    hyper = dict(lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                 weight_decay=weight_decay, step=step,
                 trust_coeff=trust_coeff, gnorm_scale=gnorm_scale,
                 stochastic=stochastic, seed=seed, rows=rows,
                 bits_m=bits_m, bits_r=bits_r,
                 block_seeds=block_seeds, block_offsets=block_offsets,
                 segments=None if segments is None else tuple(segments),
                 tensor_scale_blocks=tensor_scale_blocks, sentinel=sentinel)
    if _fu.ALGO_SPECS[algo].matrix:
        hyper["ns_steps"] = ns_steps
        hyper["blockwise"] = blockwise
    elif impl == "jnp":
        hyper["blockwise"] = blockwise
    if _mutations.active("promote_f64"):
        # Seeded violation for the no_dtype(f64) auditor (analysis §15):
        # promote the gradient so the whole update chain lowers in f64.
        g = g.astype(jnp.float64)
    with _tracing.annotate(f"fused_update.{algo}"):
        _FUSED_UPDATE_CALLS[0] += 1
        res = fn(p, g, codes_m, absmax_m, codes_r, absmax_r, qmap_m, qmap_r,
                 **hyper)
    if ncodes_m is not None:
        res = res._replace(codes_m=PackedCodes(res.codes_m, bits_m, ncodes_m))
    if ncodes_r is not None and res.codes_r is not None:
        res = res._replace(codes_r=PackedCodes(res.codes_r, bits_r, ncodes_r))
    return res


# ------------------------------------------------- compile contracts (§15)
# The fused-update chain is where a silent promotion or a low-precision
# accumulation would hide: every algo routes through fused_update, so the
# contracts bind to the bare update lowering per (algo, bits) matrix cell.
_contracts.register(
    "fused_update.no_f64", "update",
    lambda low, cell: _contracts.check_no_dtype(low.text, "f64"),
    doc="the update chain never promotes past f32 (§6 master-dtype policy)")
_contracts.register(
    "fused_update.accumulates_in_f32", "update",
    lambda low, cell: _contracts.check_accumulates_in(low.text, "f32"),
    doc="every matmul/additive reduction in the update (LAMB/LARS norms, "
        "NS gram chain) accumulates in f32 (§11)")


def segment_tensor_scales(
    algo: str,
    p, g, codes_m, absmax_m, codes_r=None, absmax_r=None,
    qmap_m=None, qmap_r=None,
    *,
    lr, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0, step=1.0,
    trust_coeff=0.001, gnorm_scale=1.0,
    segments=None,
    impl: Optional[str] = None,
    rows: int = DEFAULT_ROWS,
) -> jax.Array:
    """Global per-block tensor_scale pass for the partitioned dispatch
    (DESIGN.md §12): the LAMB/LARS trust ratio is a whole-segment norm, and
    a segment may straddle owned-span boundaries, so the partitioned
    optimizer runs this ONCE over the full arena and hands each span its
    slice via ``fused_update(..., tensor_scale_blocks=...)``.

    Per ``impl`` this is exactly the computation ``fused_update`` performs
    internally (the Pallas norm prologue + per-segment finalize, or the jnp
    oracle's static-slice reductions), so partitioned and unpartitioned
    dispatch consume bit-identical scales.  Returns all-ones for
    block-local algorithms."""
    impl = impl or default_impl()
    spec = _fu.ALGO_SPECS[algo]
    nb = p.shape[0]
    if not spec.needs_norms:
        return jnp.ones((nb,), jnp.float32)

    codes_m, bits_m, _ = unwrap_codes(codes_m)
    codes_r, bits_r, _ = unwrap_codes(codes_r)
    segments = tuple(segments) if segments else ((0, nb),)
    hyper = dict(lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                 weight_decay=weight_decay, step=step,
                 trust_coeff=trust_coeff, gnorm_scale=gnorm_scale)
    if impl == "jnp":
        cm = unpack_codes(codes_m, bits_m).astype(jnp.uint8)
        cr = (unpack_codes(codes_r, bits_r).astype(jnp.uint8)
              if codes_r is not None else None)
        return ref.segment_scales_ref(p, g, cm, absmax_m, cr, absmax_r,
                                      qmap_m, qmap_r, algo=algo,
                                      segments=segments, **hyper)
    scalars = _scalars_vec(lr, beta1, beta2, eps, weight_decay, step,
                           gnorm_scale, trust_coeff)
    two = spec.n_states == 2
    arrs = [p, g, codes_m, absmax_m] + ([codes_r, absmax_r] if two else [])
    arrs, _ = _pad_rows(arrs, nb, rows)
    p, g, codes_m, absmax_m = arrs[:4]
    codes_r, absmax_r = (arrs[4], arrs[5]) if two else (None, None)
    out = _fu.segment_scales_pallas(
        p, g, codes_m, absmax_m, codes_r, absmax_r, qmap_m,
        qmap_r if two else None, scalars, algo=algo, rows=rows,
        interpret=(impl == "interpret"), bits_m=bits_m, bits_r=bits_r,
        segments=segments)
    return out[:nb]
