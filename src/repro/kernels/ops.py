"""Public jit'd wrappers around the Pallas kernels.

``impl`` selects the execution path:
  * "pallas"   — pl.pallas_call, compiled for TPU (interpret=False).
  * "interpret"— pl.pallas_call with interpret=True (CPU validation path).
  * "jnp"      — the pure-jnp oracle from ref.py (XLA codegen; used inside the
                 distributed train step so the 512-device dry-run doesn't have
                 to lower the interpreter graph — see DESIGN.md §3).

``default_impl()`` picks "pallas" on TPU and "jnp" elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.blockwise_dequant import dequantize_blockwise as _dequant_pallas
from repro.kernels.blockwise_quant import quantize_blockwise as _quant_pallas
from repro.kernels.fused_adam8 import adam8_update as _adam8_pallas
from repro.kernels.fused_momentum8 import momentum8_update as _momentum8_pallas


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _pad_rows(arrs, n_blocks: int, rows: int):
    """Pad the block dim of each (n_blocks, ...) array to a multiple of rows."""
    target = -(-n_blocks // rows) * rows
    if target == n_blocks:
        return arrs, n_blocks
    pad = target - n_blocks
    out = []
    for a in arrs:
        cfg = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, cfg))
    return out, target


def quantize_blockwise(x, codebook, *, impl: str | None = None, rows: int = 8):
    impl = impl or default_impl()
    if impl == "jnp":
        return ref.quantize_ref(x, codebook)
    nb = x.shape[0]
    (x,), _ = _pad_rows([x], nb, rows)
    codes, absmax = _quant_pallas(x, codebook, rows=rows,
                                  interpret=(impl == "interpret"))
    return codes[:nb], absmax[:nb]


def dequantize_blockwise(codes, absmax, codebook, *, impl: str | None = None,
                         rows: int = 8, dtype=jnp.float32):
    impl = impl or default_impl()
    if impl == "jnp":
        return ref.dequantize_ref(codes, absmax, codebook, dtype)
    nb = codes.shape[0]
    (codes, absmax), _ = _pad_rows([codes, absmax], nb, rows)
    out = _dequant_pallas(codes, absmax, codebook, rows=rows,
                          interpret=(impl == "interpret"), dtype=dtype)
    return out[:nb]


def adam8_update(p, g, codes_m, absmax_m, codes_r, absmax_r, qmap_m, qmap_r,
                 *, lr, beta1, beta2, eps, weight_decay, step,
                 impl: str | None = None, rows: int = 4):
    """Fused 8-bit Adam step in the flat block domain. Returns
    (p_new, codes_m', absmax_m', codes_r', absmax_r')."""
    impl = impl or default_impl()
    if impl == "jnp":
        return ref.adam8_ref(p, g, codes_m, absmax_m, codes_r, absmax_r,
                             qmap_m, qmap_r, lr=lr, beta1=beta1, beta2=beta2,
                             eps=eps, weight_decay=weight_decay, step=step)
    scalars = jnp.stack([
        jnp.asarray(lr, jnp.float32), jnp.asarray(beta1, jnp.float32),
        jnp.asarray(beta2, jnp.float32), jnp.asarray(eps, jnp.float32),
        jnp.asarray(weight_decay, jnp.float32), jnp.asarray(step, jnp.float32),
        jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)])
    nb = p.shape[0]
    (p, g, codes_m, absmax_m, codes_r, absmax_r), _ = _pad_rows(
        [p, g, codes_m, absmax_m, codes_r, absmax_r], nb, rows)
    p2, cm, am, cr, ar = _adam8_pallas(
        p, g, codes_m, absmax_m, codes_r, absmax_r, qmap_m, qmap_r, scalars,
        rows=rows, interpret=(impl == "interpret"))
    return p2[:nb], cm[:nb], am[:nb], cr[:nb], ar[:nb]


def momentum8_update(p, g, codes_m, absmax_m, qmap_m,
                     *, lr, beta1, weight_decay, step,
                     impl: str | None = None, rows: int = 4):
    impl = impl or default_impl()
    if impl == "jnp":
        return ref.momentum8_ref(p, g, codes_m, absmax_m, qmap_m, lr=lr,
                                 beta1=beta1, weight_decay=weight_decay,
                                 step=step)
    scalars = jnp.stack([
        jnp.asarray(lr, jnp.float32), jnp.asarray(beta1, jnp.float32),
        jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
        jnp.asarray(weight_decay, jnp.float32), jnp.asarray(step, jnp.float32),
        jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)])
    nb = p.shape[0]
    (p, g, codes_m, absmax_m), _ = _pad_rows([p, g, codes_m, absmax_m], nb, rows)
    p2, cm, am = _momentum8_pallas(p, g, codes_m, absmax_m, qmap_m, scalars,
                                   rows=rows, interpret=(impl == "interpret"))
    return p2[:nb], cm[:nb], am[:nb]
