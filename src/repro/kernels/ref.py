"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical source of truth the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
All functions operate in the flat block domain: state tensors are
``(n_blocks, B)``, absmax is ``(n_blocks,)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _bounds(codebook: jax.Array) -> jax.Array:
    return (codebook[1:] + codebook[:-1]) * 0.5


def quantize_ref(x: jax.Array, codebook: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(n_blocks, B) f32 -> (codes uint8, absmax f32)."""
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    xn = x / scale[:, None]
    codes = jnp.searchsorted(_bounds(codebook), xn, side="right")
    return codes.astype(jnp.uint8), absmax


def dequantize_ref(codes: jax.Array, absmax: jax.Array, codebook: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    return (codebook[codes.astype(jnp.int32)] * absmax[:, None]).astype(dtype)


def adam8_ref(
    p: jax.Array,            # (n_blocks, B) f32 master params (flat domain)
    g: jax.Array,            # (n_blocks, B) grads
    codes_m: jax.Array,      # (n_blocks, B) uint8
    absmax_m: jax.Array,     # (n_blocks,)   f32
    codes_r: jax.Array,      # (n_blocks, B) uint8
    absmax_r: jax.Array,     # (n_blocks,)   f32
    qmap_m: jax.Array,       # (256,) signed dynamic map
    qmap_r: jax.Array,       # (256,) unsigned dynamic map
    *,
    lr: jax.Array,
    beta1: jax.Array,
    beta2: jax.Array,
    eps: jax.Array,
    weight_decay: jax.Array,
    step: jax.Array,         # 1-based update index, for bias correction
):
    """One fused 8-bit Adam/AdamW update (paper §2 procedure):
    dequantize -> 32-bit update -> requantize.  Returns
    (p_new, codes_m', absmax_m', codes_r', absmax_r')."""
    g = g.astype(jnp.float32)
    p = p.astype(jnp.float32)
    m = dequantize_ref(codes_m, absmax_m, qmap_m)
    r = dequantize_ref(codes_r, absmax_r, qmap_r)

    m = beta1 * m + (1.0 - beta1) * g
    r = beta2 * r + (1.0 - beta2) * g * g

    c1 = 1.0 - beta1 ** step
    c2 = 1.0 - beta2 ** step
    m_hat = m / c1
    r_hat = r / c2
    update = m_hat / (jnp.sqrt(r_hat) + eps) + weight_decay * p
    p_new = p - lr * update

    cm, am = quantize_ref(m, qmap_m)
    cr, ar = quantize_ref(r, qmap_r)
    return p_new, cm, am, cr, ar


def momentum8_ref(
    p: jax.Array,
    g: jax.Array,
    codes_m: jax.Array,
    absmax_m: jax.Array,
    qmap_m: jax.Array,
    *,
    lr: jax.Array,
    beta1: jax.Array,
    weight_decay: jax.Array,
    step: jax.Array,
):
    """Fused 8-bit SGD-with-momentum update (paper Eq. 1: m = b1*m + g).

    Matches the reference implementation: the *first* update uses m_0 = g_0
    (no history), which we express as m = b1*m + g with zero-initialized m.
    """
    g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
    m = dequantize_ref(codes_m, absmax_m, qmap_m)
    m = beta1 * m + g
    p_new = p.astype(jnp.float32) - lr * m
    cm, am = quantize_ref(m, qmap_m)
    return p_new, cm, am
