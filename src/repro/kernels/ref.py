"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical source of truth the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
All functions operate in the flat block domain: state tensors are
``(n_blocks, B)``, absmax is ``(n_blocks,)``.

``fused_update_ref`` is the single parameterized reference for the fused
optimizer update: it shares the 32-bit update math and norm finalization
with ``fused_update.py`` (parity by construction) but keeps independent
quantization mechanics (searchsorted + gather instead of the kernels'
compare-sum + one-hot contraction).  It also implements the ablation modes
the Pallas path does not serve: tensor-wise (single absmax) quantization.
It is registered in ``ops.py`` as the ``impl="jnp"`` entry for every
algorithm — the only surviving form of the old multi-pass jnp fallback.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels import fused_update as fu
from repro.kernels import newton_schulz as ns


def _bounds(codebook: jax.Array) -> jax.Array:
    return (codebook[1:] + codebook[:-1]) * 0.5


def quantize_ref(x: jax.Array, codebook: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(n_blocks, B) f32 -> (codes uint8, absmax f32)."""
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    xn = x / scale[:, None]
    codes = jnp.searchsorted(_bounds(codebook), xn, side="right")
    return codes.astype(jnp.uint8), absmax


def dequantize_ref(codes: jax.Array, absmax: jax.Array, codebook: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    return (codebook[codes.astype(jnp.int32)] * absmax[:, None]).astype(dtype)


def _requantize(x: jax.Array, codebook: jax.Array, *, blockwise: bool,
                random_u: Optional[jax.Array]) -> tuple[jax.Array, jax.Array]:
    """Requantize one state tensor: block-wise or tensor-wise absmax,
    optionally with stochastic rounding (same uniforms as the kernel)."""
    if blockwise:
        absmax = jnp.max(jnp.abs(x), axis=-1)
    else:
        # tensor-wise ablation: a single absmax for the whole tensor
        absmax = jnp.full((x.shape[0],), jnp.max(jnp.abs(x)), jnp.float32)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    xn = x / scale[:, None]
    codes = jnp.searchsorted(_bounds(codebook), xn, side="right").astype(jnp.int32)
    if random_u is not None:
        q_near = codebook[codes]
        direction = jnp.where(xn > q_near, 1, -1)
        # k-bit codebooks have 2^bits levels; clip at the last real one.
        other = jnp.clip(codes + direction, 0, codebook.shape[0] - 1)
        q_other = codebook[other]
        codes = common.stochastic_codes(xn, codes, q_near, q_other, other,
                                        random_u)
    return codes.astype(jnp.uint8), absmax


def _segment_scales(spec, g, p, m, r, s, trust_coeff, segments):
    """Per-block tensor_scale vector from per-segment trust ratios, on
    global 2-D slices — the jnp analogue of the kernels' prologue+finalize
    (shared by ``fused_update_ref`` and ``segment_scales_ref`` so the
    partitioned dispatch consumes bit-identical scales)."""
    two = spec.n_states == 2

    def seg_scale(i, off, nb):
        sl = slice(off, off + nb)
        return fu.tensor_scale_for(spec, g[sl], p[sl], m[sl],
                                   r[sl] if two else None, s, trust_coeff)

    return fu.segment_scale_vector(segments, p.shape[0], seg_scale)


def segment_scales_ref(
    p, g, codes_m, absmax_m, codes_r, absmax_r, qmap_m, qmap_r, *,
    algo: str, lr, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
    step=1.0, trust_coeff=0.001, gnorm_scale=1.0, segments=None,
) -> jax.Array:
    """Standalone (n_blocks,) per-block tensor_scale pass, exactly the
    vector ``fused_update_ref`` derives internally — run once over the
    whole arena by the partitioned dispatch (DESIGN.md §12), which then
    slices it per owned span (a segment may straddle span boundaries)."""
    spec = fu.ALGO_SPECS[algo]
    n_blocks = p.shape[0]
    if not spec.needs_norms:
        return jnp.ones((n_blocks,), jnp.float32)
    two = spec.n_states == 2
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32) * jnp.asarray(gnorm_scale, jnp.float32)
    s = dict(lr=jnp.asarray(lr, jnp.float32),
             beta1=jnp.asarray(beta1, jnp.float32),
             beta2=jnp.asarray(beta2, jnp.float32),
             eps=jnp.asarray(eps, jnp.float32),
             weight_decay=jnp.asarray(weight_decay, jnp.float32),
             step=jnp.asarray(step, jnp.float32),
             tensor_scale=jnp.float32(1.0))
    m = dequantize_ref(codes_m, absmax_m, qmap_m)
    r = dequantize_ref(codes_r, absmax_r, qmap_r) if two else None
    segments = tuple(segments) if segments else ((0, n_blocks),)
    return _segment_scales(spec, g, p, m, r, s,
                           jnp.asarray(trust_coeff, jnp.float32), segments)


def fused_update_ref(
    p: jax.Array,                  # (n_blocks, B) f32 master params
    g: jax.Array,                  # (n_blocks, B) grads
    codes_m: jax.Array,            # (n_blocks, B) uint8
    absmax_m: jax.Array,           # (n_blocks,)   f32
    codes_r: Optional[jax.Array],  # 2-state algos only
    absmax_r: Optional[jax.Array],
    qmap_m: jax.Array,             # (2^bits,) state-1 codebook
    qmap_r: Optional[jax.Array],   # (2^bits,) state-2 codebook
    *,
    algo: str,
    lr, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0, step=1.0,
    trust_coeff=0.001, gnorm_scale=1.0,
    blockwise: bool = True,
    stochastic: bool = False,
    seed=0,
    block_seeds=None,
    block_offsets=None,
    segments=None,
    tensor_scale_blocks=None,
) -> fu.FusedUpdateResult:
    """The paper's §2 procedure (dequantize -> 32-bit update -> requantize)
    for any of the six algorithms, as straight-line XLA ops.

    ``block_seeds`` / ``block_offsets`` / ``segments`` carry the pooled
    dispatch's per-leaf identity (see ``ops.fused_update``); None keeps the
    single-tensor behaviour.  Per-segment trust ratios are computed on
    static slices so each segment's reduction has exactly the shape the
    per-leaf call would use — pooled and per-leaf results stay bit-exact.
    ``tensor_scale_blocks`` overrides the trust-ratio computation with an
    externally finalized per-block vector (the partitioned dispatch,
    DESIGN.md §12 — segments may straddle owned-span boundaries, so scales
    are computed globally via ``segment_scales_ref`` and sliced per span).
    """
    spec = fu.ALGO_SPECS[algo]
    two = spec.n_states == 2
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32) * jnp.asarray(gnorm_scale, jnp.float32)
    s = dict(lr=jnp.asarray(lr, jnp.float32),
             beta1=jnp.asarray(beta1, jnp.float32),
             beta2=jnp.asarray(beta2, jnp.float32),
             eps=jnp.asarray(eps, jnp.float32),
             weight_decay=jnp.asarray(weight_decay, jnp.float32),
             step=jnp.asarray(step, jnp.float32),
             tensor_scale=jnp.float32(1.0))

    m = dequantize_ref(codes_m, absmax_m, qmap_m)
    r = dequantize_ref(codes_r, absmax_r, qmap_r) if two else None

    tc = jnp.asarray(trust_coeff, jnp.float32)
    if tensor_scale_blocks is not None:
        s["tensor_scale"] = tensor_scale_blocks.astype(jnp.float32)[:, None]
    elif spec.needs_norms and segments:
        s["tensor_scale"] = _segment_scales(spec, g, p, m, r, s, tc,
                                            segments)[:, None]
    else:
        s["tensor_scale"] = fu.tensor_scale_for(spec, g, p, m, r, s, tc)

    m2, r2, p2 = fu.update_math(spec, g, p, m, r, s)

    u1 = u2 = None
    if stochastic:
        if block_seeds is None:
            seed = jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
            idx = common.element_indices(*codes_m.shape, 0)
        else:
            nb_, bsz = codes_m.shape
            offs = (jnp.arange(nb_, dtype=jnp.uint32) if block_offsets is None
                    else block_offsets.astype(jnp.uint32))
            col = jax.lax.broadcasted_iota(jnp.uint32, (nb_, bsz), 1)
            idx = offs[:, None] * jnp.uint32(bsz) + col
            seed = block_seeds.astype(jnp.uint32)[:, None]
        u1 = common.hash_uniform(idx, seed + jnp.uint32(common.STATE1_SEED_SALT))
        if two:
            u2 = common.hash_uniform(idx, seed + jnp.uint32(common.STATE2_SEED_SALT))
    cm, am = _requantize(m2, qmap_m, blockwise=blockwise, random_u=u1)
    if two:
        cr, ar = _requantize(r2, qmap_r, blockwise=blockwise, random_u=u2)
        return fu.FusedUpdateResult(p2, cm, am, cr, ar)
    return fu.FusedUpdateResult(p2, cm, am, None, None)


def newton_schulz_ref(x: jax.Array, *, steps: int = ns.DEFAULT_NS_STEPS,
                      eps: float = 1e-7) -> jax.Array:
    """≈ orth(x) — the pure-jnp Newton–Schulz oracle (DESIGN.md §11).

    The quintic iteration X ← aX + b(XX^T)X + c(XX^T)²X on the Frobenius-
    normalized input, min-dim-first via the transpose.  Numerically this is
    the same tile-replaying path the Pallas kernels mirror
    (``newton_schulz.newton_schulz(impl="jnp")``), so kernel parity tests
    have a single source of truth to compare against.
    """
    return ns.newton_schulz(x, steps=steps, impl="jnp", eps=eps)


def muon_update_ref(p, g, codes_m, absmax_m, qmap_m, *, lr, beta1=0.95,
                    weight_decay=0.0, gnorm_scale=1.0, stochastic=False,
                    seed=0,
                    ns_steps: int = ns.DEFAULT_NS_STEPS) -> fu.FusedUpdateResult:
    """Muon leaf update oracle: dequantize the block-domain momentum,
    nesterov-EMA it with the matrix-shaped gradient, Newton–Schulz-
    orthogonalize, step the param, requantize (DESIGN.md §11).  This is
    the ``("muon", "jnp")`` registry entry's math, re-exported here next
    to the other oracles; parity with "interpret"/"pallas" holds because
    only the NS matmul chain is impl-routed.
    """
    from repro.kernels import ops as kops
    return kops.fused_update(
        "muon", p, g, codes_m, absmax_m, qmap_m=qmap_m, lr=lr, beta1=beta1,
        weight_decay=weight_decay, gnorm_scale=gnorm_scale,
        stochastic=stochastic, seed=seed, ns_steps=ns_steps, impl="jnp")
