"""Pallas TPU kernel family: fused k-bit optimizer update, all algorithms.

One generic kernel builder, parameterized by a static :class:`AlgoSpec`
(update math, one-vs-two states, signedness, per-tensor norm needs), covers
adam / adamw / momentum / lamb / lars / adagrad.  Each grid step streams one
tile of the flat block domain HBM -> VMEM, dequantizes the quantized state,
runs the 32-bit update math in registers, and requantizes with per-block
absmax — the paper's §2 procedure in a single HBM pass per state tensor
(DESIGN.md §3).

State bitwidth is a per-slot static parameter (``bits_m`` / ``bits_r`` ∈
{4, 5, 6, 8}; DESIGN.md §9): sub-byte codes arrive bit-packed as
``(n_blocks, B*bits/8)`` uint8 words and are unpacked *inside* the kernel
(``repro.core.lowbit.unpack_codes`` — broadcast shifts, no gathers), so the
fused path streams only packed bytes through HBM and never materializes an
unpacked code tensor.  Requantized codes are re-packed in VMEM before the
store.  8-bit slots skip both steps and keep the legacy layout bit-exactly.

Extras fused into the same pass:

  * **stochastic rounding** — counter-based PRNG evaluated on the VPU
    (``common.hash_uniform``); no extra dequant/requant round trip and no
    host randomness, so restarts are bit-exact.
  * **gradient scaling** — the percentile-clipping ``gnorm_scale`` is a
    scalar multiplied into g in-kernel (bitsandbytes-style, DESIGN.md §7).

LAMB/LARS need per-tensor norms, which are global reductions and cannot be
fused into one block-local pass.  They get a *norm prologue*: a first grid
pass emits per-**block** partial sums of ||p||^2 / ||g||^2 / ||u||^2, the
XLA side finalizes them per *segment* (a contiguous block range belonging
to one logical tensor — the whole input by default, one range per pooled
leaf under the pooled dispatch, DESIGN.md §10) into a per-block
trust-ratio vector the main kernel streams like a second absmax (so
LAMB/LARS cost two passes instead of the jnp fallback's 3-4).

The pooled dispatch (DESIGN.md §10) batches many parameter leaves into one
arena, so per-leaf identity enters the kernel as three extra per-block
inputs/statics: ``block_seeds`` (each block's stochastic-rounding seed —
the seed of the leaf it belongs to), ``block_offsets`` (each block's index
*within its leaf*, so element indices for the counter-based PRNG are
leaf-local), and the static ``segments``.  With the defaults (constant
seed, ``arange`` offsets, one segment) the kernel is bit-identical to the
historical single-tensor behaviour.

``repro.kernels.ops`` registers these builders under ``(algo, "pallas")``
and ``(algo, "interpret")``; the matching jnp oracle lives in ``ref.py``
under ``(algo, "jnp")`` and shares :func:`update_math` /
:func:`tensor_scale_from_norms` with the kernels, so parity holds by
construction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lowbit import pack_codes, packed_width, unpack_codes
from repro.errors import FormatError
from repro.kernels import common

# scalar vector layout:
# [lr, beta1, beta2, eps, weight_decay, step, gnorm_scale, tensor_scale]
# Slot 7 holds trust_coeff on entry to fused_update_pallas; norm-needing
# algorithms (lamb/lars) consume the finalized per-block tensor_scale via a
# dedicated (n_blocks, 1) input instead, and the slot is rewritten to 1.0
# before the main kernel runs.
N_SCALARS = 8


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """Static description of one optimizer algorithm for the kernel builder.

    name          : algorithm key ("adam", ...)
    n_states      : 1 (momentum/lars/adagrad/muon) or 2 (adam/adamw/lamb)
    state1_signed : first state uses the signed codebook (False: adagrad's
                    strictly-positive accumulator uses the unsigned map)
    norm_kind     : "" (block-local), "lamb" (needs ||p||, ||update||) or
                    "lars" (needs ||p||, ||g||) — selects the norm prologue
    matrix        : matrix-class algorithm (muon): the update consumes the
                    leaf in its 2-D *param shape* (Newton–Schulz matmuls,
                    kernels/newton_schulz.py) while the quantized state
                    stays in the flat block domain; ops.fused_update takes
                    matrix-shaped p/g and the engine dispatches such
                    leaves per-leaf, never through a pooled arena
                    (DESIGN.md §11).
    """
    name: str
    n_states: int
    state1_signed: bool
    norm_kind: str = ""
    matrix: bool = False

    @property
    def needs_norms(self) -> bool:
        return self.norm_kind != ""


ALGO_SPECS: dict[str, AlgoSpec] = {
    "adam":     AlgoSpec("adam", 2, True),
    "adamw":    AlgoSpec("adamw", 2, True),
    "lamb":     AlgoSpec("lamb", 2, True, norm_kind="lamb"),
    "momentum": AlgoSpec("momentum", 1, True),
    "lars":     AlgoSpec("lars", 1, True, norm_kind="lars"),
    "adagrad":  AlgoSpec("adagrad", 1, False),
    "muon":     AlgoSpec("muon", 1, True, matrix=True),
}


class FusedUpdateResult(NamedTuple):
    """Output of one fused update in the flat block domain.

    ``health`` is the optional numerics-sentinel output (DESIGN.md §16):
    per-block f32 counts ``(n_blocks, N_SCALARS)`` in :data:`HEALTH_SLOTS`
    order, present iff the dispatch ran with ``sentinel=True``.  A ``None``
    leaf vanishes in pytree flattening, so sentinel-off results (and their
    lowerings) are unchanged by the field's existence."""
    p: jax.Array
    codes_m: jax.Array
    absmax_m: jax.Array
    codes_r: Optional[jax.Array]
    absmax_r: Optional[jax.Array]
    health: Optional[jax.Array] = None


# ------------------------------------------------- numerics sentinel (§16)
# Slot layout of the per-block health counts the sentinel emits.  Counts
# are integer-valued f32 (exact addition in any order up to 2^24), so the
# Pallas tiles, the jnp oracle, per-span shard_map pieces and their
# concatenation/summation all agree bit-exactly.
HEALTH_SLOTS = (
    "nonfinite_grad",        # nonfinite entries in the incoming (raw) grad
    "nonfinite_update",      # nonfinite entries in the updated master
    "nonfinite_absmax_m",    # nonfinite new per-block absmax, state 1
    "nonfinite_absmax_r",    # nonfinite new per-block absmax, state 2
    "edge_hits_m",           # requantized state-1 codes at a codebook edge
    "edge_hits_r",           # requantized state-2 codes at a codebook edge
    "absmax_overflow_m",     # new state-1 absmax past the overflow guard
    "absmax_overflow_r",     # new state-2 absmax past the overflow guard
)
N_HEALTH = len(HEALTH_SLOTS)
if N_HEALTH != N_SCALARS:  # health rows reuse the (rows, 8) tile shape
    raise FormatError("HEALTH_SLOTS must match the N_SCALARS tile width")

# f32 max is ~3.4e38; an absmax past 1e30 means squaring/scale math on the
# dequantized state is about to overflow — flag before the inf appears.
ABSMAX_OVERFLOW_THRESHOLD = 1e30


def health_rows(g, p2, c1n, a1n, c2n, a2n, bits_m: int, bits_r: int):
    """Per-block health counts ``(n_blocks, N_HEALTH)`` f32, HEALTH_SLOTS
    order, from one fused update's inputs/outputs: the raw (unscaled)
    grad blocks ``g``, the updated master blocks ``p2``, and the NEW
    *unpacked* codes / absmax of each state slot (pre ``pack_codes`` —
    exactly what the kernel holds in VMEM after ``block_requantize``).
    Pure jnp: runs inside the Pallas kernel tile-by-tile and at the XLA
    level post-hoc (jnp oracle / muon entry) unchanged, so sentinel
    parity across impls holds by construction.  Absmax vectors whose
    length differs from ``n_blocks`` (the tensor-wise ablation's
    per-tensor absmax) fold their counts into row 0."""
    nb = p2.shape[0]
    zero = jnp.zeros((nb,), jnp.float32)

    def nf2(x):                                   # (nb, B) -> (nb,)
        return jnp.sum((~jnp.isfinite(x)).astype(jnp.float32), axis=1)

    def amax_slots(a):
        if a is None:
            return zero, zero
        a = jnp.asarray(a, jnp.float32).reshape(-1)
        nfin = (~jnp.isfinite(a)).astype(jnp.float32)
        over = jnp.where(jnp.isfinite(a) &
                         (a > ABSMAX_OVERFLOW_THRESHOLD), 1.0, 0.0)
        if a.shape[0] == nb:
            return nfin, over
        return (zero.at[0].add(jnp.sum(nfin)),
                zero.at[0].add(jnp.sum(over)))

    def edge(c, bits):
        if c is None:
            return zero
        hit = (c == 0) | (c == (1 << bits) - 1)
        return jnp.sum(hit.astype(jnp.float32), axis=1)

    nf_a1, ov_a1 = amax_slots(a1n)
    nf_a2, ov_a2 = amax_slots(a2n)
    return jnp.stack([
        nf2(g.astype(jnp.float32)), nf2(p2.astype(jnp.float32)),
        nf_a1, nf_a2, edge(c1n, bits_m), edge(c2n, bits_r),
        ov_a1, ov_a2], axis=1)


# --------------------------------------------------------------- update math
def adam_moments(g, m, r, s):
    """Shared first/second moment EMA for the adam family (incl. lamb)."""
    m2 = s["beta1"] * m + (1.0 - s["beta1"]) * g
    r2 = s["beta2"] * r + (1.0 - s["beta2"]) * g * g
    return m2, r2


def adam_base_update(g, p, m, r, s):
    """Bias-corrected adam step direction incl. decoupled weight decay —
    the pre-trust-ratio 'u' of LAMB. Returns (m2, r2, u)."""
    m2, r2 = adam_moments(g, m, r, s)
    c1 = 1.0 - jnp.power(s["beta1"], s["step"])
    c2 = 1.0 - jnp.power(s["beta2"], s["step"])
    u = (m2 / c1) / (jnp.sqrt(r2 / c2) + s["eps"]) + s["weight_decay"] * p
    return m2, r2, u


def update_math(spec: AlgoSpec, g, p, m, r, s):
    """One 32-bit optimizer update on (already gnorm-scaled) g.

    ``s`` is a dict of scalars: lr, beta1, beta2, eps, weight_decay, step,
    tensor_scale (the finalized LAMB trust ratio / LARS local lr; 1.0 for
    block-local algorithms).  Returns (m2, r2, p2) with r2 = None for
    one-state algorithms.  Pure jnp: runs inside the Pallas kernel and in
    the jnp reference unchanged — parity by construction.
    """
    algo = spec.name
    if algo in ("adam", "adamw"):
        m2, r2, u = adam_base_update(g, p, m, r, s)
        return m2, r2, p - s["lr"] * u
    if algo == "lamb":
        m2, r2, u = adam_base_update(g, p, m, r, s)
        return m2, r2, p - s["lr"] * s["tensor_scale"] * u
    if algo == "momentum":
        m2 = s["beta1"] * m + (g + s["weight_decay"] * p)
        return m2, None, p - s["lr"] * m2
    if algo == "lars":
        m2 = s["beta1"] * m + s["tensor_scale"] * (g + s["weight_decay"] * p)
        return m2, None, p - s["lr"] * m2
    if algo == "adagrad":
        m2 = m + g * g
        u = g / (jnp.sqrt(m2) + s["eps"]) + s["weight_decay"] * p
        return m2, None, p - s["lr"] * u
    raise ValueError(algo)


def tensor_scale_from_norms(spec: AlgoSpec, pn2, gn2, un2, *,
                            weight_decay, trust_coeff):
    """Finalize the norm-prologue partials into the main kernel's scalar.

    lamb: trust ratio ||p|| / ||u||; lars: local lr
    trust_coeff*||p|| / (||g|| + wd*||p||).  Identical guards to the
    long-standing 32-bit engine math."""
    pn = jnp.sqrt(pn2)
    if spec.norm_kind == "lamb":
        un = jnp.sqrt(un2)
        return jnp.where((pn > 0) & (un > 0),
                         pn / jnp.where(un > 0, un, 1.0), 1.0)
    if spec.norm_kind == "lars":
        gn = jnp.sqrt(gn2)
        denom = gn + weight_decay * pn + 1e-12
        return jnp.where(pn > 0, trust_coeff * pn / denom, 1.0)
    return jnp.float32(1.0)


def segment_scale_vector(segments, total: int, scale_fn):
    """Assemble a per-block (or per-element) tensor_scale vector from
    per-segment scalars: ``scale_fn(i, off, n)`` returns segment i's scalar
    scale; positions past the last segment (rows padding) get 1.0.  The
    single shared assembly point for the pooled dispatch's per-tensor trust
    ratios — the Pallas finalization, the jnp oracle and the fp32 pool all
    call it, so the pooled/per-leaf bit-exactness contract has one
    implementation to keep honest.  Segments must tile a contiguous
    prefix of ``total``."""
    pieces, cursor = [], 0
    for i, (off, n) in enumerate(segments):
        assert off == cursor, (segments, "segments must be contiguous")
        pieces.append(jnp.broadcast_to(scale_fn(i, off, n), (n,)))
        cursor += n
    if cursor < total:
        pieces.append(jnp.ones((total - cursor,), jnp.float32))
    return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)


def tensor_scale_for(spec: AlgoSpec, g, p, m, r, s, trust_coeff):
    """Whole-tensor norm prologue + finalization for single-tensor callers
    (the jnp oracle and the Full32 engine path).  The Pallas path computes
    the same sums as per-grid-row partials instead."""
    if not spec.needs_norms:
        return jnp.float32(1.0)
    pn2 = jnp.sum(p * p)
    gn2 = jnp.sum(g * g)
    un2 = jnp.zeros((), jnp.float32)
    if spec.norm_kind == "lamb":
        _, _, u = adam_base_update(g, p, m, r, s)
        un2 = jnp.sum(u * u)
    return tensor_scale_from_norms(spec, pn2, gn2, un2,
                                   weight_decay=s["weight_decay"],
                                   trust_coeff=trust_coeff)


def _scalars_dict(scal_row):
    return dict(lr=scal_row[0, 0], beta1=scal_row[0, 1], beta2=scal_row[0, 2],
                eps=scal_row[0, 3], weight_decay=scal_row[0, 4],
                step=scal_row[0, 5], gnorm_scale=scal_row[0, 6],
                tensor_scale=scal_row[0, 7])


# ------------------------------------------------------------ kernel builder
def _make_update_kernel(spec: AlgoSpec, rows: int, bsz: int, stochastic: bool,
                        bits_m: int, bits_r: int, sentinel: bool = False):
    """Build the main fused-update kernel for one (algo, tile, mode, bits).

    ``sentinel`` appends one trailing ``(rows, N_HEALTH)`` output of
    per-block health counts (``health_rows``) — computed on values the
    update already holds in VMEM, so the only extra HBM traffic is the
    (n_blocks, 8) f32 store itself."""
    two = spec.n_states == 2

    def kernel(*refs):
        it = iter(refs)
        scal_ref = next(it)
        seed_ref = next(it) if stochastic else None
        boff_ref = next(it) if stochastic else None
        ts_ref = next(it) if spec.needs_norms else None
        qm1_ref, b1_ref = next(it), next(it)
        qm2_ref, b2_ref = (next(it), next(it)) if two else (None, None)
        p_ref, g_ref, c1_ref, a1_ref = next(it), next(it), next(it), next(it)
        c2_ref, a2_ref = (next(it), next(it)) if two else (None, None)
        p_out, c1_out, a1_out = next(it), next(it), next(it)
        c2_out, a2_out = (next(it), next(it)) if two else (None, None)
        h_out = next(it) if sentinel else None

        s = _scalars_dict(scal_ref[...])
        if spec.needs_norms:
            # Per-block trust ratio / local lr from the norm prologue;
            # constant within a segment, broadcast over the block dim.
            s["tensor_scale"] = ts_ref[...]
        g = g_ref[...].astype(jnp.float32) * s["gnorm_scale"]
        p = p_ref[...].astype(jnp.float32)

        # ---- unpack sub-byte codes + dequantize (one-hot on MXU) ----
        m = common.decode(unpack_codes(c1_ref[...], bits_m),
                          qm1_ref[...], 1 << bits_m) * a1_ref[...]
        r = (common.decode(unpack_codes(c2_ref[...], bits_r),
                           qm2_ref[...], 1 << bits_r) * a2_ref[...]
             if two else None)

        # ---- 32-bit update math in registers ----
        m2, r2, p2 = update_math(spec, g, p, m, r, s)
        p_out[...] = p2.astype(p_out.dtype)

        # ---- requantize (per-block absmax is a row reduction in VMEM) ----
        u1 = u2 = None
        if stochastic:
            # Per-block seed + leaf-local block offset (pooled dispatch):
            # element index is offset*B + col inside the block's own leaf,
            # so pooled and per-leaf rounding draw identical uniforms.
            seed = seed_ref[...].astype(jnp.uint32)          # (rows, 1)
            off = boff_ref[...].astype(jnp.uint32)           # (rows, 1)
            col = jax.lax.broadcasted_iota(jnp.uint32, (rows, bsz), 1)
            idx = off * jnp.uint32(bsz) + col
            u1 = common.hash_uniform(idx, seed + jnp.uint32(common.STATE1_SEED_SALT))
            if two:
                u2 = common.hash_uniform(idx, seed + jnp.uint32(common.STATE2_SEED_SALT))
        c1n, a1n = common.block_requantize(m2, b1_ref[...], qm1_ref[...],
                                           random_u=u1,
                                           max_code=(1 << bits_m) - 1)
        c1_out[...] = pack_codes(c1n, bits_m)
        a1_out[...] = a1n
        c2n = a2n = None
        if two:
            c2n, a2n = common.block_requantize(r2, b2_ref[...], qm2_ref[...],
                                               random_u=u2,
                                               max_code=(1 << bits_r) - 1)
            c2_out[...] = pack_codes(c2n, bits_r)
            a2_out[...] = a2n
        if sentinel:
            # Health counts on the RAW grad tile (pre gnorm_scale: inf*0
            # would mask a nonfinite grad) and the values already live in
            # VMEM — no second pass over HBM.
            h_out[...] = health_rows(g_ref[...], p2, c1n, a1n, c2n, a2n,
                                     bits_m, bits_r)

    return kernel


def _make_norm_kernel(spec: AlgoSpec, rows: int, bsz: int,
                      bits_m: int, bits_r: int):
    """Norm prologue: per-**block** partial squared norms, one (rows, 8)
    tile of rows [||p||^2, ||g||^2, ||u||^2, 0...] per grid step.  Block
    granularity (not grid-row granularity) is what lets the XLA side
    finalize the partials per *segment* under the pooled dispatch, where a
    leaf boundary need not be tile-aligned.  lars only needs p and g; lamb
    re-derives the pre-trust update u from the dequantized states."""

    def kernel(*refs):
        it = iter(refs)
        scal_ref = next(it)
        if spec.norm_kind == "lamb":
            qm1_ref, qm2_ref = next(it), next(it)
            p_ref, g_ref = next(it), next(it)
            c1_ref, a1_ref, c2_ref, a2_ref = (next(it), next(it),
                                              next(it), next(it))
        else:
            p_ref, g_ref = next(it), next(it)
        out_ref = next(it)

        s = _scalars_dict(scal_ref[...])
        g = g_ref[...].astype(jnp.float32) * s["gnorm_scale"]
        p = p_ref[...].astype(jnp.float32)
        pn2 = jnp.sum(p * p, axis=1)                      # (rows,)
        gn2 = jnp.sum(g * g, axis=1)
        un2 = jnp.zeros((rows,), jnp.float32)
        if spec.norm_kind == "lamb":
            m = common.decode(unpack_codes(c1_ref[...], bits_m),
                              qm1_ref[...], 1 << bits_m) * a1_ref[...]
            r = common.decode(unpack_codes(c2_ref[...], bits_r),
                              qm2_ref[...], 1 << bits_r) * a2_ref[...]
            _, _, u = adam_base_update(g, p, m, r, s)
            un2 = jnp.sum(u * u, axis=1)
        zero = jnp.zeros((rows,), jnp.float32)
        out_ref[...] = jnp.stack(
            [pn2, gn2, un2, zero, zero, zero, zero, zero], axis=1)

    return kernel


def _norm_partials_pallas(spec: AlgoSpec, p, g, codes_m, absmax_m, codes_r,
                          absmax_r, qm1, qm2, scalars, *, rows: int,
                          bits_m: int, bits_r: int, interpret: bool):
    """Run the norm prologue over the whole input: per-block partial
    squared norms, (n_blocks, N_SCALARS) f32.  Shared by the fused update
    and the standalone segment-scale pass of the partitioned dispatch
    (DESIGN.md §12) — one implementation, so both produce bit-identical
    partials."""
    n_blocks, bsz = p.shape
    w1 = packed_width(bsz, bits_m)
    row_spec = pl.BlockSpec((rows, bsz), lambda i: (i, 0))
    code1_spec = pl.BlockSpec((rows, w1), lambda i: (i, 0))
    one_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    const_spec = pl.BlockSpec((1, common.CODEBOOK_SIZE), lambda i: (0, 0))
    scal_spec = pl.BlockSpec((1, N_SCALARS), lambda i: (0, 0))

    norm_kernel = _make_norm_kernel(spec, rows, bsz, bits_m, bits_r)
    in_specs = [scal_spec]
    args = [scalars.reshape(1, N_SCALARS)]
    if spec.norm_kind == "lamb":
        in_specs += [const_spec, const_spec]
        args += [qm1, qm2]
    in_specs += [row_spec, row_spec]
    args += [p, g]
    if spec.norm_kind == "lamb":
        w2 = packed_width(bsz, bits_r)
        code2_spec = pl.BlockSpec((rows, w2), lambda i: (i, 0))
        in_specs += [code1_spec, one_spec, code2_spec, one_spec]
        args += [codes_m, absmax_m[:, None], codes_r, absmax_r[:, None]]
    return pl.pallas_call(
        norm_kernel,
        grid=(n_blocks // rows,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rows, N_SCALARS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, N_SCALARS), jnp.float32),
        interpret=interpret,
    )(*args)


def segment_scales_from_partials(spec: AlgoSpec, partials, segments,
                                 n_blocks: int, weight_decay, trust_coeff):
    """Finalize per-block norm partials into the per-block tensor_scale
    vector: a (nb_s,) sum per segment — identical in shape (hence in f32
    reduction order) to the per-leaf dispatch, the pooled/per-leaf AND
    partitioned/unpartitioned trust-ratio bit-exactness contract."""
    def seg_scale(i, off, nb):
        sums = jnp.sum(partials[off:off + nb], axis=0)
        return tensor_scale_from_norms(
            spec, sums[0], sums[1], sums[2],
            weight_decay=weight_decay, trust_coeff=trust_coeff)

    return segment_scale_vector(segments, n_blocks, seg_scale)


@functools.partial(jax.jit, static_argnames=("algo", "rows", "stochastic",
                                             "interpret", "bits_m", "bits_r",
                                             "segments"))
def segment_scales_pallas(
    p, g, codes_m, absmax_m, codes_r, absmax_r, qmap_m, qmap_r, scalars,
    *, algo: str, rows: int = common.DEFAULT_ROWS, stochastic: bool = False,
    interpret: bool = True, bits_m: int = 8, bits_r: int = 8,
    segments: tuple = (),
) -> jax.Array:
    """Standalone norm-prologue pass -> (n_blocks,) per-block tensor_scale,
    exactly the vector ``fused_update_pallas`` would derive internally.
    The partitioned dispatch (DESIGN.md §12) runs this once over the whole
    arena, then feeds per-span slices to the main kernel via
    ``tensor_scale_blocks`` — segment norms are global reductions and a
    leaf may straddle owned-span boundaries."""
    del stochastic
    spec = ALGO_SPECS[algo]
    n_blocks = p.shape[0]
    assert n_blocks % rows == 0, (n_blocks, rows)
    if not segments:
        segments = ((0, n_blocks),)
    if not spec.needs_norms:
        return jnp.ones((n_blocks,), jnp.float32)
    scalars = scalars.astype(jnp.float32)
    qm1 = common.padded_qmap(qmap_m)
    qm2 = common.padded_qmap(qmap_r) if spec.norm_kind == "lamb" else None
    partials = _norm_partials_pallas(
        spec, p, g, codes_m, absmax_m, codes_r, absmax_r, qm1, qm2, scalars,
        rows=rows, bits_m=bits_m, bits_r=bits_r, interpret=interpret)
    return segment_scales_from_partials(spec, partials, segments, n_blocks,
                                        scalars[4], scalars[7])


# ------------------------------------------------------------- public entry
@functools.partial(jax.jit, static_argnames=("algo", "rows", "stochastic",
                                             "interpret", "bits_m", "bits_r",
                                             "segments", "sentinel"))
def fused_update_pallas(
    p: jax.Array,                  # (n_blocks, B) f32 master params
    g: jax.Array,                  # (n_blocks, B) f32/bf16 grads
    codes_m: jax.Array,            # (n_blocks, B*bits_m/8) uint8 (packed)
    absmax_m: jax.Array,           # (n_blocks,)  f32
    codes_r: Optional[jax.Array],  # 2-state algos only
    absmax_r: Optional[jax.Array],
    qmap_m: jax.Array,             # (2^bits_m,) state-1 codebook
    qmap_r: Optional[jax.Array],   # (2^bits_r,) state-2 codebook
    scalars: jax.Array,            # (N_SCALARS,) f32 (tensor_scale slot unused)
    block_seeds: jax.Array,        # (n_blocks,) int32 per-block rounding seeds
    block_offsets: jax.Array,      # (n_blocks,) int32 leaf-local block index
    tensor_scale_blocks: Optional[jax.Array] = None,  # (n_blocks,) f32
    *,
    algo: str,
    rows: int = common.DEFAULT_ROWS,
    stochastic: bool = False,
    interpret: bool = True,
    bits_m: int = 8,
    bits_r: int = 8,
    segments: tuple = (),          # ((block_offset, n_blocks), ...) static
    sentinel: bool = False,        # emit per-block health counts (§16)
) -> FusedUpdateResult:
    """One fused k-bit update for ``algo`` in the flat block domain.

    ``n_blocks`` must be a multiple of ``rows`` (ops.fused_update pads).
    ``scalars`` layout: [lr, beta1, beta2, eps, weight_decay, step,
    gnorm_scale, trust_coeff].  ``block_seeds`` / ``block_offsets`` give
    every block its stochastic-rounding seed and its block index *within
    its own leaf* — a constant seed plus ``arange`` offsets reproduce the
    single-tensor behaviour; the pooled dispatch (DESIGN.md §10) passes one
    seed per pooled leaf so pooled and per-leaf rounding are bit-identical.
    ``segments`` lists the contiguous per-tensor block ranges the lamb/lars
    norm prologue is finalized over (empty = one segment spanning the
    input); blocks outside every segment get tensor_scale 1.0.
    ``tensor_scale_blocks`` short-circuits the norm prologue with an
    externally computed per-block vector — the partitioned dispatch
    (DESIGN.md §12) computes it globally (``segment_scales_pallas``) and
    feeds each owned span its slice, since a segment may straddle span
    boundaries.  Sub-byte state slots (``bits_m``/``bits_r`` < 8) stream
    bit-packed uint8 words and unpack/re-pack inside the kernel
    (DESIGN.md §9).
    """
    spec = ALGO_SPECS[algo]
    two = spec.n_states == 2
    n_blocks, bsz = p.shape
    assert n_blocks % rows == 0, (n_blocks, rows)
    w1 = packed_width(bsz, bits_m)
    assert codes_m.shape == (n_blocks, w1), (codes_m.shape, n_blocks, w1)
    if two:
        w2 = packed_width(bsz, bits_r)
        assert codes_r.shape == (n_blocks, w2), (codes_r.shape, n_blocks, w2)
    if not segments:
        segments = ((0, n_blocks),)
    grid = (n_blocks // rows,)

    row_spec = pl.BlockSpec((rows, bsz), lambda i: (i, 0))
    code1_spec = pl.BlockSpec((rows, w1), lambda i: (i, 0))
    code2_spec = pl.BlockSpec((rows, w2), lambda i: (i, 0)) if two else None
    one_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    const_spec = pl.BlockSpec((1, common.CODEBOOK_SIZE), lambda i: (0, 0))
    scal_spec = pl.BlockSpec((1, N_SCALARS), lambda i: (0, 0))

    qm1, b1 = common.padded_qmap(qmap_m), common.padded_bounds(qmap_m)
    if two:
        qm2, b2 = common.padded_qmap(qmap_r), common.padded_bounds(qmap_r)

    scalars = scalars.astype(jnp.float32)
    tscale_blocks = None
    if spec.needs_norms:
        if tensor_scale_blocks is not None:
            # Externally finalized scales (partitioned dispatch): the
            # caller ran the prologue globally; this span consumes its
            # slice directly.
            tscale_blocks = tensor_scale_blocks.astype(jnp.float32)[:, None]
        else:
            partials = _norm_partials_pallas(
                spec, p, g, codes_m, absmax_m, codes_r, absmax_r, qm1,
                qm2 if spec.norm_kind == "lamb" else None, scalars,
                rows=rows, bits_m=bits_m, bits_r=bits_r,
                interpret=interpret)
            tscale_blocks = segment_scales_from_partials(
                spec, partials, segments, n_blocks, scalars[4],
                scalars[7])[:, None]
    scalars = scalars.at[7].set(1.0)

    kernel = _make_update_kernel(spec, rows, bsz, stochastic, bits_m, bits_r,
                                 sentinel)
    in_specs = [scal_spec]
    args = [scalars.reshape(1, N_SCALARS)]
    if stochastic:
        in_specs += [one_spec, one_spec]
        args += [block_seeds.astype(jnp.int32)[:, None],
                 block_offsets.astype(jnp.int32)[:, None]]
    if spec.needs_norms:
        in_specs += [one_spec]
        args += [tscale_blocks]
    in_specs += [const_spec, const_spec]
    args += [qm1, b1]
    if two:
        in_specs += [const_spec, const_spec]
        args += [qm2, b2]
    in_specs += [row_spec, row_spec, code1_spec, one_spec]
    args += [p, g, codes_m, absmax_m[:, None]]
    if two:
        in_specs += [code2_spec, one_spec]
        args += [codes_r, absmax_r[:, None]]

    out_specs = [row_spec, code1_spec, one_spec]
    out_shape = [
        jax.ShapeDtypeStruct((n_blocks, bsz), jnp.float32),
        jax.ShapeDtypeStruct((n_blocks, w1), jnp.uint8),
        jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
    ]
    if two:
        out_specs += [code2_spec, one_spec]
        out_shape += [
            jax.ShapeDtypeStruct((n_blocks, w2), jnp.uint8),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        ]
    if sentinel:
        out_specs += [pl.BlockSpec((rows, N_HEALTH), lambda i: (i, 0))]
        out_shape += [jax.ShapeDtypeStruct((n_blocks, N_HEALTH),
                                           jnp.float32)]

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    health = outs[-1] if sentinel else None
    if two:
        p2, c1, a1, c2, a2 = outs[:5]
        return FusedUpdateResult(p2, c1, a1[:, 0], c2, a2[:, 0], health)
    p2, c1, a1 = outs[:3]
    return FusedUpdateResult(p2, c1, a1[:, 0], None, None, health)
