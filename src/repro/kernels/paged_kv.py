"""Paged block-wise quantized KV cache: append + gather-dequant kernels.

The serving KV cache (DESIGN.md §17) stores keys/values in a fixed pool of
*pages*.  One page holds ``page_size`` token positions for every kv head of
one layer; each (position, head) row of ``Dh`` values is one quantization
block in the paper's scheme — normalized by its own absmax, nearest-code
encoded against a 2^bits dynamic codebook (``core.qmap``), and for
``bits < 8`` bit-packed along the head dim via ``core.lowbit.pack_codes``.

Storage per layer (``W = Dh * bits / 8`` bytes per row):

    codes : (n_pages, page_size, KV, W)  uint8
    absmax: (n_pages, page_size, KV)     f32

Two data paths, both independent of the page *allocator* (host-side, in
``repro.serve.kvcache``):

  * ``append_rows`` — quantize-on-append: one new (B, KV, Dh) row batch is
    encoded and scattered to per-slot (page, offset) destinations in a
    single XLA scatter; out-of-range page ids (inactive slots, the
    scheduler's sentinel) are dropped, not clamped, so no live page can be
    corrupted by a masked lane.
  * ``gather_pages`` — dequantize-on-attend: the physical pages of every
    slot's page table are gathered and decoded to (B, L, KV, Dh) values.
    ``impl="pallas"`` is the TPU kernel: the page table rides scalar
    prefetch (``PrefetchScalarGridSpec``) so each grid step DMAs exactly
    one physical page HBM->VMEM, and the codebook lookup is the chunked
    one-hot contraction every kernel in this package uses (common.decode).
    ``impl="jnp"`` is the XLA oracle; parity is exercised in
    tests/test_serve_paged.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import qmap as qmap_lib
from repro.core.lowbit import pack_codes, unpack_codes
from repro.errors import FormatError
from repro.kernels import common

KV_QMAP_NAME = "dynamic"
KV_BITS = (4, 8)


@functools.lru_cache(maxsize=8)
def _kv_qmap_np(bits: int = 8):
    return qmap_lib.get_qmap(KV_QMAP_NAME, True, bits=bits)


def kv_qmap(bits: int = 8) -> jax.Array:
    """The signed dynamic codebook used for every KV row (2^bits levels)."""
    return jnp.asarray(_kv_qmap_np(bits))


def packed_row_width(head_dim: int, bits: int) -> int:
    """Stored bytes per (position, head) row of ``head_dim`` values."""
    if bits not in KV_BITS:
        raise FormatError(f"kv bits={bits} unsupported; choose from "
                          f"{KV_BITS}")
    if (head_dim * bits) % 8 != 0:
        raise FormatError(f"head_dim={head_dim} at {bits}-bit KV does not "
                          f"fill whole bytes")
    return (head_dim * bits) // 8


def bits_of(head_dim: int, row_width: int) -> int:
    """Recover the code bitwidth from array shapes (8 * W / Dh) — the paged
    cache carries no dtype tag, the packing ratio IS the format."""
    bits = (row_width * 8) // head_dim
    if bits not in KV_BITS or packed_row_width(head_dim, bits) != row_width:
        raise FormatError(f"row width {row_width} is not a supported "
                          f"packing of head_dim {head_dim}")
    return bits


# ------------------------------------------------------------ row quantize

def quantize_rows(x: jax.Array, bits: int = 8
                  ) -> tuple[jax.Array, jax.Array]:
    """x: (..., Dh) -> (codes uint8 (..., W), absmax f32 (...,)).

    Block = one head row (absmax per (..., head)); same math as the
    contiguous int8 KV path (layers.kv_quantize) at bits=8, so paged and
    contiguous caches quantize identically by construction.
    """
    cb = kv_qmap(bits)
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    bounds = (cb[1:] + cb[:-1]) * 0.5
    codes = jnp.searchsorted(bounds, x / scale[..., None], side="right")
    if bits == 8:
        return codes.astype(jnp.uint8), absmax
    return pack_codes(codes.astype(jnp.int32), bits), absmax


def dequantize_rows(codes: jax.Array, absmax: jax.Array, dtype,
                    bits: int = 8) -> jax.Array:
    """(codes (..., W), absmax (...,)) -> values (..., Dh) in ``dtype``."""
    cb = kv_qmap(bits)
    idx = unpack_codes(codes, bits) if bits != 8 else codes.astype(jnp.int32)
    return (cb[idx] * absmax[..., None]).astype(dtype)


# ----------------------------------------------------------------- append

def append_rows(pages_codes: jax.Array, pages_absmax: jax.Array,
                rows: jax.Array, page_ids: jax.Array, offsets: jax.Array,
                bits: int) -> tuple[jax.Array, jax.Array]:
    """Quantize-on-append one token row per slot.

    pages_codes : (n_pages, page_size, KV, W) uint8
    pages_absmax: (n_pages, page_size, KV) f32
    rows        : (B, KV, Dh) new k or v rows (post-rope)
    page_ids    : (B,) int32 physical destination page per slot; any id
                  outside [0, n_pages) is DROPPED (inactive-slot sentinel)
    offsets     : (B,) int32 position within the page
    """
    codes, absmax = quantize_rows(rows, bits)
    return (pages_codes.at[page_ids, offsets].set(codes, mode="drop"),
            pages_absmax.at[page_ids, offsets].set(absmax, mode="drop"))


# ----------------------------------------------------------- gather-dequant

def _gather_kernel(table_ref, codes_ref, absmax_ref, qmap_ref, out_ref,
                   *, bits: int):
    """One grid step = one (slot, logical page) cell: the physical page
    selected by the scalar-prefetched table is already in VMEM (index_map
    DMA); unpack -> one-hot decode -> scale."""
    del table_ref  # consumed by the index maps
    codes = codes_ref[...]                       # (1, page, KV, W) uint8
    if bits != 8:
        codes = unpack_codes(codes, bits)        # (1, page, KV, Dh)
    vals = common.decode(codes.astype(jnp.int32), qmap_ref[...],
                         n_levels=2 ** bits)
    out_ref[...] = (vals * absmax_ref[...][..., None]).astype(out_ref.dtype)


def _gather_pallas(pages_codes, pages_absmax, page_table, *, bits, dtype,
                   interpret=True):
    n_pages, page, KV, W = pages_codes.shape
    B, P = page_table.shape
    Dh = (W * 8) // bits
    # Clip on the host side of the kernel: an unallocated (-1) table entry
    # must still name a DMA-able page; its rows are masked downstream by
    # the per-slot length mask.
    table = jnp.clip(page_table, 0, n_pages - 1).astype(jnp.int32)
    qmap = common.padded_qmap(kv_qmap(bits))
    try:
        from jax.experimental.pallas import tpu as pltpu
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, P),
            in_specs=[
                pl.BlockSpec((1, page, KV, W),
                             lambda b, p, t: (t[b, p], 0, 0, 0)),
                pl.BlockSpec((1, page, KV),
                             lambda b, p, t: (t[b, p], 0, 0)),
                pl.BlockSpec((1, common.CODEBOOK_SIZE),
                             lambda b, p, t: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, page, KV, Dh),
                                   lambda b, p, t: (b, p, 0, 0)),
        )
        return pl.pallas_call(
            functools.partial(_gather_kernel, bits=bits),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, P * page, KV, Dh), dtype),
            interpret=interpret,
        )(table, pages_codes, pages_absmax, qmap)
    except ImportError:  # pallas-tpu unavailable: XLA path is the fallback
        return _gather_jnp(pages_codes, pages_absmax, page_table,
                           bits=bits, dtype=dtype)


def _gather_jnp(pages_codes, pages_absmax, page_table, *, bits, dtype):
    n_pages, page, KV, W = pages_codes.shape
    B, P = page_table.shape
    table = jnp.clip(page_table, 0, n_pages - 1)
    codes = pages_codes[table]                   # (B, P, page, KV, W)
    absmax = pages_absmax[table]                 # (B, P, page, KV)
    vals = dequantize_rows(codes, absmax, dtype, bits)
    Dh = (W * 8) // bits
    return vals.reshape(B, P * page, KV, Dh)


@functools.partial(jax.jit, static_argnames=("bits", "dtype", "impl"))
def gather_pages(pages_codes: jax.Array, pages_absmax: jax.Array,
                 page_table: jax.Array, *, bits: int, dtype=jnp.float32,
                 impl: str = "jnp") -> jax.Array:
    """Gather + dequantize every slot's pages.

    page_table: (B, P) int32 physical page per logical page (-1 =
    unallocated; gathered-but-masked, see DESIGN.md §17).  Returns
    (B, P*page_size, KV, Dh) values in ``dtype``.
    """
    if impl == "jnp":
        return _gather_jnp(pages_codes, pages_absmax, page_table,
                           bits=bits, dtype=dtype)
    if impl in ("pallas", "interpret"):
        return _gather_pallas(pages_codes, pages_absmax, page_table,
                              bits=bits, dtype=dtype,
                              interpret=(impl == "interpret"))
    raise FormatError(f"unknown impl {impl!r}; have jnp|pallas|interpret")
