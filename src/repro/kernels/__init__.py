# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Layout (DESIGN.md §3): common.py (in-kernel helpers, DEFAULT_ROWS, PRNG),
# blockwise_quant/dequant.py (standalone quant kernels), fused_update.py
# (the algorithm-parameterized fused optimizer-update kernel family),
# ref.py (jnp oracles), ops.py (public wrappers + (algo, impl) registry).
