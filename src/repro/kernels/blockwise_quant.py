"""Pallas TPU kernel: block-wise 8-bit quantization (paper §2.1).

The Pallas tile is aligned to the quantization block: input is
``(n_blocks, B)`` and each grid step processes ``ROWS`` whole blocks, so the
per-block absmax is a row reduction inside one VMEM tile — no cross-core
communication, which is exactly the paper's argument for block-wise
normalization, mapped onto the TPU memory hierarchy (HBM -> VMEM -> VREG).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import common

DEFAULT_ROWS = common.DEFAULT_ROWS  # quantization blocks per grid step


def _quant_kernel(x_ref, bounds_ref, codes_ref, absmax_ref):
    x = x_ref[...].astype(jnp.float32)              # (ROWS, B)
    codes, absmax = common.block_requantize(x, bounds_ref[...])
    codes_ref[...] = codes.astype(jnp.uint8)
    absmax_ref[...] = absmax                        # (ROWS, 1)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def quantize_blockwise(
    x: jax.Array,
    codebook: jax.Array,
    *,
    rows: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(n_blocks, B) -> (codes uint8 (n_blocks, B), absmax f32 (n_blocks,)).

    n_blocks must be a multiple of ``rows`` (ops.py pads).
    """
    n_blocks, bsz = x.shape
    assert n_blocks % rows == 0, (n_blocks, rows)
    bounds = common.padded_bounds(codebook)
    grid = (n_blocks // rows,)
    codes, absmax = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, bsz), lambda i: (i, 0)),
            pl.BlockSpec((1, common.CODEBOOK_SIZE), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, bsz), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, bsz), jnp.uint8),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, bounds)
    return codes, absmax[:, 0]
