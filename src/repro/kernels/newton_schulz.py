"""Pallas TPU kernel family: tiled Newton–Schulz orthogonalization (Muon).

The repo's first *matrix-class* kernel (DESIGN.md §11): where every other
kernel in this package is element-wise over the flat block domain, the
Muon optimizer (Jordan et al. 2024; quantized states: Gupta et al. 2025)
orthogonalizes its 2-D momentum with the quintic Newton–Schulz iteration

    X ← a·X + b·(XX^T)X + c·(XX^T)^2 X

run NS_STEPS times on the Frobenius-normalized momentum matrix.  The
coefficients (a, b, c) are the Muon quintic tuned for fast convergence of
the singular values into a band around 1 rather than exact orthogonality —
the update direction only needs orth(M) approximately.

Tiling.  With the min-dim-first convention (X is (m, n), m ≤ n — callers
hand the transpose for tall matrices) each iteration is two tiled passes
over the lane dim plus one tiny m×m matmul:

  * **gram pass**   A = X X^T : grid over n-tiles, each grid step computes
    a (m, TILE_N) × (TILE_N, m) partial on the MXU and accumulates into the
    (m, m) output block (all grid steps map to the same output tile —
    sequential TPU grid ⇒ a well-defined reduction order).
  * **finalize**    B = b·A + c·A·A : one (m, m) matmul, done at the XLA
    level like the LAMB norm finalization (§3) — m is the *small* dim.
  * **apply pass**  X' = a·X + B X : grid over n-tiles; B streams as a
    constant block, each grid step emits one (m, TILE_N) output tile.

VMEM footprint is m·TILE_N + m·m floats, so the kernel assumes the small
dim fits on chip (m ≲ 4k on v5e) — true for every config in this repo
(the min dim of a weight matrix is ≤ d_model).

Parity by construction: `_gram_tile` / `_apply_tile` are the *same jnp
functions* inside the Pallas kernels and in the `impl="jnp"` path, which
replays the identical tile loop on identically padded arrays in the same
accumulation order — so `impl="interpret"` and `impl="jnp"` are bit-exact
(tests/test_muon.py), the same contract the fused-update family follows.
Zero padding (rows to the sublane multiple, lanes to a TILE_N multiple) is
exact: padded rows/cols of X are zero, so their gram/apply contributions
are exact f32 zeros.

`kernels/ops.py` registers the full Muon leaf update (dequant → momentum →
NS → param update → requant) under ``("muon", impl)`` in the fused-update
registry; `kernels/ref.py` keeps the thin jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.telemetry import tracing as _tracing

# Muon quintic coefficients (Jordan et al. 2024).
NS_COEFFS = (3.4445, -4.7750, 2.0315)
DEFAULT_NS_STEPS = 5
# Lane-dim tile per grid step (multiple of the 128-lane register width).
TILE_N = 256
_SUBLANE = 8


def _pad_matrix(x: jax.Array, tile_n: int) -> jax.Array:
    """Zero-pad (m, n) so m is a sublane multiple and n a tile multiple."""
    m, n = x.shape
    mp = -(-m // _SUBLANE) * _SUBLANE
    np_ = -(-n // tile_n) * tile_n
    if (mp, np_) != (m, n):
        x = jnp.pad(x, ((0, mp - m), (0, np_ - n)))
    return x


def _gram_tile(xt: jax.Array) -> jax.Array:
    """(m, t) tile -> (m, m) partial gram, contraction over the lane dim.
    Shared verbatim by the Pallas kernel and the jnp path (parity)."""
    return jax.lax.dot_general(xt, xt, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _apply_tile(xt: jax.Array, b_mat: jax.Array, a: float) -> jax.Array:
    """One (m, t) tile of a·X + B·X.  Shared by both impls (parity)."""
    return a * xt + jax.lax.dot(b_mat, xt,
                                preferred_element_type=jnp.float32)


def _gram(x: jax.Array, tile_n: int, impl: str) -> jax.Array:
    """A = X X^T over the padded (m, n) matrix, tiled along n."""
    m, n = x.shape
    grid = (n // tile_n,)
    if impl == "jnp":
        acc = jnp.zeros((m, m), jnp.float32)
        for j in range(grid[0]):   # static loop, same order as the grid
            acc = acc + _gram_tile(
                jax.lax.dynamic_slice(x, (0, j * tile_n), (m, tile_n)))
        return acc

    def kernel(x_ref, out_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)
        out_ref[...] += _gram_tile(x_ref[...])

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, tile_n), lambda j: (0, j))],
        out_specs=pl.BlockSpec((m, m), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=(impl == "interpret"),
    )(x)


def _ns_apply(x: jax.Array, b_mat: jax.Array, a: float, tile_n: int,
              impl: str) -> jax.Array:
    """X' = a·X + B·X over the padded (m, n) matrix, tiled along n."""
    m, n = x.shape
    grid = (n // tile_n,)
    if impl == "jnp":
        tiles = [_apply_tile(
            jax.lax.dynamic_slice(x, (0, j * tile_n), (m, tile_n)),
            b_mat, a) for j in range(grid[0])]
        return tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles, axis=1)

    def kernel(x_ref, b_ref, out_ref):
        out_ref[...] = _apply_tile(x_ref[...], b_ref[...], a)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, tile_n), lambda j: (0, j)),
                  pl.BlockSpec((m, m), lambda j: (0, 0))],
        out_specs=pl.BlockSpec((m, tile_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=(impl == "interpret"),
    )(x, b_mat)


@functools.partial(jax.jit, static_argnames=("steps", "impl", "tile_n"))
def newton_schulz(x: jax.Array, *, steps: int = DEFAULT_NS_STEPS,
                  impl: str = "jnp", tile_n: int = TILE_N,
                  eps: float = 1e-7) -> jax.Array:
    """≈ orth(x): quintic Newton–Schulz on a 2-D matrix, any shape.

    Tall matrices are handled via the transpose (the iteration runs with
    the small dim first, so the gram matrix is min(m,n)²).  ``impl`` ∈
    {"pallas", "interpret", "jnp"} selects compiled kernels, the
    interpreter (CPU validation), or the tile-replaying jnp path — the
    latter two are bit-exact by construction.  Singular values of the
    result land in a band around 1 (not exactly 1): Muon only needs the
    approximate orthogonalization.
    """
    assert x.ndim == 2, x.shape
    a, b, c = NS_COEFFS
    transpose = x.shape[0] > x.shape[1]
    x = x.T if transpose else x
    shape = x.shape
    x = x.astype(jnp.float32)
    x = x / (jnp.sqrt(jnp.sum(x * x)) + jnp.float32(eps))
    x = _pad_matrix(x, tile_n)
    for _ in range(steps):
        with _tracing.annotate("ns.gram"):
            g = _gram(x, tile_n, impl)
            # Finalize the quintic's small m×m factor at the XLA level,
            # like the LAMB norm finalization (§3): B = b·A + c·A·A.
            b_mat = b * g + c * jax.lax.dot(
                g, g, preferred_element_type=jnp.float32)
        with _tracing.annotate("ns.apply"):
            x = _ns_apply(x, b_mat, a, tile_n, impl)
    out = x[:shape[0], :shape[1]]
    return out.T if transpose else out


def rms_scale(shape: tuple) -> float:
    """Muon's shape-dependent update scale: the orthogonalized update has
    RMS ~ 1/sqrt(min(m,n)); scaling by sqrt(max(1, m/n)) matches the RMS
    of an Adam-style update across aspect ratios (Jordan et al. 2024)."""
    m, n = shape
    return max(1.0, m / n) ** 0.5


def muon_math(g, p, m, *, beta1, lr, weight_decay,
              steps: int = DEFAULT_NS_STEPS, impl: str = "jnp"):
    """One fp32 Muon step on matrix-shaped (g, p, m): nesterov momentum
    EMA, NS orthogonalization, rms-matched param update.  Returns
    (m2, p2).  The single implementation shared by the quantized registry
    entry (``ops._muon_entry``) and the fp32 engine path
    (``MuonOptimizer._math32``) — the muon analogue of ``update_math``
    (§3), so the muon32 baseline and quantized muon cannot drift apart.
    ``g`` must already be gnorm-scaled; all inputs f32."""
    b1 = jnp.asarray(beta1, jnp.float32)
    m2 = b1 * m + g
    o = newton_schulz(g + b1 * m2, steps=steps, impl=impl)
    p2 = p - jnp.asarray(lr, jnp.float32) * (
        jnp.float32(rms_scale(tuple(p.shape))) * o
        + jnp.asarray(weight_decay, jnp.float32) * p)
    return m2, p2
