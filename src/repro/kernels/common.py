"""Shared in-kernel helpers for the 8-bit optimizer Pallas kernels.

TPU adaptation notes (DESIGN.md §3): the CUDA kernels of the paper use
per-thread binary search + shared-memory LUTs.  On TPU we use gather-free
formulations:

  * nearest-code search: ``code = sum_j [x >= b_j]`` over the 255 midpoint
    boundaries — broadcast compare + integer sum on the VPU, chunked over the
    codebook axis so the materialized compare tile stays small in VMEM.
  * codebook lookup: chunked one-hot contraction ``one_hot(code) @ qmap`` —
    the MXU-friendly analogue of an SRAM LUT.

Codebook/boundary inputs are padded to 256 lanes (boundary 256 = +inf) so the
last dim is hardware-aligned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

CODEBOOK_SIZE = 256
# Codebook-axis chunk: bounds the (tile_elems, CHUNK) compare/one-hot
# materialization in VMEM.
CHUNK = 64
# Quantization blocks per grid step, shared by every kernel in this package
# (DESIGN.md §3: one value so the fused-update and quant/dequant kernels tile
# the flat block domain identically).
DEFAULT_ROWS = 8
# Seed offsets decorrelating the two state tensors' stochastic rounding.
STATE1_SEED_SALT = 0
STATE2_SEED_SALT = 0x9E3779B9


def padded_bounds(codebook) -> jax.Array:
    """Midpoint decision boundaries padded with +inf to 256 lanes, (1, 256).

    For an L-entry codebook (L = 2^bits ≤ 256) the L-1 real boundaries are
    followed by +inf padding, so ``encode`` can only emit codes < L — the
    k-bit maps cap their code range for free."""
    cb = jnp.asarray(codebook, dtype=jnp.float32)
    b = (cb[1:] + cb[:-1]) * 0.5
    pad = CODEBOOK_SIZE - b.shape[0]
    b = jnp.concatenate([b, jnp.full((pad,), jnp.inf, jnp.float32)])
    return b.reshape(1, CODEBOOK_SIZE)


def padded_qmap(codebook) -> jax.Array:
    """Codebook zero-padded to 256 lanes, (1, 256) f32.  Padding entries are
    unreachable: codes from ``encode``/``block_requantize`` stay below the
    real level count."""
    cb = jnp.asarray(codebook, dtype=jnp.float32)
    pad = CODEBOOK_SIZE - cb.shape[0]
    if pad:
        cb = jnp.concatenate([cb, jnp.zeros((pad,), jnp.float32)])
    return cb.reshape(1, CODEBOOK_SIZE)


def _n_chunks(n_levels: int) -> int:
    """Codebook chunks that can contain live lanes for an n_levels map."""
    return -(-min(n_levels, CODEBOOK_SIZE) // CHUNK)


def encode(x_norm: jax.Array, bounds_row: jax.Array,
           n_levels: int = CODEBOOK_SIZE) -> jax.Array:
    """Nearest-code indices for normalized values in [-1, 1].

    x_norm: (..., N) f32; bounds_row: (1, 256) f32 (+inf beyond the real
    boundaries).  Returns int32 codes. ``sum_j [x >= b_j]`` ==
    searchsorted(side='right').  ``n_levels`` (2^bits for k-bit maps)
    bounds the chunk sweep: lanes past it are +inf and contribute nothing,
    so sub-byte codebooks skip ~3/4 of the compare work.
    """
    flat = x_norm.reshape(-1)
    acc = jnp.zeros(flat.shape, dtype=jnp.int32)
    for c in range(0, _n_chunks(n_levels) * CHUNK, CHUNK):
        chunk = jax.lax.dynamic_slice(bounds_row, (0, c), (1, CHUNK))  # (1, CHUNK)
        acc = acc + jnp.sum(
            (flat[:, None] >= chunk).astype(jnp.int32), axis=-1
        )
    return acc.reshape(x_norm.shape)


def decode(codes: jax.Array, qmap_row: jax.Array,
           n_levels: int = CODEBOOK_SIZE) -> jax.Array:
    """Codebook lookup via chunked one-hot contraction (MXU-friendly).

    codes: (..., N) int32 in [0, n_levels); qmap_row: (1, 256) f32.
    ``n_levels`` bounds the chunk sweep (codes never reach padded lanes).
    """
    flat = codes.reshape(-1)
    acc = jnp.zeros(flat.shape, dtype=jnp.float32)
    for c in range(0, _n_chunks(n_levels) * CHUNK, CHUNK):
        chunk = jax.lax.dynamic_slice(qmap_row, (0, c), (1, CHUNK))[0]  # (CHUNK,)
        onehot = (flat[:, None] == (c + jax.lax.iota(jnp.int32, CHUNK))[None, :])
        acc = acc + jax.lax.dot(
            onehot.astype(jnp.float32), chunk[:, None],
            preferred_element_type=jnp.float32,
        )[:, 0]
    return acc.reshape(codes.shape)


def hash_uniform(idx: jax.Array, seed: jax.Array) -> jax.Array:
    """Counter-based uniform [0, 1) floats from element index + seed.

    A finalizer-style integer hash on the VPU (uint32 wraparound arithmetic):
    no gathers, no host PRNG round trip, bit-identical between the Pallas
    kernel and the jnp reference — which is what makes the stochastic-rounding
    parity tests exact (DESIGN.md §3).  ``pltpu.prng_random_bits`` would also
    work on TPU but has no interpret-mode lowering on CPU.
    """
    x = idx.astype(jnp.uint32) + seed.astype(jnp.uint32) * jnp.uint32(2654435761)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x21F0AAAD)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x735A2D97)
    x = x ^ (x >> 15)
    # Top-of-24-bits mantissa -> exactly representable uniform in [0, 1).
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def element_indices(n_rows: int, n_cols: int, row_offset) -> jax.Array:
    """Global flat element index for a (n_rows, n_cols) tile whose first row
    is ``row_offset`` in the full block domain. uint32, wraps harmlessly."""
    r = jax.lax.broadcasted_iota(jnp.uint32, (n_rows, n_cols), 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, (n_rows, n_cols), 1)
    off = jnp.asarray(row_offset).astype(jnp.uint32)
    return (off + r) * jnp.uint32(n_cols) + c


def stochastic_codes(x_norm: jax.Array, codes: jax.Array, q_near: jax.Array,
                     q_other: jax.Array, other: jax.Array,
                     u: jax.Array) -> jax.Array:
    """Pick the far neighbour with probability proportional to proximity.

    Shared verbatim by the Pallas kernels and the jnp reference so both
    produce identical codes for identical uniforms."""
    span = jnp.abs(q_other - q_near)
    p_other = jnp.where(span > 0,
                        jnp.abs(x_norm - q_near) / jnp.where(span > 0, span, 1.0),
                        0.0)
    return jnp.where(u < p_other, other, codes)


def block_requantize(x: jax.Array, bounds_row: jax.Array,
                     qmap_row: jax.Array | None = None,
                     random_u: jax.Array | None = None,
                     max_code: int = CODEBOOK_SIZE - 1
                     ) -> tuple[jax.Array, jax.Array]:
    """Per-row absmax normalize + encode. x: (R, B) f32 ->
    (codes int32 (R, B), absmax f32 (R, 1)).

    With ``random_u`` (uniforms in [0, 1), same shape as x) the encode is
    stochastic: round to the nearer/farther neighbouring code with
    probability proportional to proximity (paper App H). ``qmap_row`` is
    required in that case for the neighbour lookups.  ``max_code`` is the
    highest valid code (2^bits - 1 for k-bit codebooks); deterministic
    encode respects it by construction via the +inf boundary padding."""
    n_levels = max_code + 1
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    x_norm = x / scale
    codes = encode(x_norm, bounds_row, n_levels)
    if random_u is not None:
        q_near = decode(codes, qmap_row, n_levels)
        direction = jnp.where(x_norm > q_near, 1, -1)
        other = jnp.clip(codes + direction, 0, max_code)
        q_other = decode(other, qmap_row, n_levels)
        codes = stochastic_codes(x_norm, codes, q_near, q_other, other, random_u)
    return codes, absmax
