"""Shared in-kernel helpers for the 8-bit optimizer Pallas kernels.

TPU adaptation notes (DESIGN.md §3): the CUDA kernels of the paper use
per-thread binary search + shared-memory LUTs.  On TPU we use gather-free
formulations:

  * nearest-code search: ``code = sum_j [x >= b_j]`` over the 255 midpoint
    boundaries — broadcast compare + integer sum on the VPU, chunked over the
    codebook axis so the materialized compare tile stays small in VMEM.
  * codebook lookup: chunked one-hot contraction ``one_hot(code) @ qmap`` —
    the MXU-friendly analogue of an SRAM LUT.

Codebook/boundary inputs are padded to 256 lanes (boundary 256 = +inf) so the
last dim is hardware-aligned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

CODEBOOK_SIZE = 256
# Codebook-axis chunk: bounds the (tile_elems, CHUNK) compare/one-hot
# materialization in VMEM.
CHUNK = 64


def padded_bounds(codebook) -> jax.Array:
    """255 midpoint boundaries padded with +inf to 256 lanes, shape (1, 256)."""
    cb = jnp.asarray(codebook, dtype=jnp.float32)
    b = (cb[1:] + cb[:-1]) * 0.5
    b = jnp.concatenate([b, jnp.full((1,), jnp.inf, jnp.float32)])
    return b.reshape(1, CODEBOOK_SIZE)


def padded_qmap(codebook) -> jax.Array:
    """Codebook as (1, 256) f32."""
    return jnp.asarray(codebook, dtype=jnp.float32).reshape(1, CODEBOOK_SIZE)


def encode(x_norm: jax.Array, bounds_row: jax.Array) -> jax.Array:
    """Nearest-code indices for normalized values in [-1, 1].

    x_norm: (..., N) f32; bounds_row: (1, 256) f32 (last = +inf).
    Returns int32 codes. ``sum_j [x >= b_j]`` == searchsorted(side='right').
    """
    flat = x_norm.reshape(-1)
    acc = jnp.zeros(flat.shape, dtype=jnp.int32)
    for c in range(0, CODEBOOK_SIZE, CHUNK):
        chunk = jax.lax.dynamic_slice(bounds_row, (0, c), (1, CHUNK))  # (1, CHUNK)
        acc = acc + jnp.sum(
            (flat[:, None] >= chunk).astype(jnp.int32), axis=-1
        )
    return acc.reshape(x_norm.shape)


def decode(codes: jax.Array, qmap_row: jax.Array) -> jax.Array:
    """Codebook lookup via chunked one-hot contraction (MXU-friendly).

    codes: (..., N) int32 in [0, 255]; qmap_row: (1, 256) f32.
    """
    flat = codes.reshape(-1)
    acc = jnp.zeros(flat.shape, dtype=jnp.float32)
    for c in range(0, CODEBOOK_SIZE, CHUNK):
        chunk = jax.lax.dynamic_slice(qmap_row, (0, c), (1, CHUNK))[0]  # (CHUNK,)
        onehot = (flat[:, None] == (c + jax.lax.iota(jnp.int32, CHUNK))[None, :])
        acc = acc + jax.lax.dot(
            onehot.astype(jnp.float32), chunk[:, None],
            preferred_element_type=jnp.float32,
        )[:, 0]
    return acc.reshape(codes.shape)


def block_requantize(x: jax.Array, bounds_row: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row absmax normalize + encode. x: (R, B) f32 ->
    (codes int32 (R, B), absmax f32 (R, 1))."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    codes = encode(x / scale, bounds_row)
    return codes, absmax
