"""Pallas TPU kernel: block-wise 8-bit dequantization.

Codebook lookup is a chunked one-hot contraction (MXU) — the TPU analogue of
the CUDA shared-memory LUT gather (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import common

DEFAULT_ROWS = common.DEFAULT_ROWS


def _dequant_kernel(codes_ref, absmax_ref, qmap_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)        # (ROWS, B)
    vals = common.decode(codes, qmap_ref[...])      # f32
    out_ref[...] = (vals * absmax_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows", "interpret", "dtype"))
def dequantize_blockwise(
    codes: jax.Array,
    absmax: jax.Array,
    codebook: jax.Array,
    *,
    rows: int = DEFAULT_ROWS,
    interpret: bool = True,
    dtype=jnp.float32,
) -> jax.Array:
    """(codes (n_blocks, B), absmax (n_blocks,)) -> values (n_blocks, B)."""
    n_blocks, bsz = codes.shape
    assert n_blocks % rows == 0, (n_blocks, rows)
    qmap = common.padded_qmap(codebook)
    grid = (n_blocks // rows,)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, bsz), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, common.CODEBOOK_SIZE), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, bsz), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, bsz), dtype),
        interpret=interpret,
    )(codes, absmax[:, None], qmap)
    return out
