"""Pallas TPU kernel: fused 8-bit Adam/AdamW update (the paper's hot kernel).

One HBM pass per state tensor: stream codes(m), codes(r), absmax(m), absmax(r),
param, grad in; dequantize + 32-bit Adam math + per-block absmax + requantize
happen entirely in VMEM/VREGs; stream param', codes', absmax' out.  This is
the TPU realization of the paper's "8-bit to 32-bit conversion
element-by-element in registers" (§2) — see DESIGN.md §3 for the mapping.

Arithmetic intensity is ~O(600) VPU/MXU ops per ~11 bytes streamed; on v5e the
kernel sits on the HBM-bandwidth roofline (the codebook search adds compute
but stays under the memory time for ROWS<=8; see EXPERIMENTS.md §Perf napkin
math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import common

DEFAULT_ROWS = 4

# scalar vector layout: [lr, beta1, beta2, eps, weight_decay, step, 0, 0]
N_SCALARS = 8


def _adam8_kernel(
    scal_ref,       # (1, 8) f32
    qm_ref,         # (1, 256) signed qmap
    bm_ref,         # (1, 256) signed bounds (+inf padded)
    qr_ref,         # (1, 256) unsigned qmap
    br_ref,         # (1, 256) unsigned bounds
    p_ref,          # (ROWS, B) f32
    g_ref,          # (ROWS, B) f32/bf16
    cm_ref,         # (ROWS, B) uint8
    am_ref,         # (ROWS, 1) f32
    cr_ref,         # (ROWS, B) uint8
    ar_ref,         # (ROWS, 1) f32
    p_out,          # (ROWS, B) f32
    cm_out, am_out, cr_out, ar_out,
):
    lr = scal_ref[0, 0]
    b1 = scal_ref[0, 1]
    b2 = scal_ref[0, 2]
    eps = scal_ref[0, 3]
    wd = scal_ref[0, 4]
    step = scal_ref[0, 5]

    g = g_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)

    # ---- dequantize (one-hot contraction on MXU) ----
    m = common.decode(cm_ref[...].astype(jnp.int32), qm_ref[...]) * am_ref[...]
    r = common.decode(cr_ref[...].astype(jnp.int32), qr_ref[...]) * ar_ref[...]

    # ---- 32-bit Adam math in registers ----
    m = b1 * m + (1.0 - b1) * g
    r = b2 * r + (1.0 - b2) * g * g
    c1 = 1.0 - jnp.power(b1, step)
    c2 = 1.0 - jnp.power(b2, step)
    update = (m / c1) / (jnp.sqrt(r / c2) + eps) + wd * p
    p_out[...] = (p - lr * update).astype(p_out.dtype)

    # ---- requantize (per-block absmax is a row reduction in VMEM) ----
    cm_new, am_new = common.block_requantize(m, bm_ref[...])
    cr_new, ar_new = common.block_requantize(r, br_ref[...])
    cm_out[...] = cm_new.astype(jnp.uint8)
    am_out[...] = am_new
    cr_out[...] = cr_new.astype(jnp.uint8)
    ar_out[...] = ar_new


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def adam8_update(
    p: jax.Array,         # (n_blocks, B) f32
    g: jax.Array,         # (n_blocks, B)
    codes_m: jax.Array,   # (n_blocks, B) uint8
    absmax_m: jax.Array,  # (n_blocks,) f32
    codes_r: jax.Array,
    absmax_r: jax.Array,
    qmap_m: jax.Array,    # (256,)
    qmap_r: jax.Array,    # (256,)
    scalars: jax.Array,   # (8,) f32: lr, b1, b2, eps, wd, step
    *,
    rows: int = DEFAULT_ROWS,
    interpret: bool = True,
):
    n_blocks, bsz = p.shape
    assert n_blocks % rows == 0, (n_blocks, rows)
    qm, qr = qmap_m, qmap_r
    consts = (
        common.padded_qmap(qm),
        common.padded_bounds(qm),
        common.padded_qmap(qr),
        common.padded_bounds(qr),
    )
    grid = (n_blocks // rows,)
    row_spec = pl.BlockSpec((rows, bsz), lambda i: (i, 0))
    one_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    const_spec = pl.BlockSpec((1, common.CODEBOOK_SIZE), lambda i: (0, 0))
    scal_spec = pl.BlockSpec((1, N_SCALARS), lambda i: (0, 0))
    outs = pl.pallas_call(
        _adam8_kernel,
        grid=grid,
        in_specs=[scal_spec, const_spec, const_spec, const_spec, const_spec,
                  row_spec, row_spec, row_spec, one_spec, row_spec, one_spec],
        out_specs=[row_spec, row_spec, one_spec, row_spec, one_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, bsz), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, bsz), jnp.uint8),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, bsz), jnp.uint8),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        ],
        interpret=interpret,
    )(scalars.reshape(1, N_SCALARS), *consts,
      p, g, codes_m, absmax_m[:, None], codes_r, absmax_r[:, None])
    p_new, cm, am, cr, ar = outs
    return p_new, cm, am[:, 0], cr, ar[:, 0]
