"""Logical-axis sharding rules -> PartitionSpecs (DESIGN.md §4).

Every param carries a tuple of logical axis names (from the model init).
Resolution is greedy and *divisibility-safe*:

  pass 1 (TP): each logical name tries its preferred mesh axes; an axis is
    taken only if it divides the dim and isn't already used on this param.
    (qwen's 40 heads on a 16-way 'model' axis simply fall through — the
    assignment's sharding footgun, handled by construction.)
  pass 2 (FSDP): remaining axes (pod, data, and 'model' if still free) are
    swept onto the largest divisible dims of large params, fully sharding
    weights ZeRO-3 style.

Optimizer state: Quant8Leaf lives in the flat block domain — codes/absmax/
master shard their block dim over *all* mesh axes (whole quantization blocks
per device); Full32Leaf mirrors the param's spec.  Bit-packed sub-byte codes
(``PackedCodes``, DESIGN.md §9) shard the *block-count* axis (dim 0) exactly
like plain codes — never the byte axis, whose width is a per-block packing
detail — so k-bit states inherit the whole-blocks-per-device guarantee.
The pooled dispatch's ``QuantArena`` (DESIGN.md §10) is that same flat
block domain with every quantized leaf concatenated, and shards
identically (block dim over all axes); pooled masters keep the param spec
and the fp32 small-leaf pool (``Pool32Arena``) is replicated.  Muon's
matrix momentum (DESIGN.md §11) needs no extra rule: it is a one-state
``Quant8Leaf`` riding per-leaf inside the pooled layout, so the block dim
of its codes/absmax shards over all axes like every other quantized state
while the Newton–Schulz matmuls consume the (param-sharded) matrix view.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis import contracts as _contracts
from repro.analysis import mutations as _mutations
from repro.core.lowbit import PackedCodes
from repro.core.optim.base import (Full32Leaf, Pool32Arena, Pool32Leaf,
                                   PooledQuantLeaf, Quant8Leaf, QuantArena)
from repro.core.optim.adafactor import AdafactorLeaf
from repro.errors import ConfigError
from repro.kernels import common as _kernels_common

Pytree = Any

# preferred mesh axes per logical axis name (pass 1)
DEFAULT_TP_RULES = {
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "lru": ("model",),
    "head_out": (),
    "embed": (),            # embed dim is FSDP territory, not TP
    "embed_out": (),
    "layers": (),           # scan dim: never sharded
    "unsharded": (),
}


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    tp_rules: Optional[dict] = None
    fsdp_axes: tuple = ("pod", "data")
    fsdp_include_model_if_free: bool = True
    fsdp_min_size: int = 1 << 20       # params smaller than 1M stay replicated
    data_axes: tuple = ("pod", "data")  # batch sharding
    # Params containing these logical dims are left out of the FSDP sweep:
    # a head/embedding that is both vocab-TP and embed-FSDP makes SPMD
    # resolve the head backward by all-gathering f32 logit grads
    # (26 GiB/device measured; EXPERIMENTS.md §Perf C3).
    fsdp_exclude_logical: tuple = ("vocab",)

    def rules(self):
        r = dict(DEFAULT_TP_RULES)
        if self.tp_rules:
            r.update(self.tp_rules)
        return r


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def resolve_spec(logical: tuple, shape: tuple, mesh: Mesh,
                 policy: ShardingPolicy) -> P:
    """Greedy TP + FSDP resolution for one param."""
    rules = policy.rules()
    if len(logical) != len(shape):
        raise ConfigError(f"logical axes {logical} do not match param "
                          f"shape {shape}")
    assign: list[list[str]] = [[] for _ in shape]
    used: set[str] = set()
    avail = set(mesh.axis_names)

    # pass 1: TP preferences
    for i, (name, dim) in enumerate(zip(logical, shape)):
        for ax in rules.get(name, ()):  # unknown names -> no TP
            if ax in avail and ax not in used and dim % _axis_size(mesh, ax) == 0:
                assign[i].append(ax)
                used.add(ax)
                break

    # pass 2: FSDP sweep for large params
    if (int(np.prod(shape)) >= policy.fsdp_min_size
            and not any(l in policy.fsdp_exclude_logical for l in logical)):
        fsdp = list(policy.fsdp_axes)
        if policy.fsdp_include_model_if_free and "model" not in used \
                and "model" in avail:
            fsdp.append("model")
        for ax in fsdp:
            if ax not in avail or ax in used:
                continue
            # place on the largest dim still divisible by the extra factor
            order = sorted(range(len(shape)),
                           key=lambda i: -(shape[i] // max(
                               math.prod(_axis_size(mesh, a) for a in assign[i]), 1)))
            for i in order:
                if logical[i] in ("layers",):
                    continue
                cur = math.prod(_axis_size(mesh, a) for a in assign[i]) if assign[i] else 1
                if shape[i] % (cur * _axis_size(mesh, ax)) == 0:
                    assign[i].append(ax)
                    used.add(ax)
                    break

    return P(*[tuple(a) if len(a) > 1 else (a[0] if a else None)
               for a in assign])


def param_shardings(specs: Pytree, abstract_params: Pytree, mesh: Mesh,
                    policy: ShardingPolicy) -> Pytree:
    """Tree of NamedShardings matching the params tree."""
    def one(spec, p):
        return NamedSharding(mesh, resolve_spec(tuple(spec), tuple(p.shape),
                                                mesh, policy))
    is_spec = lambda t: isinstance(t, tuple) and all(isinstance(e, str) for e in t)
    return jax.tree_util.tree_map(one, specs, abstract_params, is_leaf=is_spec)


def flat_block_spec(mesh: Mesh) -> P:
    """Spec for the flat block domain: block dim over ALL mesh axes."""
    return P(tuple(mesh.axis_names), None)


# ------------------------------------------ partitioned (ZeRO-1) plumbing
# (DESIGN.md §12.)  The partitioned optimizer dispatch splits the pooled
# arenas' leading dim into per-owner spans (core.optim.base.ArenaPartition)
# and runs each span on its owner.  These helpers own the mesh mechanics:
# the owned-span PartitionSpec, the shard_map wrapper that pads the arena
# to the partition's padded domain and runs one local update per device
# (grads reduce-scatter into the span layout on entry; updated master
# slices all-gather at their use sites), and the whole-leaf owner routing
# used for muon matrix leaves.


def owned_span_spec(ndim: int, axes="data") -> P:
    """Spec placing dim 0 (the block/element dim) on the partition
    axes (a name or tuple of names, e.g. ("pod", "data")): each device
    holds exactly its owned span of the padded arena."""
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    return P(axes, *([None] * (ndim - 1)))


def shard_map_over_spans(mesh: Mesh, axes, part, fn, spans, consts=()):
    """Run ``fn(args, consts)`` with every array in ``spans`` split into
    per-owner spans of ``part`` (an ArenaPartition) along dim 0.

    Arrays are padded from ``part.total`` to ``part.padded_total`` rows
    (trailing owners own padding — their kernels run on zeros, discarded
    on unpad), resharded onto the partition ``axes`` (this is the grads'
    reduce-scatter when they arrive replicated or otherwise sharded), and
    each device calls ``fn`` once on its local ``(span_pad, ...)`` views.
    ``consts`` are replicated operands (codebooks, traced scalars).
    Outputs must be span-shaped arrays; they come back unpadded to
    ``part.total`` rows.
    """
    from jax.experimental.shard_map import shard_map
    import jax.numpy as jnp

    pad = part.padded_total - part.total

    def padrows(a):
        a = jnp.asarray(a)
        if pad == 0:
            return a
        return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))

    arrays = [padrows(a) for a in spans]
    consts = tuple(consts)
    n_arr = len(arrays)

    def inner(*flat):
        return fn(flat[:n_arr], flat[n_arr:])

    in_specs = tuple(owned_span_spec(a.ndim, axes) for a in arrays) \
        + tuple(P() for _ in consts)
    local_args = [jax.ShapeDtypeStruct((part.span_pad,) + a.shape[1:],
                                       a.dtype) for a in arrays]
    # out-spec inference must not perturb the trace-time dispatch counter
    # (opt_fused_dispatches counts real launches only)
    from repro.kernels import ops as _kops
    with _kops.dispatch_count_paused():
        out_shapes = jax.eval_shape(inner, *local_args, *consts)
    out_specs = tuple(owned_span_spec(len(o.shape), axes)
                      for o in out_shapes)
    outs = shard_map(inner, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)(*arrays, *consts)
    return tuple(o[:part.total] for o in outs)


def replicate_for_scales(mesh: Mesh, arrays):
    """Constrain arrays to fully-replicated placement so a following
    global reduction (the LAMB/LARS segment-norm pass) compiles as the
    oracle's single-device reduction on every device — SPMD distributing
    it would change the f32 summation order (DESIGN.md §12)."""
    if _mutations.active("drop_replication_pin"):
        # Seeded violation for the replicated(...) auditor (analysis §15):
        # skip the pin so the partitioned lowering loses its §12 guarantee.
        return tuple(arrays)
    rep = NamedSharding(mesh, P())

    def one(x):
        if x is None:
            return None
        if isinstance(x, PackedCodes):
            return dataclasses.replace(
                x, packed=jax.lax.with_sharding_constraint(x.packed, rep))
        return jax.lax.with_sharding_constraint(x, rep)

    return tuple(one(a) for a in arrays)


def owner_routed(mesh: Mesh, axes, owner: int, fn, args):
    """Whole-leaf owner routing (muon matrix leaves, DESIGN.md §12): only
    the device whose (major-to-minor combined) index along the partition
    ``axes`` equals ``owner`` computes ``fn(*args)``; the result
    broadcasts to the replicas via a psum against zeros.  All result
    leaves round-trip through f32 (exact for uint8 codes and f32 state),
    so the broadcast is bit-exact."""
    from jax.experimental.shard_map import shard_map
    import jax.numpy as jnp
    from repro.kernels import ops as _kops

    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    with _kops.dispatch_count_paused():
        out_tree = jax.eval_shape(fn, *args)

    def routed(*a):
        def compute(ops):
            out = fn(*ops)
            return jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float32), out)

        def zeros(ops):
            del ops
            return jax.tree_util.tree_map(
                lambda sd: jnp.zeros(sd.shape, jnp.float32), out_tree)

        idx = jax.lax.axis_index(axes[0])
        for ax in axes[1:]:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        outf = jax.lax.cond(idx == owner, compute, zeros, a)
        outf = jax.lax.psum(outf, axes)
        return jax.tree_util.tree_map(lambda x, sd: x.astype(sd.dtype),
                                      outf, out_tree)

    return shard_map(routed, mesh=mesh,
                     in_specs=tuple(P() for _ in args),
                     out_specs=P(), check_rep=False)(*args)


def opt_state_shardings(abstract_opt_state, param_shard_tree, mesh: Mesh,
                        policy: ShardingPolicy):
    """Shardings for a Block8bitOptimizer / Adafactor state."""
    blocks = NamedSharding(mesh, flat_block_spec(mesh))
    vec = NamedSharding(mesh, P(tuple(mesh.axis_names)))
    rep = NamedSharding(mesh, P())

    def code_sharding(c):
        # Packed codes: the sharding rides on the packed uint8 child so
        # the tree mirrors the state's structure; dim 0 is still the
        # block-count axis, the byte axis stays unsharded.
        if isinstance(c, PackedCodes):
            return dataclasses.replace(c, packed=blocks)
        return blocks

    def leaf(st, pshard):
        if isinstance(st, Quant8Leaf):
            return Quant8Leaf(master=pshard, codes_m=code_sharding(st.codes_m),
                              absmax_m=vec,
                              codes_r=None if st.codes_r is None
                              else code_sharding(st.codes_r),
                              absmax_r=None if st.absmax_r is None else vec,
                              shape=st.shape, n=st.n)
        if isinstance(st, PooledQuantLeaf):
            # pooled dispatch (DESIGN.md §10): only the param-shaped master
            # lives per leaf; the arena is sharded below.
            return dataclasses.replace(st, master=pshard)
        if isinstance(st, Pool32Leaf):
            return st                      # no arrays; Pool32Arena below
        if isinstance(st, Full32Leaf):
            return Full32Leaf(master=pshard, m=pshard,
                              r=None if st.r is None else pshard)
        if isinstance(st, AdafactorLeaf):
            def reduce_last(ps, drop_axis):
                spec = list(ps.spec) + [None] * (st.master.ndim - len(ps.spec))
                del spec[drop_axis]
                return NamedSharding(mesh, P(*spec))
            return AdafactorLeaf(
                master=pshard, m=pshard,
                v_row=None if st.v_row is None else reduce_last(pshard, -1),
                v_col=None if st.v_col is None else reduce_last(pshard, -2),
                v_full=None if st.v_full is None else pshard)
        raise TypeError(type(st))

    is_state_leaf = lambda x: isinstance(
        x, (Quant8Leaf, Full32Leaf, PooledQuantLeaf, Pool32Leaf,
            AdafactorLeaf))
    leaves = jax.tree_util.tree_map(leaf, abstract_opt_state.leaves,
                                    param_shard_tree, is_leaf=is_state_leaf)
    extra = {}
    if getattr(abstract_opt_state, "gnorm_vec", None) is not None:
        # percentile-clipping gnorm history: tiny, replicated everywhere
        extra["gnorm_vec"] = rep
    arena = getattr(abstract_opt_state, "arena", None)
    if arena is not None:
        # the arena is the flat block domain itself: block dim over ALL
        # mesh axes, exactly like per-leaf codes (total_blocks is a sum of
        # per-leaf shard_multiple-padded counts, so it divides evenly)
        extra["arena"] = QuantArena(
            codes_m=code_sharding(arena.codes_m), absmax_m=vec,
            codes_r=None if arena.codes_r is None
            else code_sharding(arena.codes_r),
            absmax_r=None if arena.absmax_r is None else vec,
            segments=arena.segments,
            partition=getattr(arena, "partition", None))
    pool32 = getattr(abstract_opt_state, "pool32", None)
    if pool32 is not None:
        # pooled small leaves: tiny by construction, replicated like the
        # per-leaf Full32 small leaves they replace
        extra["pool32"] = Pool32Arena(
            master=rep, m=rep, r=None if pool32.r is None else rep,
            segments=pool32.segments,
            partition=getattr(pool32, "partition", None))
    return type(abstract_opt_state)(step=rep, leaves=leaves, **extra)


def batch_sharding(mesh: Mesh, policy: ShardingPolicy, ndim: int = 2,
                   batch_dim_size: Optional[int] = None):
    """Batch-dim sharding over the data axes; drops axes that do not divide
    the batch (long_500k has global_batch=1 -> fully replicated)."""
    axes = tuple(a for a in policy.data_axes if a in mesh.axis_names)
    if batch_dim_size is not None:
        kept = []
        prod = 1
        for a in axes:
            if batch_dim_size % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        axes = tuple(kept)
    if not axes:
        return NamedSharding(mesh, P(*([None] * ndim)))
    return NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))


def cache_shardings(abstract_cache, cfg, mesh: Mesh, policy: ShardingPolicy):
    """KV-cache / recurrent-state shardings for serving.

    batch dim -> data axes.  Attention caches additionally shard kv_heads on
    'model' when divisible, else the *sequence* dim on 'model' (sequence
    parallelism for GQA kv < model axis — DESIGN.md §4).
    """
    dp = tuple(a for a in policy.data_axes if a in mesh.axis_names)
    msize = mesh.shape.get("model", 1)

    def one(x):
        shape = x.shape
        nd = len(shape)
        lead_scan = cfg.scan_layers and cfg.n_superblocks > 0
        spec = [None] * nd
        b_idx = 1 if lead_scan else 0
        if nd > b_idx and shape[b_idx] % max(
                math.prod(mesh.shape[a] for a in dp), 1) == 0:
            spec[b_idx] = dp
        # attention kv cache: (..., B, S, KV, Dh) or absmax (..., B, S, KV)
        if nd - b_idx in (3, 4) and "model" in mesh.axis_names:
            kv_idx = nd - 2 if nd - b_idx == 4 else nd - 1
            s_idx = kv_idx - 1
            if shape[kv_idx] % msize == 0:
                spec[kv_idx] = "model"
            elif shape[s_idx] % msize == 0:
                spec[s_idx] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, abstract_cache)


# ------------------------------------------------- compile contracts (§15)
# replicate_for_scales is a with_sharding_constraint, so dropping it never
# changes numerics on one device — only the *lowering* betrays the loss.
# These contracts pin the §12 guarantee at the StableHLO level.

def _check_replicated_scales(low, cell):
    if getattr(cell, "partition", 1) <= 1:
        return None  # no mesh, no pins to check
    # Count only vector pins and skip the (256,) codebook constants: those
    # are pinned by the arena layout regardless of replicate_for_scales,
    # so a lost scale pin must not hide behind them.
    return _contracts.check_replicated(
        low.text, min_pins=1, vectors_only=True,
        exclude_shapes=((_kernels_common.CODEBOOK_SIZE,),))


def _check_partition_pins(pair, cell):
    """pair:partition — the partitioned lowering must carry the §12
    replication pins its unpartitioned twin has no reason to emit."""
    pins = {k: _contracts.replicated_pins(low.text)
            for k, low in pair.items()}
    on = max(pins.values())
    off = min(pins.values())
    ok = on >= 1 and on > off
    return ok, f"replicated pins per partition setting: {pins}"


_contracts.register(
    "partitioned_step.replicated_scales", "step", _check_replicated_scales,
    doc="partitioned lowering pins tensor scales / gnorm reductions "
        "fully replicated (§12 bit-exactness)")
_contracts.register(
    "partitioned_step.partition_pins", "pair:partition",
    _check_partition_pins,
    doc="turning partitioning on introduces replication pins; turning it "
        "off removes them (the pin is partition-conditional, §12)")
