"""Fault-tolerant checkpointing.

Format: one directory per step, containing ``leaves.npz`` (every pytree leaf
keyed by its tree path) + ``manifest.json`` (step, leaf index, dtypes).
Writes are atomic (tmp dir + rename), ``keep_last`` old steps are pruned,
and ``latest_step`` scans the directory so restart-after-crash needs no
bookkeeping.

Because leaves are stored as *full logical arrays* keyed by path (not by
device shard), restore is **elastic**: the same checkpoint can be loaded
onto any mesh shape / sharding — restore takes a template pytree (built with
``jax.eval_shape``) and optional per-leaf shardings and device_puts
accordingly.  8-bit optimizer states are stored as their uint8 codes +
f32 absmax, so checkpoints are ~4x smaller than fp32-state checkpoints —
the paper's memory saving carried through to the storage/restore path.

Auxiliary optimizer state rides along unchanged: the percentile-clipping
gnorm history (``OptState.gnorm_vec``) is an ordinary f32 leaf, so a
restored run resumes with the exact clipping statistics it left with
(tests/test_checkpoint.py round-trips it).  ``None`` leaves (e.g. the
history when clipping is off) are recorded in the manifest and restored
as ``None``.

Bit-packed sub-byte states (``PackedCodes``, DESIGN.md §9) are stored as
their packed uint8 words with a ``"packed": {"bits", "n_codes"}`` manifest
annotation; restore validates the annotation against the template's static
format (a 4-bit checkpoint cannot silently load as 5-bit — same byte
count, different codes) and re-wraps the array.  Because packing is a
per-block layout detail and the full logical array is stored, packed
leaves stay elastic: the same checkpoint restores onto any mesh.

Pooled optimizer states (``OptimConfig.pooled``, DESIGN.md §10) are stored
**per-leaf**: ``save`` slices every arena back into the per-leaf canonical
layout (``blockopt.unpool_state``) before writing, and ``restore``
reassembles arenas to match the template (``blockopt.repool_like``).  The
on-disk format is therefore independent of the pooling flag — per-leaf
checkpoints restore into pooled states and vice versa, on any mesh.

Partitioned (ZeRO-1) states (``OptimConfig.partition``, DESIGN.md §12)
add nothing on disk: the ``ArenaPartition`` is static arena aux metadata
that ``unpool_state`` drops on save and ``repool_like`` reattaches from
the restore template, so partitioned ↔ pooled ↔ per-leaf interchange is
elastic in all six directions and across shard counts
(tests/test_partition.py interchange matrix).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.core.lowbit import PackedCodes

Pytree = Any


def _is_packed(x) -> bool:
    return isinstance(x, PackedCodes)


def _canonical(tree: Pytree) -> Pytree:
    """Per-leaf canonical view of every OptState in the tree (identity for
    trees without pooled optimizer states)."""
    from repro.core.optim import blockopt
    return blockopt.map_opt_states(tree, blockopt.unpool_state)


def _repool(tree: Pytree, template: Pytree) -> Pytree:
    """Reassemble pooled arenas to match ``template`` (identity when the
    template has no pooled optimizer states)."""
    from repro.core.optim import blockopt
    return blockopt.zip_opt_states(tree, template, blockopt.repool_like)


def _check_no_orphan_pooled(tree: Pytree) -> None:
    """Pooled containers outside an OptState cannot be canonicalized (the
    arena and its per-leaf nodes live on sibling OptState fields), so e.g.
    saving ``state.leaves`` alone would silently drop every quantized
    statistic.  Fail loudly instead."""
    from repro.core.optim import base as optim_base
    pooled = (optim_base.PooledQuantLeaf, optim_base.Pool32Leaf,
              optim_base.QuantArena, optim_base.Pool32Arena)
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: _is_packed(x) or isinstance(x, pooled))[0]
    bad = [jax.tree_util.keystr(p) for p, l in flat if isinstance(l, pooled)]
    if bad:
        raise ValueError(
            f"cannot checkpoint pooled optimizer containers outside their "
            f"OptState (their arena/per-leaf halves live on sibling "
            f"fields): {bad[:5]}{'...' if len(bad) > 5 else ''} — save the "
            f"whole OptState (or unpool_state it) instead")


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_packed)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Pytree, *, keep_last: int = 3) -> str:
    """Atomically write checkpoint for ``step``. Returns the final path."""
    tree = _canonical(tree)
    _check_no_orphan_pooled(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        arrays, index = {}, []
        for i, (key, leaf) in enumerate(_leaf_paths(tree)):
            if leaf is None:
                index.append({"key": key, "none": True})
                continue
            name = f"a{i}"
            entry = {"key": key, "name": name}
            if _is_packed(leaf):
                entry["packed"] = {"bits": leaf.bits, "n_codes": leaf.n_codes}
                leaf = leaf.packed
            arrays[name] = np.asarray(jax.device_get(leaf))
            entry.update(dtype=str(arrays[name].dtype),
                         shape=list(arrays[name].shape))
            index.append(entry)
        np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "index": index}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep_last)
    return final


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_"):
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template: Pytree,
            shardings: Optional[Pytree] = None) -> Pytree:
    """Load ``step`` into the structure of ``template`` (values ignored; may
    be ShapeDtypeStructs from jax.eval_shape).  ``shardings``: optional
    tree of jax.sharding.Sharding matching ``template`` for elastic
    placement; ``None`` entries (at any leaf) mean default placement, and a
    shardings tree whose structure does not match the template is an
    error."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    by_key, meta_by_key = {}, {}
    for ent in manifest["index"]:
        by_key[ent["key"]] = None if ent.get("none") else data[ent["name"]]
        meta_by_key[ent["key"]] = ent

    # Checkpoints are stored in the per-leaf canonical layout; load into
    # the per-leaf view of the template, then repool to its real layout.
    pl_template = _canonical(template)
    _check_no_orphan_pooled(pl_template)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        pl_template, is_leaf=_is_packed)
    leaves = []
    for p, tmpl in flat:
        key = jax.tree_util.keystr(p)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = by_key[key]
        if arr is None:
            leaves.append(None)
            continue
        packed_tmpl = tmpl if _is_packed(tmpl) else None
        saved = meta_by_key[key].get("packed")
        if packed_tmpl is not None:
            # Packedness must agree in both directions: packed bytes and
            # plain codes can share a shape without sharing a meaning.
            if saved is None:
                raise ValueError(
                    f"{key}: template expects {packed_tmpl.bits}-bit packed "
                    f"codes; checkpoint stores a plain array")
            if (saved["bits"] != packed_tmpl.bits or
                    saved["n_codes"] != packed_tmpl.n_codes):
                raise ValueError(
                    f"{key}: checkpoint packs {saved['bits']}-bit x "
                    f"{saved['n_codes']} codes; template expects "
                    f"{packed_tmpl.bits}-bit x {packed_tmpl.n_codes}")
            tmpl = packed_tmpl.packed
        elif saved is not None:
            raise ValueError(
                f"{key}: checkpoint stores packed {saved['bits']}-bit codes; "
                f"template expects a plain array")
        want = tuple(tmpl.shape) if hasattr(tmpl, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {want}")
        if packed_tmpl is not None:
            arr = PackedCodes(arr, packed_tmpl.bits, packed_tmpl.n_codes)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    tree = _repool(tree, template)
    if shardings is None:
        return jax.device_put(tree)

    # Flatten the shardings with the *output* treedef (is_leaf aware and
    # None-preserving): tree_leaves(shardings) would silently drop None
    # entries and mis-zip everything after the first one.
    out_flat, out_treedef = jax.tree_util.tree_flatten(tree,
                                                       is_leaf=_is_packed)
    try:
        shard_flat = out_treedef.flatten_up_to(shardings)
    except ValueError as e:
        raise ValueError(
            f"shardings tree structure does not match the restore "
            f"template: {e}") from e
    placed = [jax.device_put(x) if shd is None else jax.device_put(x, shd)
              for x, shd in zip(out_flat, shard_flat)]
    return jax.tree_util.tree_unflatten(out_treedef, placed)
