"""Fault-tolerant checkpointing.

Format: one directory per step, containing ``leaves.npz`` (every pytree leaf
keyed by its tree path) + ``manifest.json`` (step, leaf index, dtypes).
Writes are atomic (tmp dir + rename), ``keep_last`` old steps are pruned,
and ``latest_step`` scans the directory so restart-after-crash needs no
bookkeeping.

Because leaves are stored as *full logical arrays* keyed by path (not by
device shard), restore is **elastic**: the same checkpoint can be loaded
onto any mesh shape / sharding — restore takes a template pytree (built with
``jax.eval_shape``) and optional per-leaf shardings and device_puts
accordingly.  8-bit optimizer states are stored as their uint8 codes +
f32 absmax, so checkpoints are ~4x smaller than fp32-state checkpoints —
the paper's memory saving carried through to the storage/restore path.

Auxiliary optimizer state rides along unchanged: the percentile-clipping
gnorm history (``OptState.gnorm_vec``) is an ordinary f32 leaf, so a
restored run resumes with the exact clipping statistics it left with
(tests/test_checkpoint.py round-trips it).  ``None`` leaves (e.g. the
history when clipping is off) are recorded in the manifest and restored
as ``None``.

Bit-packed sub-byte states (``PackedCodes``, DESIGN.md §9) are stored as
their packed uint8 words with a ``"packed": {"bits", "n_codes"}`` manifest
annotation; restore validates the annotation against the template's static
format (a 4-bit checkpoint cannot silently load as 5-bit — same byte
count, different codes) and re-wraps the array.  Because packing is a
per-block layout detail and the full logical array is stored, packed
leaves stay elastic: the same checkpoint restores onto any mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.core.lowbit import PackedCodes

Pytree = Any


def _is_packed(x) -> bool:
    return isinstance(x, PackedCodes)


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_packed)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Pytree, *, keep_last: int = 3) -> str:
    """Atomically write checkpoint for ``step``. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        arrays, index = {}, []
        for i, (key, leaf) in enumerate(_leaf_paths(tree)):
            if leaf is None:
                index.append({"key": key, "none": True})
                continue
            name = f"a{i}"
            entry = {"key": key, "name": name}
            if _is_packed(leaf):
                entry["packed"] = {"bits": leaf.bits, "n_codes": leaf.n_codes}
                leaf = leaf.packed
            arrays[name] = np.asarray(jax.device_get(leaf))
            entry.update(dtype=str(arrays[name].dtype),
                         shape=list(arrays[name].shape))
            index.append(entry)
        np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "index": index}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep_last)
    return final


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_"):
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template: Pytree,
            shardings: Optional[Pytree] = None) -> Pytree:
    """Load ``step`` into the structure of ``template`` (values ignored; may
    be ShapeDtypeStructs from jax.eval_shape).  ``shardings``: optional
    matching tree of jax.sharding.Sharding for elastic placement."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    by_key, meta_by_key = {}, {}
    for ent in manifest["index"]:
        by_key[ent["key"]] = None if ent.get("none") else data[ent["name"]]
        meta_by_key[ent["key"]] = ent

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=_is_packed)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (p, tmpl), shd in zip(flat, shard_flat):
        key = jax.tree_util.keystr(p)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = by_key[key]
        if arr is None:
            leaves.append(None)
            continue
        packed_tmpl = tmpl if _is_packed(tmpl) else None
        saved = meta_by_key[key].get("packed")
        if packed_tmpl is not None:
            # Packedness must agree in both directions: packed bytes and
            # plain codes can share a shape without sharing a meaning.
            if saved is None:
                raise ValueError(
                    f"{key}: template expects {packed_tmpl.bits}-bit packed "
                    f"codes; checkpoint stores a plain array")
            if (saved["bits"] != packed_tmpl.bits or
                    saved["n_codes"] != packed_tmpl.n_codes):
                raise ValueError(
                    f"{key}: checkpoint packs {saved['bits']}-bit x "
                    f"{saved['n_codes']} codes; template expects "
                    f"{packed_tmpl.bits}-bit x {packed_tmpl.n_codes}")
            tmpl = packed_tmpl.packed
        elif saved is not None:
            raise ValueError(
                f"{key}: checkpoint stores packed {saved['bits']}-bit codes; "
                f"template expects a plain array")
        want = tuple(tmpl.shape) if hasattr(tmpl, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {want}")
        arr = jax.device_put(arr, shd) if shd is not None else jax.device_put(arr)
        if packed_tmpl is not None:
            arr = PackedCodes(arr, packed_tmpl.bits, packed_tmpl.n_codes)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
