"""Training step construction: loss, grad accumulation, clipping, optimizer.

``make_train_step`` builds the jit-able function the launcher lowers for the
multi-pod dry-run; ``train_loop`` is the host loop used by the examples and
the end-to-end driver (checkpointing, preemption, straggler logging live in
repro/launch/train.py).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.analysis import contracts as _contracts
from repro.models import model as M
from repro.telemetry import tracing as _tracing

Pytree = Any


class TrainState(NamedTuple):
    """params are NOT stored: they are a cast view of the optimizer's
    (sharded, flat-block) master copies, re-materialized inside each step —
    ZeRO-3 style, no persistent model-shape duplicate.

    ``opt_state`` also carries the optimizer's auxiliary state: the
    percentile-clipping gnorm history (``OptState.gnorm_vec``) rides here
    and therefore checkpoints/restores with everything else, as do the
    pooled-dispatch arenas (``OptState.arena`` / ``pool32``, DESIGN.md
    §10 — checkpointed per-leaf, so pooled and per-leaf runs share
    checkpoints); stochastic-rounding seeds are derived from
    ``opt_state.step`` inside the optimizer, so a restore replays
    identical rounding — no RNG state to persist."""
    opt_state: Any            # optimizer-owned (master, 8-bit stats, gnorms)
    step: jax.Array           # int32


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    grad_clip: float = 1.0
    microbatches: int = 1
    label_smoothing: float = 0.0
    moe_aux_coef: float = 0.01
    moe_z_coef: float = 1e-3
    lr_schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  smoothing: float = 0.0) -> jax.Array:
    """Mean token NLL in f32. logits (B, S, V), labels (B, S).

    The gold logit is extracted with a vocab-local masked reduction (not
    take_along_axis) so the loss works on *vocab-sharded* logits without an
    all-gather — with V=100k+ and f32 logits that gather is a 100GB+
    catastrophe the roofline caught (EXPERIMENTS.md §Perf)."""
    from repro.models.constrain import constrain
    logits = constrain(logits.astype(jnp.float32), "dp", None, "tp")
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    if smoothing > 0.0:
        mean_lp = jnp.mean(logits - logz[..., None], axis=-1)
        nll = (1 - smoothing) * nll - smoothing * mean_lp
    return jnp.mean(nll)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Pytree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda x: x * scale, tree), norm


def make_loss_fn(cfg, hyper: TrainHyper):
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        embeds = batch.get("embeds")
        logits, mx = M.forward(cfg, params, inputs, embeds=embeds)
        if embeds is not None:
            logits = logits[:, -labels.shape[1]:]   # loss on token positions
        loss = cross_entropy(logits, labels, hyper.label_smoothing)
        total = loss
        if "moe_aux_loss" in mx:
            total = total + hyper.moe_aux_coef * mx["moe_aux_loss"] \
                          + hyper.moe_z_coef * mx["moe_z_loss"]
        mx = dict(mx)
        mx["ce_loss"] = loss
        return total, mx
    return loss_fn


def make_train_step(cfg, optimizer, hyper: TrainHyper = TrainHyper(),
                    param_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    grad accumulation: batch is split into ``hyper.microbatches`` equal
    slices along the batch dim and grads averaged with a scan (bounds
    activation + MoE dispatch memory — the per-(arch,shape) knob of §Perf).

    ``param_shardings``: optional pytree of NamedSharding constraining the
    params view reconstructed from the flat-block master — without it XLA
    propagates the block-domain sharding through the reshape and lands on
    the scan (layers) dim, triggering involuntary full rematerialization.
    """
    loss_fn = make_loss_fn(cfg, hyper)
    param_dtype = jnp.dtype(cfg.param_dtype)
    # ZeRO-2 (DESIGN.md §13): accumulate straight into the optimizer's
    # owned-span GradBuffer instead of a replicated param-shaped pytree.
    shard_grads = bool(
        getattr(getattr(optimizer, "cfg", None), "shard_grads_active", False)
        and hasattr(optimizer, "init_grad_buffer"))
    # Deferred all-gather (§13d): apply() skips the model-shape params
    # reconstruction when it supports the kwarg — train_step discards that
    # output anyway; params re-materialize at their first use, the
    # params_view call at the top of the NEXT step.
    defer_kw = {}
    if "materialize_params" in inspect.signature(
            optimizer.apply).parameters:
        defer_kw["materialize_params"] = False
    # Numerics sentinel (DESIGN.md §16): static — when on, apply() returns
    # (params, state, health) and the step surfaces the HealthFlags counts
    # as sent_* metrics; when off the step lowers byte-identically to a
    # sentinel-free build (the train_step.sentinel_invariant contract).
    sentinel_on = bool(getattr(getattr(optimizer, "cfg", None),
                               "sentinel", False))

    def compute_grads(params, batch):
        if hyper.microbatches <= 1:
            (loss, mx), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, mx, grads

        n = hyper.microbatches

        def micro(carry, mb):
            acc, loss_acc = carry
            (loss, mx), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree_util.tree_map(jnp.add, acc, g)
            return (acc, loss_acc + loss), mx

        def split(x):
            b = x.shape[0]
            return x.reshape(n, b // n, *x.shape[1:])

        mbs = jax.tree_util.tree_map(split, batch)
        # The accumulator MUST carry the param sharding: an unconstrained
        # zeros tree lets SPMD replicate it, turning every microbatch's
        # gradient into a full (unsharded) all-reduce — measured as ~90x
        # param-bytes of all-reduce on kimi train_4k (§Perf A3).
        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if param_shardings is not None:
            zero = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, zero, param_shardings)
        (gsum, loss_sum), mxs = jax.lax.scan(micro, (zero, 0.0), mbs)
        grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
        mx = {k: jnp.mean(v) for k, v in mxs.items()}
        return loss_sum / n, mx, grads

    def compute_grad_buffer(params, batch, opt_state):
        """ZeRO-2 accumulation (DESIGN.md §13): each microbatch's grads
        flatten into the owned-span GradBuffer bucket-by-bucket as they
        are produced — the replicated grad pytree never outlives one
        microbatch, and each bucket's reduce-scatter overlaps the next
        microbatch's backward.  Addition commutes with the (exact)
        flatten, so the accumulated values are bit-identical to the
        param-shaped accumulator above."""
        buf0 = optimizer.init_grad_buffer(opt_state)
        if hyper.microbatches <= 1:
            (loss, mx), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, mx, optimizer.accumulate_grads(buf0, grads)

        n = hyper.microbatches

        def micro(carry, mb):
            buf, loss_acc = carry
            (loss, mx), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                      mb)
            return (optimizer.accumulate_grads(buf, g), loss_acc + loss), mx

        def split(x):
            b = x.shape[0]
            return x.reshape(n, b // n, *x.shape[1:])

        mbs = jax.tree_util.tree_map(split, batch)
        (buf, loss_sum), mxs = jax.lax.scan(micro, (buf0, 0.0), mbs)
        buf = jax.tree_util.tree_map(lambda g: g / n, buf)
        mx = {k: jnp.mean(v) for k, v in mxs.items()}
        return loss_sum / n, mx, buf

    def train_step(state: TrainState, batch):
        params = optimizer.params_view(state.opt_state, param_dtype)
        if param_shardings is not None:
            params = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, params, param_shardings)
        with _tracing.annotate("forward_backward"):
            if shard_grads:
                loss, mx, grads = compute_grad_buffer(params, batch,
                                                      state.opt_state)
                # same clip formula as clip_by_global_norm, with the norm
                # taken from the buffer (bit-identical per-leaf reductions)
                gnorm = optimizer.grad_buffer_norm(grads)
                scale = jnp.minimum(1.0, hyper.grad_clip /
                                    jnp.maximum(gnorm, 1e-12))
                grads = jax.tree_util.tree_map(lambda x: x * scale, grads)
            else:
                loss, mx, grads = compute_grads(params, batch)
                grads, gnorm = clip_by_global_norm(grads, hyper.grad_clip)
        lr = hyper.lr_schedule(state.step) if hyper.lr_schedule else None
        from repro.kernels import ops as kops
        dispatch0 = kops.fused_update_count()
        with _tracing.annotate("optimizer_update"):
            out = optimizer.apply(grads, state.opt_state, lr=lr,
                                  param_dtype=param_dtype, **defer_kw)
        health = None
        if sentinel_on:
            _, new_opt, health = out
        else:
            _, new_opt = out
        metrics = {"loss": loss, "grad_norm": gnorm, **mx}
        if health is not None:
            from repro.kernels import fused_update as kfu
            for i, nm in enumerate(kfu.HEALTH_SLOTS):
                metrics[f"sent_{nm}"] = health[i]
        # Counted at trace time => a constant under jit: how many fused
        # optimizer dispatches the compiled step bakes in.  1 per state-
        # format arena with the pooled dispatch (DESIGN.md §10), O(#leaves)
        # per-leaf, 0 for 32-bit engines.
        metrics["opt_fused_dispatches"] = jnp.float32(
            kops.fused_update_count() - dispatch0)
        if hasattr(optimizer, "state_bytes"):
            # Static-shape accounting (constant under jit): the *measured*
            # optimizer-statistics bytes per parameter, so k-bit memory
            # savings are observable in the metrics stream, not inferred
            # from the config (DESIGN.md §9).
            sb = optimizer.state_bytes(state.opt_state)
            if sb.get("n_params"):
                metrics["state_bytes_per_param"] = jnp.float32(
                    sb["state_bytes"] / sb["n_params"])
            if "owned_state_bytes" in sb:
                # Partitioned (ZeRO-1) dispatch (DESIGN.md §12): the
                # largest owner's block span and its share of the
                # statistics — what one device actually holds/updates.
                metrics["opt_owned_blocks"] = jnp.float32(
                    sb["owned_blocks"])
                metrics["opt_owned_state_bytes_per_param"] = jnp.float32(
                    sb["owned_state_bytes"] / sb["n_params"])
        if shard_grads and hasattr(optimizer, "grad_buffer_bytes"):
            # ZeRO-2 accounting (DESIGN.md §13): what one device holds of
            # the accumulated grads vs the replicated pytree it replaces.
            gbb = optimizer.grad_buffer_bytes(state.opt_state)
            metrics["peak_grad_bytes"] = jnp.float32(
                gbb["sharded_grad_bytes"])
            metrics["replicated_grad_bytes"] = jnp.float32(
                gbb["replicated_grad_bytes"])
        if getattr(optimizer, "cfg", None) is not None and \
                getattr(optimizer.cfg, "percentile_clipping", 100) < 100:
            # Same subgraph apply() evaluates internally -> CSE'd by XLA;
            # surfaces how hard percentile clipping bit this step.
            scale, _ = optimizer.percentile_clip(grads, state.opt_state)
            metrics["pclip_scale"] = scale
        return TrainState(opt_state=new_opt, step=state.step + 1), metrics

    return train_step


def jit_train_step(cfg, optimizer, hyper: TrainHyper = TrainHyper(),
                   param_shardings=None, *, donate: bool = True,
                   **jit_kwargs):
    """``jax.jit(make_train_step(...))`` with the TrainState donated
    (DESIGN.md §13c): the optimizer state's codes/absmax/masters alias
    their output buffers in place instead of round-tripping HBM twice.
    Callers must rebind ``state`` each step (every in-repo loop does);
    pass ``donate=False`` to keep the old state alive (A/B comparisons).
    """
    step = make_train_step(cfg, optimizer, hyper, param_shardings)
    donate_argnums = (0,) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums, **jit_kwargs)


def donation_aliases(lowered) -> int:
    """Number of donated-input/output buffer aliasings a ``.lower()``-ed
    step actually established (the ``tf.aliasing_output`` markers in the
    StableHLO) — the donation-aliasing audit hook (DESIGN.md §13c), now
    delegating to the contract checker (``repro.analysis.contracts``)."""
    return _contracts.donation_aliases(lowered.as_text())


# ------------------------------------------------- compile contracts (§15)
# Registered here, next to the step construction they protect; evaluated
# over the config matrix by `python -m repro.analysis` (analysis/runner.py).

def _telemetry_invariant(pair, cell):
    """telemetry_every is host-schedule only (§14): every knob value must
    lower the step to byte-identical StableHLO, with no tel.* scope names
    leaking into the default trace."""
    ok, detail = _contracts.lowering_invariant(
        {k: low.text for k, low in pair.items()})
    if ok and any("tel." in low.text for low in pair.values()):
        return False, "tel.* scope names leaked into the default lowering"
    return ok, detail


_contracts.register(
    "train_step.donates", "step",
    lambda low, cell: _contracts.check_donates(low.text, min_aliases=1),
    doc="donated TrainState marks >=1 in-place alias/donor (§13c)")
_contracts.register(
    "train_step.no_f64", "step",
    lambda low, cell: _contracts.check_no_dtype(low.text, "f64"),
    doc="no f64 anywhere in the jitted step (§6 master-dtype policy)")
_contracts.register(
    "train_step.collective_order", "step",
    lambda low, cell: (_contracts.check_collective_order(
        low.text,
        "{devices=",                # grads pinned into the owned-span layout
        "@SPMDFullToShardShape",    # reduce-scatter boundary: span entry
        "@SPMDShardToFullShape")    # all-gather boundary: span exit
        if getattr(cell, "shard_grads", False) else None),
    doc="ZeRO-2 step shape (§13): grad scatter pin -> span-local fused "
        "update (shard_map body) -> span exit; the implicit collectives "
        "ride these SPMD boundaries, so their order IS the "
        "reduce_scatter -> fused_update -> all_gather order")
_contracts.register(
    "train_step.telemetry_invariant", "pair:telemetry", _telemetry_invariant,
    doc="telemetry_every 0 vs N lower byte-identically (§14, ex-PR-7 test)")
_contracts.register(
    "train_step.overlap_donation_invariant", "pair:overlap",
    lambda pair, cell: _contracts.lowering_invariant(
        {k: low.text for k, low in pair.items()}, compare_aliases_only=True),
    doc="overlap_buckets 1 vs K restructures dispatch but must never cost "
        "a donated in-place arena (§13c)")


def _sentinel_invariant(pair, cell):
    """Sentinel zero-overhead contract (§16): the off default and an
    explicit sentinel=False must lower to byte-identical StableHLO (the
    feature costs nothing when off), and turning it on may only add the
    health outputs — the donated in-place arena aliasing set is
    unchanged."""
    off = {k: low.text for k, low in pair.items() if k != "on"}
    ok, detail = _contracts.lowering_invariant(off)
    if not ok:
        return False, f"sentinel-off not byte-identical: {detail}"
    return _contracts.lowering_invariant(
        {k: low.text for k, low in pair.items()}, compare_aliases_only=True)


_contracts.register(
    "train_step.sentinel_invariant", "pair:sentinel", _sentinel_invariant,
    doc="sentinel off lowers byte-identically; on preserves the donation "
        "aliasing set (§16)")


def init_train_state(cfg, optimizer, key) -> tuple[TrainState, Pytree]:
    """-> (state, logical param specs)."""
    params, specs = M.init_model(cfg, key)
    opt_state = optimizer.init(params)
    return TrainState(opt_state=opt_state,
                      step=jnp.zeros((), jnp.int32)), specs


def warmup_cosine(lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, lr * cos)
    return sched
