"""Pooled single-dispatch optimizer step (DESIGN.md §10).

The contract under test: `cfg.pooled` changes the *dispatch* (one fused
launch per state-format arena instead of one per leaf) and nothing else —
codes, absmax, masters, params, stochastic rounding and LAMB/LARS
trust ratios are bit-identical to the per-leaf parity oracle, launches per
step collapse to <= 2 per state-format group, and checkpoints interchange
with per-leaf runs in both directions on a real mesh.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qmap
from repro.core.optim import (Pool32Leaf, PooledQuantLeaf, Quant8Leaf,
                              make_optimizer, unpool_state)
from repro.kernels import ops, ref
from repro.train import checkpoint as C


def _params(key=0):
    """Several quantized leaves + an override leaf + small pooled leaves."""
    k = jax.random.PRNGKey(key)
    ks = jax.random.split(k, 5)
    return {
        "dense": {"w": jax.random.normal(ks[0], (64, 128)),
                  "v": jax.random.normal(ks[1], (48, 64))},
        "out": jax.random.normal(ks[2], (96, 32)),
        "embed": {"w": jax.random.normal(ks[3], (128, 64))},   # override
        "bias": jnp.zeros((10,)),                              # pooled fp32
        "small": jax.random.normal(ks[4], (17,)) * 0.1,        # pooled fp32
    }


def _loss(p, target):
    return sum(jnp.sum((a - b) ** 2)
               for a, b in zip(jax.tree_util.tree_leaves(p),
                               jax.tree_util.tree_leaves(target)))


def _train(opt, params, steps=3):
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    grad = jax.jit(jax.grad(lambda p: _loss(p, target)))
    st = opt.init(params)
    p = params
    for _ in range(steps):
        p, st = opt.apply(grad(p), st)
    return p, st


def _assert_trees_equal(a, b, msg=""):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# ----------------------------------------------------- engine bit-exactness
@pytest.mark.parametrize("algo", ["adam", "adamw", "momentum", "lamb",
                                  "lars", "adagrad"])
def test_pooled_matches_per_leaf_bit_exact(algo):
    """Pooled apply == per-leaf apply, bitwise: codes, absmax, master,
    params — incl. stochastic rounding (per-block seed offsets) and
    LAMB/LARS per-tensor trust ratios (segment norm prologue)."""
    kw = dict(lr=1e-2, min_8bit_size=1024, stochastic_rounding=True)
    p_a, st_a = _train(make_optimizer(f"{algo}8", pooled=True, **kw),
                       _params())
    p_b, st_b = _train(make_optimizer(f"{algo}8", pooled=False, **kw),
                       _params())
    assert st_a.arena is not None and st_a.pool32 is not None
    _assert_trees_equal(p_a, p_b, f"{algo}: params")
    _assert_trees_equal(unpool_state(st_a).leaves, st_b.leaves,
                        f"{algo}: state")


def test_pooled_matches_per_leaf_packed_and_clipping():
    """Same contract with packed (4, 8) states and percentile clipping."""
    kw = dict(lr=1e-2, min_8bit_size=1024, state_bits=(4, 8),
              stochastic_rounding=True, percentile_clipping=50,
              pclip_history=3)
    p_a, st_a = _train(make_optimizer("adam8", pooled=True, **kw),
                       _params(), steps=5)
    p_b, st_b = _train(make_optimizer("adam8", pooled=False, **kw),
                       _params(), steps=5)
    _assert_trees_equal(p_a, p_b, "params")
    _assert_trees_equal(unpool_state(st_a).leaves, st_b.leaves, "state")
    _assert_trees_equal(st_a.gnorm_vec, st_b.gnorm_vec, "gnorm history")


def test_pooled_layout_and_views():
    opt = make_optimizer("adam8", lr=1e-2, min_8bit_size=1024)
    params = _params()
    st = opt.init(params)
    kinds = {type(l).__name__
             for l in jax.tree_util.tree_leaves(
                 st.leaves, is_leaf=lambda x: isinstance(
                     x, (Quant8Leaf, PooledQuantLeaf, Pool32Leaf)) or
                 hasattr(x, "master"))}
    assert "PooledQuantLeaf" in kinds and "Pool32Leaf" in kinds
    # arena covers exactly the quantized leaves, in offset order
    segs = st.arena.segments
    assert [s.offset for s in segs] == sorted(s.offset for s in segs)
    assert st.arena.codes_m.shape[0] == sum(s.n_blocks for s in segs)
    # params_view reproduces the inputs
    view = opt.params_view(st)
    for a, b in zip(jax.tree_util.tree_leaves(view),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    # memory accounting matches the per-leaf layout exactly
    b_pooled = opt.state_bytes(st)
    opt_pl = make_optimizer("adam8", lr=1e-2, min_8bit_size=1024,
                            pooled=False)
    assert b_pooled == opt_pl.state_bytes(opt_pl.init(params))


def test_tensorwise_ablation_falls_back_to_per_leaf():
    """Tensor-wise quantization needs a per-*tensor* absmax, which one
    arena cannot represent: pooling must deactivate, not mis-quantize."""
    opt = make_optimizer("adam8", lr=1e-2, min_8bit_size=1024,
                         blockwise_norm=False)   # pooled left at default
    assert not opt.cfg.pooling_active
    st = opt.init(_params())
    assert st.arena is None and st.pool32 is None
    assert isinstance(st.leaves["dense"]["w"], Quant8Leaf)


# ------------------------------------------------------- launches per step
def test_pooled_single_dispatch_launch_count():
    """Pooled apply issues ONE fused_update per state-format arena; the
    per-leaf oracle issues one per quantized leaf."""
    params = _params()
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    grad = jax.grad(lambda p: _loss(p, target))(params)

    def calls(pooled):
        opt = make_optimizer("adam8", lr=1e-2, min_8bit_size=1024,
                             pooled=pooled)
        st = opt.init(params)
        ops.reset_fused_update_count()
        jax.jit(lambda g, s: opt.apply(g, s)).lower(grad, st)  # trace only
        return ops.fused_update_count()

    n_quant = 3   # dense/w, dense/v, out
    assert calls(False) == n_quant
    assert calls(True) == 1


# ------------------------------------------------- kernel-level pooled call
def test_fused_update_segments_match_separate_calls_interpret():
    """ops.fused_update on a concatenated input with per-block seeds /
    offsets / segments == separate per-tensor calls, bitwise, through the
    Pallas (interpret) kernels — stochastic rounding + LAMB prologue."""
    qs = jnp.asarray(qmap.get_qmap("dynamic", True))
    qu = jnp.asarray(qmap.get_qmap("dynamic", False))
    hyper = dict(lr=1e-3, weight_decay=0.01, step=5.0, trust_coeff=1e-3)

    def inputs(nb, seed):
        k = jax.random.PRNGKey(seed)
        ks = jax.random.split(k, 4)
        p = jax.random.normal(ks[0], (nb, 256))
        g = jax.random.normal(ks[1], (nb, 256)) * 0.1
        cm, am = ref.quantize_ref(jax.random.normal(ks[2], (nb, 256)) * 0.01, qs)
        cr, ar = ref.quantize_ref(
            jnp.abs(jax.random.normal(ks[3], (nb, 256))) * 1e-4, qu)
        return p, g, cm, am, cr, ar

    a, b = inputs(5, 0), inputs(11, 1)
    seeds = (17, 99)
    sep = [ops.fused_update("lamb", *x, qs, qu, impl="interpret",
                            stochastic=True, seed=s, **hyper)
           for x, s in zip((a, b), seeds)]
    cat = [jnp.concatenate([x, y]) for x, y in zip(a, b)]
    pooled = ops.fused_update(
        "lamb", *cat, qs, qu, impl="interpret", stochastic=True,
        block_seeds=jnp.concatenate([jnp.full((5,), seeds[0], jnp.int32),
                                     jnp.full((11,), seeds[1], jnp.int32)]),
        block_offsets=jnp.concatenate([jnp.arange(5, dtype=jnp.int32),
                                       jnp.arange(11, dtype=jnp.int32)]),
        segments=((0, 5), (5, 11)), **hyper)
    for name, got in zip(pooled._fields, pooled):
        if got is None:   # optional fields (health counts, sentinel off)
            assert getattr(sep[0], name) is None, name
            assert getattr(sep[1], name) is None, name
            continue
        want = jnp.concatenate([getattr(sep[0], name), getattr(sep[1], name)])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=name)


# --------------------------------------------- checkpoint interchange (mesh)
from helpers import mesh_of as _mesh_of  # noqa: E402  (shared sub-meshes)


def _mesh2():
    return _mesh_of(2)


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("state_bits", [None, (4, 8)])
def test_checkpoint_interchange_per_leaf_to_pooled(tmp_path, state_bits,
                                                   n_dev):
    """Save per-leaf -> restore pooled on {1,2,4}-device meshes, bit-exact
    codes/absmax/master (incl. PackedCodes), and the resumed pooled run
    matches the uninterrupted per-leaf run bit-exactly.  The 'u' leaf has
    an odd element count, so block counts vary across leaves."""
    from repro.sharding import rules
    mesh = _mesh_of(n_dev)
    kw = dict(lr=1e-2, min_8bit_size=256, override_32bit=lambda p: False,
              shard_multiple=n_dev, stochastic_rounding=True)
    if state_bits:
        kw["state_bits"] = state_bits
    params = {"w": jnp.ones((64, 64)), "v": jnp.ones((48, 32)),
              "b": jnp.zeros((8,)), "u": jnp.ones((40, 70)) * 0.1}
    opt_pl = make_optimizer("adam8", pooled=False, **kw)
    opt_po = make_optimizer("adam8", pooled=True, **kw)
    _, st = _train_with(opt_pl, params, 3)
    d = str(tmp_path)
    C.save(d, 3, st)

    template = jax.eval_shape(lambda: opt_po.init(params))
    pshard = jax.tree_util.tree_map(
        lambda p: jax.sharding.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec()),
        params)
    shardings = rules.opt_state_shardings(template, pshard, mesh,
                                          rules.ShardingPolicy())
    st_po = C.restore(d, 3, template, shardings)
    # arena block dim is sharded over the mesh
    assert st_po.arena.codes_m is not None
    _assert_trees_equal(unpool_state(st_po).leaves, st.leaves,
                        "restored pooled != saved per-leaf")
    # resumed step parity: pooled resume == uninterrupted per-leaf
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    grad = jax.jit(jax.grad(lambda p: _loss(p, target)))
    g = grad(opt_pl.params_view(st))
    _, st_a = opt_pl.apply(g, st)
    _, st_b = opt_po.apply(g, st_po)
    _assert_trees_equal(st_a.leaves, unpool_state(st_b).leaves,
                        "resumed step diverged")


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("state_bits", [None, (4, 8)])
def test_checkpoint_interchange_pooled_to_per_leaf(tmp_path, state_bits,
                                                   n_dev):
    """Save pooled -> restore per-leaf on {1,2,4}-device meshes,
    bit-exact (incl. an odd-element leaf, so block counts are uneven)."""
    from repro.sharding import rules
    mesh = _mesh_of(n_dev)
    kw = dict(lr=1e-2, min_8bit_size=256, override_32bit=lambda p: False,
              shard_multiple=n_dev)
    if state_bits:
        kw["state_bits"] = state_bits
    params = {"w": jnp.ones((64, 64)), "v": jnp.ones((48, 32)),
              "b": jnp.zeros((8,)), "u": jnp.ones((40, 70)) * 0.1}
    opt_po = make_optimizer("adam8", pooled=True, **kw)
    opt_pl = make_optimizer("adam8", pooled=False, **kw)
    _, st = _train_with(opt_po, params, 3)
    d = str(tmp_path)
    C.save(d, 3, st)

    template = jax.eval_shape(lambda: opt_pl.init(params))
    pshard = jax.tree_util.tree_map(
        lambda p: jax.sharding.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec()),
        params)
    shardings = rules.opt_state_shardings(template, pshard, mesh,
                                          rules.ShardingPolicy())
    st_pl = C.restore(d, 3, template, shardings)
    _assert_trees_equal(st_pl.leaves, unpool_state(st).leaves,
                        "restored per-leaf != saved pooled")


def _train_with(opt, params, steps):
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    grad = jax.jit(jax.grad(lambda p: _loss(p, target)))
    st = opt.init(params)
    p = params
    for _ in range(steps):
        p, st = opt.apply(grad(p), st)
    return p, st


# ------------------------------------------- restore shardings regression
def test_restore_none_shardings_are_preserved(tmp_path):
    """Regression: tree_leaves(shardings) silently dropped None entries,
    mis-zipping every sharding after the first None.  None must mean
    'default placement' for exactly that leaf, with everything after it
    still landing on its requested device."""
    mesh = _mesh2()
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    tree = {"a": jnp.zeros((4, 2)), "b": jnp.ones((8, 2)),
            "c": jnp.full((6, 2), 2.0)}
    d = str(tmp_path)
    C.save(d, 1, tree)
    template = jax.eval_shape(lambda: tree)
    shardings = {"a": None, "b": sh, "c": sh}
    out = C.restore(d, 1, template, shardings)
    # before the fix, 'b' got None's slot dropped -> b took sh... and 'c'
    # ran off the end; now b and c are sharded over the mesh, a is not
    assert out["b"].sharding.is_equivalent_to(sh, 2)
    assert out["c"].sharding.is_equivalent_to(sh, 2)
    for k in "abc":
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))


def test_save_orphan_pooled_containers_rejected(tmp_path):
    """Saving pooled containers outside their OptState (e.g. just the
    leaves subtree) would silently drop every quantized statistic — the
    arena lives on a sibling field.  Must fail loudly."""
    opt = make_optimizer("adam8", lr=1e-2, min_8bit_size=256,
                         override_32bit=lambda p: False)
    st = opt.init({"w": jnp.ones((64, 64))})
    with pytest.raises(ValueError, match="OptState"):
        C.save(str(tmp_path), 1, st.leaves)
    # the whole state (or its per-leaf view) is fine
    C.save(str(tmp_path), 1, st)
    C.save(str(tmp_path), 2, unpool_state(st).leaves)


def test_restore_sharding_structure_mismatch_raises(tmp_path):
    tree = {"a": jnp.zeros((4,)), "b": jnp.ones((8,))}
    d = str(tmp_path)
    C.save(d, 1, tree)
    template = jax.eval_shape(lambda: tree)
    with pytest.raises(ValueError, match="shardings"):
        C.restore(d, 1, template, {"a": None})   # missing 'b'
