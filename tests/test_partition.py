"""Partitioned (ZeRO-1) pooled optimizer state — DESIGN.md §12.

The contract under test: ``OptimConfig.partition`` changes WHERE each
arena block is updated (each owner updates only its contiguous span; on a
matching mesh, via shard_map with one local fused launch per device) and
nothing else — codes, absmax, masters, stochastic rounding, LAMB/LARS
trust ratios and the percentile-clip history are bit-identical to the
``partition=False`` pooled oracle, on 1-, 2- and 4-device meshes and on
the mesh-free statically-unrolled path (any shard count, including spans
that are padding-only on uneven arenas).  Checkpoints stay per-leaf
canonical, so partitioned ↔ pooled ↔ per-leaf interchange is elastic in
all directions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optim import (Quant8Leaf, make_optimizer, make_partition,
                              repool_like, unpool_state)
from repro.kernels import ops
from repro.train import checkpoint as C

from helpers import assert_trees_equal, mesh_of


def _params(key=0):
    """Quantized leaves (one straddles span boundaries) + an override
    leaf + small pooled leaves."""
    k = jax.random.PRNGKey(key)
    ks = jax.random.split(k, 5)
    return {
        "dense": {"w": jax.random.normal(ks[0], (64, 128)),
                  "v": jax.random.normal(ks[1], (48, 64))},
        "out": jax.random.normal(ks[2], (96, 32)),
        "embed": {"w": jax.random.normal(ks[3], (128, 64))},   # override
        "bias": jnp.zeros((10,)),                              # pooled fp32
        "small": jax.random.normal(ks[4], (17,)) * 0.1,        # pooled fp32
    }


def _loss(p, target):
    return sum(jnp.sum((a - b) ** 2)
               for a, b in zip(jax.tree_util.tree_leaves(p),
                               jax.tree_util.tree_leaves(target)))


def _train(opt, params, steps=3):
    """Jitted apply steps (the train-step context the dispatch runs in)."""
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    grad = jax.jit(jax.grad(lambda p: _loss(p, target)))
    step = jax.jit(lambda g, s: opt.apply(g, s))
    st = opt.init(params)
    p = params
    for _ in range(steps):
        p, st = step(grad(p), st)
    return p, st


def _canon(p, st):
    return (p, unpool_state(st).leaves)


# --------------------------------------------------- unrolled bit-exactness
@pytest.mark.parametrize("algo", ["adam", "adamw", "momentum", "lamb",
                                  "lars", "adagrad"])
def test_partitioned_matches_pooled_bit_exact(algo):
    """Mesh-free span dispatch, 3 shards (uneven spans): bitwise equal to
    the pooled oracle incl. stochastic rounding and trust ratios."""
    kw = dict(lr=1e-2, min_8bit_size=1024, stochastic_rounding=True)
    p_a, st_a = _train(make_optimizer(f"{algo}8", partition=True,
                                      partition_shards=3, **kw), _params())
    p_b, st_b = _train(make_optimizer(f"{algo}8", partition=False, **kw),
                       _params())
    assert st_a.arena.partition is not None
    assert st_a.arena.partition.n_shards == 3
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b), algo)


# ------------------------------------------------------ mesh bit-exactness
@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("algo", ["adamw", "lamb"])
def test_partitioned_matches_pooled_on_mesh(algo, n_dev):
    """shard_map span dispatch on a real {1,2,4}-device mesh: one local
    fused update per device, bitwise equal to the oracle (lamb covers the
    globally-finalized trust-ratio path)."""
    mesh = mesh_of(n_dev)
    kw = dict(lr=1e-2, min_8bit_size=1024, stochastic_rounding=True)
    p_a, st_a = _train(make_optimizer(f"{algo}8", mesh=mesh, partition=True,
                                      **kw), _params())
    p_b, st_b = _train(make_optimizer(f"{algo}8", partition=False, **kw),
                       _params())
    assert st_a.arena.partition.n_shards == n_dev
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b),
                       f"{algo} mesh{n_dev}")


@pytest.mark.parametrize("n_dev", [2, 4])
def test_partitioned_packed_clipping_on_mesh(n_dev):
    """Packed (4, 8) states + percentile clipping on the mesh path: codes,
    absmax, masters AND the clip history stay bit-identical."""
    mesh = mesh_of(n_dev)
    kw = dict(lr=1e-2, min_8bit_size=1024, state_bits=(4, 8),
              stochastic_rounding=True, percentile_clipping=50,
              pclip_history=3)
    p_a, st_a = _train(make_optimizer("adam8", mesh=mesh, **kw),
                       _params(), steps=5)
    p_b, st_b = _train(make_optimizer("adam8", partition=False, **kw),
                       _params(), steps=5)
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b), "state")
    assert_trees_equal(st_a.gnorm_vec, st_b.gnorm_vec, "gnorm history")


@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_muon_partitioned_routing_on_mesh(n_dev):
    """Muon matrix leaves route whole-leaf to their owner device (cond +
    broadcast — exact: uint8 codes and f32 state round-trip through the
    psum); the element-wise fallback arena partitions like every other
    algorithm.  Bitwise equal to the unpartitioned oracle."""
    mesh = mesh_of(n_dev)
    kw = dict(lr=1e-2, min_8bit_size=256, override_32bit=lambda p: False,
              stochastic_rounding=True)
    p_a, st_a = _train(make_optimizer("muon8", mesh=mesh, partition=True,
                                      **kw), _params())
    p_b, st_b = _train(make_optimizer("muon8", partition=False, **kw),
                       _params())
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b),
                       f"muon mesh{n_dev}")


def test_partition_multi_pod_axes():
    """partition_axis="pod,data": the shard_map path activates when the
    PRODUCT of the partition axes matches the shard count — multi-pod
    meshes get the one-local-launch path, not the unrolled fallback —
    and muon owner routing uses the combined (major-to-minor) index."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    kw = dict(lr=1e-2, min_8bit_size=1024, stochastic_rounding=True)
    opt = make_optimizer("adamw8", mesh=mesh, partition_axis="pod,data",
                         **kw)
    assert opt.cfg.partition_shards == 4
    assert opt._partition_mesh(4) is mesh      # shard_map path active
    p_a, st_a = _train(opt, _params())
    p_b, st_b = _train(make_optimizer("adamw8", partition=False, **kw),
                       _params())
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b), "pod,data")
    ops.reset_fused_update_count()
    grads = jax.tree_util.tree_map(lambda p: p * 0.01, _params())
    jax.jit(lambda g, s: opt.apply(g, s)).lower(grads, opt.init(_params()))
    assert ops.fused_update_count() == 1       # ONE local fused launch
    # muon: combined-index owner routing over both axes
    kw_m = dict(lr=1e-2, min_8bit_size=256, override_32bit=lambda p: False,
                stochastic_rounding=True)
    p_m, st_m = _train(make_optimizer("muon8", mesh=mesh,
                                      partition_axis="pod,data", **kw_m),
                       _params(), steps=2)
    p_o, st_o = _train(make_optimizer("muon8", partition=False, **kw_m),
                       _params(), steps=2)
    assert_trees_equal(_canon(p_m, st_m), _canon(p_o, st_o), "muon pod,data")


def test_muon_matrix_owner_assignment():
    """k-th matrix leaf (flatten order) -> owner k % D, recorded with its
    path in the partition metadata."""
    opt = make_optimizer("muon8", lr=1e-2, min_8bit_size=1,
                         override_32bit=lambda p: False,
                         partition=True, partition_shards=2)
    st = opt.init(_params())
    part = (st.arena or st.pool32).partition
    owners = dict(part.matrix_owners)
    # flatten order: bias, dense/v, dense/w, embed/w, out, small — matrix
    # (2-D quantized) leaves among them round-robin over 2 owners
    matrix_paths = [p for p, _ in part.matrix_owners]
    assert [owners[p] for p in matrix_paths] == \
        [k % 2 for k in range(len(matrix_paths))]
    assert len(matrix_paths) >= 3


# ------------------------------------------------- partition metadata/spans
def test_partition_spans_cover_and_align():
    """Spans tile [0, total) contiguously on the grid; uneven totals leave
    trailing spans short or empty (padding-only owners)."""
    part = make_partition(10, 4, grid=4)
    assert part.spans == ((0, 4), (4, 4), (8, 2), (12, 0))
    assert part.padded_total == 16 and part.max_owned == 4
    assert sum(n for _, n in part.spans) == part.total == 10
    part = make_partition(8, 2, grid=1)
    assert part.spans == ((0, 4), (4, 4))
    part = make_partition(3, 4, grid=1)
    assert part.spans == ((0, 1), (1, 1), (2, 1), (3, 0))
    for row, want in ((0, 0), (1, 1), (2, 2)):
        assert part.owner_of(row) == want


def test_uneven_arena_padded_spans_bit_exact():
    """An arena whose block count does not divide the shard count: the
    trailing owner holds a short (padded) span, on the mesh and unrolled
    paths alike."""
    params = {"a": jax.random.normal(jax.random.PRNGKey(0), (80, 64)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (40, 70))}
    kw = dict(lr=1e-2, min_8bit_size=256, override_32bit=lambda p: False,
              stochastic_rounding=True)
    p_o, st_o = _train(make_optimizer("adam8", partition=False, **kw),
                       params)
    p_u, st_u = _train(make_optimizer("adam8", partition=True,
                                      partition_shards=4, **kw), params)
    part = st_u.arena.partition
    assert part.total % part.n_shards != 0      # genuinely uneven
    assert any(n < part.span_pad for _, n in part.spans)
    assert_trees_equal(_canon(p_u, st_u), _canon(p_o, st_o), "unrolled")
    mesh = mesh_of(4)
    p_m, st_m = _train(make_optimizer("adam8", mesh=mesh, partition=True,
                                      **kw), params)
    assert_trees_equal(_canon(p_m, st_m), _canon(p_o, st_o), "mesh")


# ------------------------------------------- launches + owned-bytes metrics
def test_partition_launches_and_owned_bytes():
    """Mesh path: ONE local fused launch per device (trace-time count 1);
    unrolled: one per owned span.  4-way owned statistics <= 0.3x the
    replicated statistics (the acceptance gate)."""
    key = jax.random.PRNGKey(0)
    params = {f"l{i:02d}": jax.random.normal(jax.random.fold_in(key, i),
                                             (8 + (i % 5) * 8, 256))
              for i in range(24)}
    grads = jax.tree_util.tree_map(lambda p: p * 0.01, params)
    kw = dict(lr=1e-3, min_8bit_size=256, override_32bit=lambda p: False)

    def launches(opt):
        st = opt.init(params)
        ops.reset_fused_update_count()
        jax.jit(lambda g, s: opt.apply(g, s)).lower(grads, st)
        return ops.fused_update_count(), opt.state_bytes(st)

    mesh = mesh_of(4)
    n_mesh, sb = launches(make_optimizer("adam8", mesh=mesh, partition=True,
                                         **kw))
    assert n_mesh == 1                       # one LOCAL fused launch
    assert sb["partition_shards"] == 4
    assert sb["owned_state_bytes"] <= 0.3 * sb["state_bytes"]
    n_unrolled, sb_u = launches(make_optimizer(
        "adam8", partition=True, partition_shards=4, **kw))
    assert n_unrolled == 4                   # one per owned span
    assert sb_u["owned_state_bytes"] == sb["owned_state_bytes"]
    n_off, sb_off = launches(make_optimizer("adam8", partition=False, **kw))
    assert n_off == 1 and "owned_state_bytes" not in sb_off


# ----------------------------------------------------- hypothesis property
@pytest.mark.parametrize("shapes,bits,shards", [
    ((( 40, 64), (13, 17), (256,)), None, 2),
    (((96, 32), (7, 300), (64, 64), (2048,)), (4, 8), 3),
    (((130, 70),), (4, 8), 4),
])
def test_partition_stitch_property_cases(shapes, bits, shards):
    _stitch_property(shapes, bits, shards)


def _stitch_property(shapes, bits, shards):
    """build arena -> partition -> local updates stitched back == the
    unpartitioned pooled update, bitwise; and unpool(repool_like(...)) is
    an identity through a partitioned arena."""
    key = jax.random.PRNGKey(hash((tuple(shapes), shards)) % (2 ** 31))
    params = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), s)
              for i, s in enumerate(shapes)}
    kw = dict(lr=1e-2, min_8bit_size=64, override_32bit=lambda p: False,
              stochastic_rounding=True)
    if bits:
        kw["state_bits"] = bits
    opt_p = make_optimizer("adam8", partition=True, partition_shards=shards,
                           **kw)
    opt_o = make_optimizer("adam8", partition=False, **kw)
    p_a, st_a = _train(opt_p, params, steps=2)
    p_b, st_b = _train(opt_o, params, steps=2)
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b), "stitch")
    # round trip through the per-leaf canonical form preserves both the
    # arrays and the partition metadata
    back = repool_like(unpool_state(st_a), st_a)
    assert_trees_equal(back, st_a, "repool identity")
    assert back.arena is None or \
        back.arena.partition == st_a.arena.partition


def test_partition_stitch_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    dims = st.integers(min_value=3, max_value=160)
    shape = st.one_of(st.tuples(dims, dims), st.tuples(
        st.integers(min_value=64, max_value=4096)))

    @settings(max_examples=8, deadline=None)
    @given(shapes=st.lists(shape, min_size=1, max_size=3),
           bits=st.sampled_from([None, (4, 8), (5, 8)]),
           shards=st.integers(min_value=1, max_value=4))
    def prop(shapes, bits, shards):
        _stitch_property(tuple(tuple(s) for s in shapes), bits, shards)

    prop()


# ------------------------------------------- elastic interchange (ckpt/mesh)
@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("state_bits", [None, (4, 8)])
def test_checkpoint_interchange_partitioned_pooled_per_leaf(tmp_path, n_dev,
                                                            state_bits):
    """Save partitioned -> restore pooled AND per-leaf; save per-leaf ->
    restore partitioned; all bit-exact on {1,2,4}-device meshes with an
    uneven arena, and the resumed partitioned step matches the
    uninterrupted pooled run."""
    from repro.sharding import rules
    mesh = mesh_of(n_dev)
    # shard_multiple=n_dev keeps the stored block dim divisible by the
    # mesh (flat_block_spec); partition_shards=3 keeps the OWNED spans
    # uneven regardless, so padded spans are exercised on every mesh.
    kw = dict(lr=1e-2, min_8bit_size=256, override_32bit=lambda p: False,
              shard_multiple=n_dev, stochastic_rounding=True)
    if state_bits:
        kw["state_bits"] = state_bits
    params = {"w": jnp.ones((80, 64)), "v": jnp.ones((40, 32)),
              "b": jnp.zeros((8,))}
    opt_part = make_optimizer("adam8", partition=True, partition_shards=3,
                              **kw)
    opt_pool = make_optimizer("adam8", partition=False, **kw)
    opt_pl = make_optimizer("adam8", pooled=False, **kw)
    _, st = _train(opt_part, params, 3)
    d = str(tmp_path)
    C.save(d, 3, st)

    pshard = jax.tree_util.tree_map(
        lambda p: jax.sharding.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec()),
        params)

    def restore_into(opt):
        template = jax.eval_shape(lambda: opt.init(params))
        shardings = rules.opt_state_shardings(template, pshard, mesh,
                                              rules.ShardingPolicy())
        return C.restore(d, 3, template, shardings)

    st_pool = restore_into(opt_pool)
    st_pl = restore_into(opt_pl)
    assert_trees_equal(unpool_state(st_pool).leaves,
                       unpool_state(st).leaves, "partitioned -> pooled")
    assert_trees_equal(st_pl.leaves, unpool_state(st).leaves,
                       "partitioned -> per-leaf")

    # per-leaf save -> partitioned restore, then a resumed step matches
    # the uninterrupted pooled continuation
    C.save(d, 4, st_pl)
    st_part = restore_into(opt_part)
    assert st_part.arena.partition is not None
    assert_trees_equal(unpool_state(st_part).leaves,
                       unpool_state(st).leaves, "per-leaf -> partitioned")
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    g = jax.jit(jax.grad(lambda p: _loss(p, target)))(
        opt_pool.params_view(st_pool))
    _, st_a = jax.jit(lambda g, s: opt_part.apply(g, s))(g, st_part)
    _, st_b = jax.jit(lambda g, s: opt_pool.apply(g, s))(g, st_pool)
    assert_trees_equal(unpool_state(st_a).leaves, unpool_state(st_b).leaves,
                       "resumed partitioned step diverged")


@pytest.mark.parametrize("n_dev", [2, 4])
def test_bucketed_packed_overlap_on_mesh(n_dev):
    """DESIGN.md §13: bucketed dispatch (overlap_buckets=3) composed with
    packed (4, 8) states and percentile clipping on the mesh path stays
    bit-identical to the unpartitioned single-dispatch oracle — buckets
    change the launch schedule, never the numerics."""
    mesh = mesh_of(n_dev)
    kw = dict(lr=1e-2, min_8bit_size=1024, state_bits=(4, 8),
              stochastic_rounding=True, percentile_clipping=50,
              pclip_history=3)
    p_a, st_a = _train(make_optimizer("adam8", mesh=mesh,
                                      overlap_buckets=3, **kw),
                       _params(), steps=5)
    p_b, st_b = _train(make_optimizer("adam8", partition=False, **kw),
                       _params(), steps=5)
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b),
                       f"packed overlap mesh{n_dev}")
    assert_trees_equal(st_a.gnorm_vec, st_b.gnorm_vec, "gnorm history")
