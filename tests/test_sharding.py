"""Sharding-rule resolver unit tests (divisibility fallbacks are the core
guarantee that one codebase serves all 10 archs on a fixed mesh)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import rules


def _mesh():
    # abstract 4-device stand-in mesh with production axis names
    return jax.sharding.Mesh(
        np.array(jax.devices("cpu") * 1).reshape(1, 1, 1),
        ("pod", "data", "model"))


class FakeMesh:
    """Shape-only mesh stand-in for resolver tests (no devices needed)."""
    def __init__(self, shape_map):
        self._shape = shape_map
        self.axis_names = tuple(shape_map)

    @property
    def shape(self):
        return self._shape


PROD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_tp_on_divisible_heads():
    spec = rules.resolve_spec(("embed", "heads"), (2048, 4096), PROD,
                              rules.ShardingPolicy())
    assert spec[1] == "model" or (isinstance(spec[1], tuple)
                                  and "model" in spec[1])


def test_heads_fallback_when_not_divisible():
    """qwen's 40-head case: 'model'(16) doesn't divide 5120? it does —
    use a truly non-divisible dim to check the fallback drops the axis."""
    spec = rules.resolve_spec(("embed", "heads"), (30, 40), PROD,
                              rules.ShardingPolicy(fsdp_min_size=10**9))
    assert spec == P(None, None)


def test_fsdp_sweep_fully_shards_large_params():
    pol = rules.ShardingPolicy(fsdp_min_size=1 << 20)
    spec = rules.resolve_spec(("embed", "mlp"), (8192, 32768), PROD, pol)
    used = set()
    for e in spec:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    assert used == {"pod", "data", "model"}


def test_small_params_stay_replicated():
    spec = rules.resolve_spec(("embed",), (4096,), PROD,
                              rules.ShardingPolicy())
    assert spec == P(None)


def test_layers_dim_never_sharded():
    pol = rules.ShardingPolicy(fsdp_min_size=1)
    spec = rules.resolve_spec(("layers", "embed", "mlp"), (64, 4096, 16384),
                              PROD, pol)
    assert spec[0] is None


def test_expert_parallel_when_divisible():
    # kimi: 384 experts % 16 == 0 -> EP on model axis
    spec = rules.resolve_spec(("expert", "embed", "mlp"), (384, 7168, 2048),
                              PROD, rules.ShardingPolicy(fsdp_min_size=1 << 20))
    flat = [e for e in jax.tree_util.tree_leaves(tuple(spec)) if e]
    assert spec[0] == "model" or (isinstance(spec[0], tuple) and "model" in spec[0])


def test_mixtral_experts_fall_through_to_tp():
    # 8 experts % 16 != 0 -> model axis lands on mlp dim instead
    spec = rules.resolve_spec(("expert", "embed", "mlp"), (8, 6144, 16384),
                              PROD, rules.ShardingPolicy(fsdp_min_size=1 << 40))
    assert spec[0] is None
    assert spec[2] == "model" or (isinstance(spec[2], tuple)
                                  and "model" in spec[2])


def test_flat_block_spec_covers_all_axes():
    spec = rules.flat_block_spec(PROD)
    assert spec == P(("pod", "data", "model"), None)


def test_divisibility_always_respected():
    """Property: for random shapes, every assigned axis divides its dim."""
    rng = np.random.RandomState(0)
    pol = rules.ShardingPolicy(fsdp_min_size=1)
    for _ in range(200):
        shape = tuple(int(rng.choice([1, 3, 8, 24, 40, 64, 112, 2048, 5632]))
                      for _ in range(rng.randint(1, 4)))
        logical = tuple(rng.choice(["embed", "heads", "mlp", "vocab",
                                    "unsharded"]) for _ in shape)
        spec = rules.resolve_spec(logical, shape, PROD, pol)
        for dim, entry in zip(shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = 1
            for a in axes:
                prod *= PROD.shape[a]
            assert dim % prod == 0, (shape, logical, spec)
