"""End-to-end behaviour test for the paper's system: the "two-line change"
drop-in property — swap adam32 -> adam8, train the same model on the same
data, reach the same loss with ~4x less optimizer-statistics memory."""
import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core.optim import make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.train import loop as L


def test_drop_in_replacement_end_to_end():
    cfg = base.reduced(base.get_config("paper-lm-209m"), d_model=64,
                       n_layers=2, vocab_size=128)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=128, seq_len=32,
                                          global_batch=8))
    results = {}
    for name in ["adam32", "adam8"]:
        opt = make_optimizer(name, lr=5e-3, min_8bit_size=1024)  # line 1
        state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        step = jax.jit(L.make_train_step(cfg, opt))               # line 2
        for i in range(40):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            state, m = step(state, batch)
        results[name] = (float(m["loss"]),
                         opt.state_bytes(state.opt_state)["state_bytes"])
    l32, b32 = results["adam32"]
    l8, b8 = results["adam8"]
    assert abs(l8 - l32) < 0.05 * l32 + 0.05       # same quality
    assert b8 < b32 * 0.45                          # state memory saved
