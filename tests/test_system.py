"""End-to-end behaviour test for the paper's system: the "two-line change"
drop-in property — swap adam32 -> adam8, train the same model on the same
data, reach the same loss with ~4x less optimizer-statistics memory.
The pipeline/step setup lives in tests/helpers.py (shared with the golden
-trajectory and partition end-to-end tests)."""
import jax

from repro.core.optim import make_optimizer

from helpers import assert_trees_equal, mesh_of, tiny_train


def test_drop_in_replacement_end_to_end():
    results = {}
    for name in ["adam32", "adam8"]:
        opt = make_optimizer(name, lr=5e-3, min_8bit_size=1024)  # line 1
        state, m, _ = tiny_train(opt, 40)                        # line 2
        results[name] = (float(m["loss"]),
                         opt.state_bytes(state.opt_state)["state_bytes"])
    l32, b32 = results["adam32"]
    l8, b8 = results["adam8"]
    assert abs(l8 - l32) < 0.05 * l32 + 0.05       # same quality
    assert b8 < b32 * 0.45                          # state memory saved


def test_drop_in_replacement_partitioned_end_to_end():
    """The same drop-in property with the ZeRO-1 partitioned dispatch on
    the 4-device mesh (DESIGN.md §12): the trajectory tracks the
    unpartitioned adam8 run (apply itself is bit-exact on fixed grads —
    tests/test_partition.py; end-to-end the fwd/bwd compiles around the
    shard_map, so grads may differ at f32-ULP level and the runs track
    within a tight tolerance), and per-device owned state shrinks with
    the shard count."""
    mesh = mesh_of(4)
    opt_p = make_optimizer("adam8", lr=5e-3, min_8bit_size=1024,
                           mesh=mesh, partition=True)
    assert opt_p.cfg.partition_shards == 4 and opt_p.cfg.partition_active
    st_p, m_p, tr_p = tiny_train(opt_p, 40, trace=("loss",))
    opt_o = make_optimizer("adam8", lr=5e-3, min_8bit_size=1024,
                           partition=False)
    st_o, m_o, tr_o = tiny_train(opt_o, 40, trace=("loss",))
    import numpy as np
    np.testing.assert_allclose(tr_p["loss"], tr_o["loss"],
                               rtol=5e-3, atol=5e-3)
    sb = opt_p.state_bytes(st_p.opt_state)
    assert sb["partition_shards"] == 4
    part = st_p.opt_state.arena.partition
    assert part.n_shards == 4
    assert sum(n for _, n in part.spans) == part.total
    # each owner's span is ~1/4 of the arena (up to grid padding)
    assert sb["owned_blocks"] == part.span_pad
    assert sb["owned_state_bytes"] < sb["state_bytes"]
    assert float(m_p["opt_owned_blocks"]) == sb["owned_blocks"]
