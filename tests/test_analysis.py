"""Static-analysis subsystem tests (DESIGN.md §15).

Four families:

  * primitives — the contract text checks against synthetic StableHLO.
  * lowering contracts — the §14 zero-overhead guard on the contract
    API, overlap_buckets 1-vs-K donation invariance, partition on/off
    replication pins (4-device host mesh via conftest).
  * kernel budget — the VMEM model vs the real BlockSpec layouts, the
    NS envelope, grid alignment, oversized-tile detection.
  * mutation self-tests — every auditor must FIRE on its seeded
    violation (an auditor that cannot fail is decoration): promote_f64
    -> no_dtype, drop_replication_pin -> replicated, oversized block ->
    budget, synthetic host-sync source -> lint.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from helpers import mesh_of, tiny_cfg, tiny_pipe
from repro.analysis import contracts, dtypes, kernel_budget, lint, mutations
from repro.analysis import runner
from repro.core.optim import make_optimizer
from repro.errors import ConfigError, FormatError
from repro.train import loop as L


# ------------------------------------------------------------- primitives
def test_donation_markers_counts_both_kinds():
    text = ("func @main(%arg0: tensor<4xf32> {tf.aliasing_output = 0 : i32},"
            " %arg1: tensor<4xf32> {jax.buffer_donor = true},"
            " %arg2: tensor<4xf32> {tf.aliasing_output = 1 : i32})")
    m = contracts.donation_markers(text)
    assert m == {"aliased": 2, "donors": 1}
    ok, detail = contracts.check_donates(text, min_aliases=3)
    assert ok, detail
    ok, _ = contracts.check_donates(text, min_aliases=4)
    assert not ok


def test_no_dtype_finds_f64_not_f16():
    good = "stablehlo.add %0, %1 : tensor<8x16xf32>"
    bad = good + "\n  %2 = stablehlo.convert %0 : tensor<8xf64>"
    assert contracts.check_no_dtype(good, "f64")[0]
    ok, detail = contracts.check_no_dtype(bad, "f64")
    assert not ok and "f64" in detail
    # f16 in a shape must not trip the f64 scan ("f64" not a substring)
    assert contracts.check_no_dtype(
        "stablehlo.add %0, %1 : tensor<16xf16>", "f64")[0]


def test_accumulation_sites_and_check():
    text = "\n".join([
        "  %3 = stablehlo.dot_general %1, %2, contracting_dims = [1] x [0]"
        " : (tensor<8x16xf32>, tensor<16x8xf32>) -> tensor<8x8xf32>",
        "  %4 = stablehlo.reduce(%3 init: %c) applies stablehlo.add"
        " across dimensions = [1] : (tensor<8x8xf32>, tensor<f32>)"
        " -> tensor<8xf32>",
        "  %5 = stablehlo.reduce(%i init: %z) applies stablehlo.add"
        " across dimensions = [0] : (tensor<4xi32>, tensor<i32>)"
        " -> tensor<i32>",
    ])
    sites = contracts.accumulation_sites(text)
    assert [op for op, _, _ in sites] == ["dot_general", "reduce_add",
                                          "reduce_add"]
    ok, detail = contracts.check_accumulates_in(text, "f32")
    assert ok, detail          # the i32 reduction is exempt
    bf = text.replace("tensor<8x8xf32>", "tensor<8x8xbf16>")
    ok, detail = contracts.check_accumulates_in(bf, "f32")
    assert not ok and "bf16" in detail


def test_collective_order_checks_first_occurrence():
    text = "aaa SCATTER bbb UPDATE ccc GATHER ddd"
    ok, _ = contracts.check_collective_order(text, "SCATTER", "UPDATE",
                                             "GATHER")
    assert ok
    ok, detail = contracts.check_collective_order(text, "GATHER", "SCATTER")
    assert not ok and "VIOLATED" in detail
    # missing markers: ok only when not required
    ok, _ = contracts.check_collective_order(text, "SCATTER", "MISSING")
    assert not ok
    ok, _ = contracts.check_collective_order(text, "SCATTER", "MISSING",
                                             require_all=False)
    assert ok


def test_lowering_invariant_modes():
    a = "line1\nline2\nline3"
    ok, _ = contracts.lowering_invariant({0: a, 2: a})
    assert ok
    ok, detail = contracts.lowering_invariant({0: a, 2: a.replace("2", "X")})
    assert not ok and "line 2" in detail
    don = "{tf.aliasing_output = 0 : i32}"
    ok, _ = contracts.lowering_invariant(
        {1: "x" + don, 4: "yyy" + don}, compare_aliases_only=True)
    assert ok
    ok, _ = contracts.lowering_invariant(
        {1: don, 4: don * 2}, compare_aliases_only=True)
    assert not ok
    with pytest.raises(contracts.AnalysisError):
        contracts.lowering_invariant({1: a})


def test_registry_register_evaluate_not_applicable():
    contracts.register("tmp.test_contract", "step",
                       lambda low, cell: None if cell is None
                       else (True, "ok"), doc="test")
    try:
        spec = dict((s.name, s) for s in contracts.contracts_for("step"))[
            "tmp.test_contract"]
        low = contracts.Lowering("x", "")
        assert contracts.evaluate(spec, low, None) is None
        r = contracts.evaluate(spec, low, runner.Cell("c", "adamw8", (8, 8)))
        assert r.ok and r.target == "c"
    finally:
        contracts._REGISTRY.pop("tmp.test_contract", None)


# ----------------------------------------------------------- dtype table
def test_dtype_tables_are_shared_and_complete():
    from repro.roofline import analysis as roof
    from repro.roofline import hlo_cost
    assert hlo_cost._DTYPE_BYTES is dtypes.DTYPE_BYTES
    assert roof._DTYPE_BYTES is dtypes.DTYPE_BYTES
    # s4 rounds UP to 1 byte on purpose (HBM buffer storage; see module doc)
    for name, expect in (("f32", 4), ("bf16", 2), ("s4", 1), ("u8", 1),
                         ("f8e4m3fn", 1), ("c128", 16), ("pred", 1)):
        assert dtypes.dtype_bytes(name) == expect
    with pytest.raises(KeyError):
        dtypes.dtype_bytes("f128")


# ------------------------------------------------------ typed exceptions
def test_config_validation_raises_typed_errors():
    with pytest.raises(ConfigError):
        make_optimizer("adamw8", lr=1e-3, overlap_buckets=0)
    with pytest.raises(ConfigError):
        make_optimizer("adamw8", lr=1e-3, state_bits=3)
    with pytest.raises(FormatError):
        from repro.core.lowbit import packed_width
        packed_width(3, 4)  # 12 bits don't fill whole bytes
    # ConfigError/FormatError stay ValueError for existing except-clauses
    assert issubclass(ConfigError, ValueError)
    assert issubclass(FormatError, ValueError)


# ------------------------------------------------- lowering contracts
def _pooled_step_text(**overrides):
    cfg = tiny_cfg()
    pipe = tiny_pipe(vocab_size=cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    opt = make_optimizer("adam8", lr=5e-3, min_8bit_size=1024, **overrides)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    return L.jit_train_step(cfg, opt).lower(state, batch).as_text()


def test_telemetry_guard_on_contract_api():
    """The §14 zero-overhead guard via lowering_invariant (ex-PR-7 test)."""
    texts = {n: _pooled_step_text(telemetry_every=n) for n in (0, 2)}
    ok, detail = contracts.lowering_invariant(texts)
    assert ok, detail
    assert "tel." not in texts[0]


def test_overlap_buckets_donation_invariant():
    """overlap_buckets 1 vs K restructures dispatch but must keep every
    donated in-place arena (§13c) — the pair:overlap contract."""
    mesh = mesh_of(4)
    texts = {}
    for k in (1, 2):
        cell = runner.Cell(f"ov{k}", "adamw8", (8, 8), partition=4,
                           shard_grads=True, overlap_buckets=k)
        texts[k] = runner.lower_step(cell).text
    ok, detail = contracts.lowering_invariant(texts,
                                              compare_aliases_only=True)
    assert ok, detail
    del mesh


def test_partition_toggles_replication_pins():
    """partition on -> §12 replication pins appear; off -> none
    (the pair:partition contract)."""
    mesh = mesh_of(4)
    on = runner.lower_step(runner.Cell("on", "adamw8", (8, 8), partition=4))
    off = runner.lower_step(runner.Cell("off", "adamw8", (8, 8)))
    pins_on = contracts.replicated_pins(on.text)
    pins_off = contracts.replicated_pins(off.text)
    assert pins_on >= 1 and pins_off == 0, (pins_on, pins_off)
    ok, detail = contracts.check_replicated(on.text)
    assert ok, detail
    del mesh


def test_runner_matrix_cell_passes_all_step_contracts():
    """One full matrix cell end-to-end through the registered contracts."""
    import repro.kernels.ops  # noqa: F401 — registration side effects
    import repro.sharding.rules  # noqa: F401
    import repro.train.loop  # noqa: F401
    mesh_of(4)
    cell = runner.Cell("zero2", "adamw8", (8, 8), partition=4,
                       shard_grads=True, overlap_buckets=2)
    low = runner.lower_step(cell)
    assert low is not None
    results = [contracts.evaluate(s, low, cell)
               for s in contracts.contracts_for("step")]
    results = [r for r in results if r is not None]
    assert results and all(r.ok for r in results), \
        [str(r) for r in results if not r.ok]


# ------------------------------------------------------- kernel budget
def test_fused_update_tile_matches_blockspec_layout():
    """The VMEM mirror must agree with the real in_specs assembly: the
    streamed input bytes of one adamw tile are exactly the BlockSpec
    shapes of fused_update_pallas (p, g, codes_m, absmax_m, codes_r,
    absmax_r) and the outputs mirror them."""
    rows, bsz = 8, 2048
    t = kernel_budget.fused_update_tile("adamw", rows=rows, block_size=bsz)
    row = rows * bsz * 4
    assert t.streamed_in == 2 * row + rows * bsz + rows * 4 \
        + rows * bsz + rows * 4          # p,g + cm,am + cr,ar
    assert t.streamed_out == row + rows * bsz + rows * 4 \
        + rows * bsz + rows * 4
    # 4-bit momentum halves the state-1 code stream exactly
    t4 = kernel_budget.fused_update_tile("adamw", rows=rows, block_size=bsz,
                                         bits_m=4)
    assert t.streamed_in - t4.streamed_in == rows * bsz // 2
    # lars adds the tensor-scale slice, single state
    tl = kernel_budget.fused_update_tile("lars", rows=rows, block_size=bsz)
    assert tl.config["bits_r"] is None


def test_budget_audit_clean_and_oversized_detected():
    results = kernel_budget.audit()
    bad = [r for r in results if not r[1]]
    assert not bad, bad
    # mutation: an absurd block size must blow the budget
    big = kernel_budget.fused_update_tile("adamw", block_size=1 << 19)
    assert not big.fits()
    assert big.headroom() < 0


def test_ns_envelope_and_matrix_rejected():
    assert kernel_budget.ns_max_m() >= 1024
    with pytest.raises(contracts.AnalysisError):
        kernel_budget.fused_update_tile("muon")


def test_grid_alignment_checks():
    from repro.core.optim import base as optim_base

    ok, detail = kernel_budget.check_grid_alignment(12345, 4, 2, grid=8)
    assert ok, detail
    # production grid: shard_multiple == mesh size, distinct from kernel rows
    ok, detail = kernel_budget.check_grid_alignment(1000, 4, 2, grid=4)
    assert ok, detail

    # The checker must actually be able to fail: corrupt a valid plan and
    # assert each corruption class fires.
    part = optim_base.make_partition(1000, 4, grid=4)
    plan = optim_base.make_buckets(part, 2, grid=4)
    ok, _ = kernel_budget.check_partition_plan(part, plan, grid=4)
    assert ok

    # misaligned bucket boundary inside the span
    bad_ranges = ((0, 3),) + tuple((3 if k0 == plan.ranges[1][0] else k0, k1)
                                   for k0, k1 in plan.ranges[1:])
    bad_plan = dataclasses.replace(plan, ranges=bad_ranges)
    ok, detail = kernel_budget.check_partition_plan(part, bad_plan, grid=4)
    assert not ok and "misaligned" in detail

    # non-contiguous / non-covering bucket ranges
    gap_plan = dataclasses.replace(plan, ranges=plan.ranges[:-1])
    ok, detail = kernel_budget.check_partition_plan(part, gap_plan, grid=4)
    assert not ok

    # span_pad off the grid
    bad_part = dataclasses.replace(part, span_pad=part.span_pad + 1)
    ok, detail = kernel_budget.check_partition_plan(bad_part, None, grid=4)
    assert not ok and "span_pad" in detail


def test_budget_table_shape():
    table = kernel_budget.budget_table()
    kernels = {row["kernel"] for row in table}
    assert {"fused_update", "blockwise_quant", "blockwise_dequant",
            "newton_schulz_gram", "newton_schulz_apply"} <= kernels
    for row in table:
        assert row["total_bytes"] == (
            2 * (row["streamed_in_bytes"] + row["streamed_out_bytes"])
            + row["invariant_bytes"] + row["scratch_bytes"])


# ----------------------------------------------------- mutation self-tests
def test_mutation_promote_f64_trips_no_dtype():
    """Seeded f64 promotion in ops.fused_update must trip no_dtype(f64).
    x64 mode is enabled only around the bare update lowering — without it
    the astype silently stays f32 and the mutation proves nothing."""
    # Clean reference lowered in normal (x64-off) mode: under enable_x64
    # even an unmutated lowering carries f64 weak-typed constants, so the
    # clean check must use the production trace mode.
    clean = runner.lower_update("adamw", 8)
    assert contracts.check_no_dtype(clean.text, "f64")[0] is True
    with jax.experimental.enable_x64():
        with mutations.seeded("promote_f64"):
            mutated = runner.lower_update("adamw", 8)
    ok, detail = contracts.check_no_dtype(mutated.text, "f64")
    assert not ok, "auditor failed to fire on seeded f64 promotion"
    assert "f64" in detail


def test_mutation_drop_replication_pin_trips_replicated():
    """Dropping replicate_for_scales must strip the §12 scale pins and trip
    the registered replicated_scales auditor.  The arena layout pins the
    (256,) codebook constants and a few scalars independently, so the
    auditor counts vector pins excluding the codebook shape — those must
    go to exactly zero under the mutation."""
    from repro.kernels import common as kernels_common
    from repro.sharding import rules  # ensure auditor registration

    mesh_of(4)
    cell = runner.Cell("mut", "adamw8", (8, 8), partition=4)
    codebook = ((kernels_common.CODEBOOK_SIZE,),)
    clean = runner.lower_step(cell)
    assert contracts.check_replicated(clean.text, vectors_only=True,
                                      exclude_shapes=codebook)[0]
    with mutations.seeded("drop_replication_pin"):
        mutated = runner.lower_step(cell)
    pins = contracts.replicated_pins(mutated.text, vectors_only=True,
                                     exclude_shapes=codebook)
    assert pins == 0, f"mutation left {pins} scale pins"
    # the registered auditor itself must fire on the mutated lowering
    (contract,) = [c for c in contracts.all_contracts()
                   if c.name == "partitioned_step.replicated_scales"]
    ok, detail = contract.check(mutated, cell)
    assert not ok, f"auditor failed to fire: {detail}"


def test_mutation_unknown_name_rejected():
    with pytest.raises(ValueError):
        with mutations.seeded("not_a_mutation"):
            pass
    assert not mutations.active("promote_f64")


def test_mutation_host_sync_lint_fires(tmp_path):
    """The host-sync rule must fire on a jitted function calling .item()
    (static lint runs on source, so the violation is a synthetic file)."""
    bad = tmp_path / "bad_module.py"
    bad.write_text(
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    s = x.sum().item()\n"
        "    y = jax.device_get(x)\n"
        "    return s, y\n")
    vs = lint.lint_paths(str(tmp_path))
    rules = sorted(v.rule for v in vs)
    assert rules == ["host-sync-in-jit", "host-sync-in-jit"], vs


def test_lint_rules_on_synthetic_sources(tmp_path):
    (tmp_path / "m.py").write_text(
        "import os\n"
        "import os\n"
        "def f():\n"
        "    assert True\n"
        "    return os.environ.get('X')\n")
    vs = lint.lint_paths(str(tmp_path))
    rules = sorted(v.rule for v in vs)
    assert rules == ["bare-assert", "duplicate-import", "env-read-at-trace"]


def test_lint_baseline_gate(tmp_path):
    (tmp_path / "m.py").write_text("def f():\n    assert True\n")
    base = tmp_path / "baseline.json"
    ok, _ = lint.run(str(tmp_path), baseline_path=str(base))
    assert not ok                               # no baseline: new violation
    ok, _ = lint.run(str(tmp_path), baseline_path=str(base),
                     update_baseline=True)
    assert ok and json.loads(base.read_text()) == {"m.py::bare-assert": 1}
    ok, _ = lint.run(str(tmp_path), baseline_path=str(base))
    assert ok                                   # baselined
    (tmp_path / "m.py").write_text(
        "def f():\n    assert True\n    assert False\n")
    ok, lines = lint.run(str(tmp_path), baseline_path=str(base))
    assert not ok and any("NEW" in ln for ln in lines)


def test_repo_lint_is_clean_against_baseline():
    import os
    # repro is a namespace package (__file__ is None); anchor on a module
    root = os.path.dirname(os.path.dirname(lint.__file__))
    ok, lines = lint.run(root)
    assert ok, "\n".join(lines)
