"""Pallas kernel validation: interpret-mode execution vs the pure-jnp oracle
(ref.py), swept over shapes and dtypes as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qmap
from repro.kernels import ops, ref

QS = jnp.asarray(qmap.get_qmap("dynamic", True))
QU = jnp.asarray(qmap.get_qmap("dynamic", False))

SHAPES = [(1, 128), (4, 256), (8, 512), (3, 2048), (16, 1024)]


def _rand(nb, bsz, seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (nb, bsz), jnp.float32) * scale


@pytest.mark.parametrize("nb,bsz", SHAPES)
def test_quantize_kernel_matches_ref(nb, bsz):
    x = _rand(nb, bsz, scale=0.01)
    c_k, a_k = ops.quantize_blockwise(x, QS, impl="interpret")
    c_r, a_r = ref.quantize_ref(x, QS)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r))


@pytest.mark.parametrize("nb,bsz", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dequantize_kernel_matches_ref(nb, bsz, dtype):
    x = _rand(nb, bsz, seed=1)
    c, a = ref.quantize_ref(x, QS)
    d_k = ops.dequantize_blockwise(c, a, QS, impl="interpret", dtype=dtype)
    d_r = ref.dequantize_ref(c, a, QS, dtype)
    np.testing.assert_allclose(np.asarray(d_k, np.float32),
                               np.asarray(d_r, np.float32), atol=1e-6)


@pytest.mark.parametrize("nb,bsz", [(2, 256), (5, 512), (8, 2048)])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_fused_adam8_matches_ref(nb, bsz, gdtype):
    p = _rand(nb, bsz, 2)
    g = _rand(nb, bsz, 3, 0.1).astype(gdtype)
    cm, am = ref.quantize_ref(_rand(nb, bsz, 4, 0.01), QS)
    cr, ar = ref.quantize_ref(jnp.abs(_rand(nb, bsz, 5, 1e-4)), QU)
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8,
              weight_decay=0.01, step=7.0)
    out_k = ops.adam8_update(p, g, cm, am, cr, ar, QS, QU,
                             impl="interpret", **kw)
    out_r = ops.adam8_update(p, g, cm, am, cr, ar, QS, QU, impl="jnp", **kw)
    for k_, r_ in zip(out_k, out_r):
        if k_.dtype == jnp.uint8:
            # codes may differ only at exact boundary ties
            mism = int((np.asarray(k_) != np.asarray(r_)).sum())
            assert mism <= k_.size * 0.001
        else:
            np.testing.assert_allclose(np.asarray(k_, np.float32),
                                       np.asarray(r_, np.float32),
                                       atol=5e-6, rtol=1e-5)


@pytest.mark.parametrize("nb,bsz", [(2, 256), (4, 1024)])
def test_fused_momentum8_matches_ref(nb, bsz):
    p = _rand(nb, bsz, 6)
    g = _rand(nb, bsz, 7, 0.1)
    cm, am = ref.quantize_ref(_rand(nb, bsz, 8, 0.05), QS)
    kw = dict(lr=0.1, beta1=0.9, weight_decay=1e-4, step=3.0)
    out_k = ops.momentum8_update(p, g, cm, am, QS, impl="interpret", **kw)
    out_r = ops.momentum8_update(p, g, cm, am, QS, impl="jnp", **kw)
    for k_, r_ in zip(out_k, out_r):
        if k_.dtype == jnp.uint8:
            assert int((np.asarray(k_) != np.asarray(r_)).sum()) <= k_.size * 0.001
        else:
            np.testing.assert_allclose(np.asarray(k_), np.asarray(r_),
                                       atol=5e-6, rtol=1e-5)


def test_kernel_row_padding():
    """ops.* pads non-multiple-of-rows block counts transparently."""
    x = _rand(5, 256)      # 5 rows, default rows=8 -> padded to 8
    c_k, a_k = ops.quantize_blockwise(x, QS, impl="interpret", rows=8)
    c_r, a_r = ref.quantize_ref(x, QS)
    assert c_k.shape == (5, 256)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


def test_zero_block_safe():
    """All-zero blocks (padding) must not produce NaN (absmax=0 guard)."""
    x = jnp.zeros((4, 256))
    c, a = ops.quantize_blockwise(x, QS, impl="interpret")
    d = ops.dequantize_blockwise(c, a, QS, impl="interpret")
    assert not bool(jnp.isnan(d).any())
    assert float(jnp.abs(d).max()) == 0.0


def test_quantize_other_codebooks():
    """Kernel works for any sorted 256-codebook (linear, quantile...)."""
    for name, signed in [("linear", True), ("quantile_normal", True),
                         ("inverse_dynamic", False)]:
        cb = jnp.asarray(qmap.get_qmap(name, signed))
        x = _rand(4, 256, 9) if signed else jnp.abs(_rand(4, 256, 9))
        c_k, a_k = ops.quantize_blockwise(x, cb, impl="interpret")
        c_r, a_r = ref.quantize_ref(x, cb)
        np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
