"""Pallas kernel validation: interpret-mode execution vs the pure-jnp oracle
(ref.py), swept over shapes and dtypes as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qmap
from repro.kernels import ops, ref

QS = jnp.asarray(qmap.get_qmap("dynamic", True))
QU = jnp.asarray(qmap.get_qmap("dynamic", False))

SHAPES = [(1, 128), (4, 256), (8, 512), (3, 2048), (16, 1024)]


def _rand(nb, bsz, seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (nb, bsz), jnp.float32) * scale


@pytest.mark.parametrize("nb,bsz", SHAPES)
def test_quantize_kernel_matches_ref(nb, bsz):
    x = _rand(nb, bsz, scale=0.01)
    c_k, a_k = ops.quantize_blockwise(x, QS, impl="interpret")
    c_r, a_r = ref.quantize_ref(x, QS)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r))


@pytest.mark.parametrize("nb,bsz", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dequantize_kernel_matches_ref(nb, bsz, dtype):
    x = _rand(nb, bsz, seed=1)
    c, a = ref.quantize_ref(x, QS)
    d_k = ops.dequantize_blockwise(c, a, QS, impl="interpret", dtype=dtype)
    d_r = ref.dequantize_ref(c, a, QS, dtype)
    np.testing.assert_allclose(np.asarray(d_k, np.float32),
                               np.asarray(d_r, np.float32), atol=1e-6)


ALGOS = list(ops.ALGOS)
HYPER = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8,
             weight_decay=0.01, step=7.0, trust_coeff=1e-3)


def _fused_inputs(algo, nb, bsz, gdtype=jnp.float32):
    """(p, g, codes_m, absmax_m, codes_r, absmax_r, qmap_m, qmap_r)."""
    spec_two = algo in ("adam", "adamw", "lamb")
    p = _rand(nb, bsz, 2)
    g = _rand(nb, bsz, 3, 0.1).astype(gdtype)
    if algo == "adagrad":
        cm, am = ref.quantize_ref(jnp.abs(_rand(nb, bsz, 4, 1e-3)), QU)
        q1 = QU
    else:
        cm, am = ref.quantize_ref(_rand(nb, bsz, 4, 0.01), QS)
        q1 = QS
    cr = ar = None
    if spec_two:
        cr, ar = ref.quantize_ref(jnp.abs(_rand(nb, bsz, 5, 1e-4)), QU)
    return p, g, cm, am, cr, ar, q1, QU


def _assert_results_close(out_k, out_r, tol_codes=0.001):
    for name, k_, r_ in zip(out_k._fields, out_k, out_r):
        if k_ is None:
            assert r_ is None, name
        elif k_.dtype == jnp.uint8:
            # codes may differ only at exact boundary ties
            mism = int((np.asarray(k_) != np.asarray(r_)).sum())
            assert mism <= k_.size * tol_codes, (name, mism)
        else:
            np.testing.assert_allclose(np.asarray(k_, np.float32),
                                       np.asarray(r_, np.float32),
                                       atol=5e-6, rtol=1e-5, err_msg=name)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("nb,bsz", [(2, 256), (4, 512)])
def test_fused_update_matches_ref(algo, nb, bsz):
    """The unified kernel path (interpret) vs the jnp registry entry, for
    all six algorithms — including the LAMB/LARS norm prologue."""
    args = _fused_inputs(algo, nb, bsz)
    out_k = ops.fused_update(algo, *args, impl="interpret", **HYPER)
    out_r = ops.fused_update(algo, *args, impl="jnp", **HYPER)
    _assert_results_close(out_k, out_r)


@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_grad_dtypes(gdtype):
    args = _fused_inputs("adam", 8, 2048, gdtype)
    out_k = ops.fused_update("adam", *args, impl="interpret", **HYPER)
    out_r = ops.fused_update("adam", *args, impl="jnp", **HYPER)
    _assert_results_close(out_k, out_r)


@pytest.mark.parametrize("algo", ["adam", "lars"])
def test_fused_update_stochastic_parity(algo):
    """In-kernel stochastic rounding uses the same counter-based PRNG as
    the jnp reference, so codes agree bit-for-bit given the same seed."""
    args = _fused_inputs(algo, 2, 256)
    out_k = ops.fused_update(algo, *args, impl="interpret",
                             stochastic=True, seed=123, **HYPER)
    out_r = ops.fused_update(algo, *args, impl="jnp",
                             stochastic=True, seed=123, **HYPER)
    _assert_results_close(out_k, out_r)
    # ...and a different seed actually changes the rounding
    out_s = ops.fused_update(algo, *args, impl="jnp",
                             stochastic=True, seed=124, **HYPER)
    assert int((np.asarray(out_r.codes_m) != np.asarray(out_s.codes_m)).sum()) > 0


def test_fused_update_stochastic_mean_preserving():
    """Averaged over seeds, stochastic requantization of the new state is
    closer to the exact 32-bit state than deterministic rounding (the whole
    point of the ablation, paper App H)."""
    nb, bsz = 1, 2048
    qs = QS
    p = jnp.zeros((nb, bsz))
    # With zero-initialized momentum, m2 == g exactly. One 1.0 element pins
    # the block absmax, the 0.3 bulk sits between dynamic-map codes.
    g = jnp.full((nb, bsz), 0.3).at[0, 0].set(1.0)
    cm, am = ref.quantize_ref(jnp.zeros((nb, bsz)), qs)
    kw = dict(HYPER, lr=0.0, weight_decay=0.0)
    exact = float(g.mean())
    det = ops.fused_update("momentum", p, g, cm, am, None, None, qs, QU,
                           impl="jnp", **kw)
    det_mean = float(ref.dequantize_ref(det.codes_m, det.absmax_m, qs).mean())
    assert abs(det_mean - exact) > 1e-6   # deterministic rounding is biased
    means = []
    for seed in range(30):
        st = ops.fused_update("momentum", p, g, cm, am, None, None, qs, QU,
                              impl="jnp", stochastic=True, seed=seed, **kw)
        means.append(float(ref.dequantize_ref(st.codes_m, st.absmax_m, qs).mean()))
    assert abs(np.mean(means) - exact) < abs(det_mean - exact)


def test_fused_update_gnorm_scale_scales_grad():
    """gnorm_scale=0.5 inside the fused path must equal feeding g/2."""
    args = _fused_inputs("adam", 2, 256)
    p, g, cm, am, cr, ar, q1, q2 = args
    a = ops.fused_update("adam", p, g, cm, am, cr, ar, q1, q2,
                         impl="interpret", gnorm_scale=0.5, **HYPER)
    b = ops.fused_update("adam", p, g * 0.5, cm, am, cr, ar, q1, q2,
                         impl="interpret", **HYPER)
    _assert_results_close(a, b)


def test_fused_update_tensorwise_ablation():
    """blockwise=False (tensor-wise absmax) routes to the jnp entry and
    produces a single shared absmax per state tensor."""
    args = _fused_inputs("adam", 4, 256)
    out = ops.fused_update("adam", *args, impl="interpret",
                           blockwise=False, **HYPER)
    am = np.asarray(out.absmax_m)
    assert np.all(am == am[0])


def test_fused_update_unknown_combo_raises():
    args = _fused_inputs("adam", 2, 256)
    with pytest.raises(KeyError):
        ops.fused_update("adam", *args, impl="cuda", **HYPER)


def test_fused_update_row_padding():
    """n_blocks not a multiple of DEFAULT_ROWS is padded transparently."""
    args = _fused_inputs("adam", 5, 256)
    out_k = ops.fused_update("adam", *args, impl="interpret", **HYPER)
    out_r = ops.fused_update("adam", *args, impl="jnp", **HYPER)
    assert out_k.p.shape == (5, 256)
    _assert_results_close(out_k, out_r)


def test_kernel_row_padding():
    """ops.* pads non-multiple-of-rows block counts transparently."""
    x = _rand(5, 256)      # 5 rows, default rows=8 -> padded to 8
    c_k, a_k = ops.quantize_blockwise(x, QS, impl="interpret", rows=8)
    c_r, a_r = ref.quantize_ref(x, QS)
    assert c_k.shape == (5, 256)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


def test_default_rows_consistent():
    """One DEFAULT_ROWS across the kernel package (hoisted into common)."""
    from repro.kernels import blockwise_dequant, blockwise_quant, common
    assert ops.DEFAULT_ROWS == common.DEFAULT_ROWS
    assert blockwise_quant.DEFAULT_ROWS == common.DEFAULT_ROWS
    assert blockwise_dequant.DEFAULT_ROWS == common.DEFAULT_ROWS


def test_zero_block_safe():
    """All-zero blocks (padding) must not produce NaN (absmax=0 guard)."""
    x = jnp.zeros((4, 256))
    c, a = ops.quantize_blockwise(x, QS, impl="interpret")
    d = ops.dequantize_blockwise(c, a, QS, impl="interpret")
    assert not bool(jnp.isnan(d).any())
    assert float(jnp.abs(d).max()) == 0.0


def test_quantize_other_codebooks():
    """Kernel works for any sorted 256-codebook (linear, quantile...)."""
    for name, signed in [("linear", True), ("quantile_normal", True),
                         ("inverse_dynamic", False)]:
        cb = jnp.asarray(qmap.get_qmap(name, signed))
        x = _rand(4, 256, 9) if signed else jnp.abs(_rand(4, 256, 9))
        c_k, a_k = ops.quantize_blockwise(x, cb, impl="interpret")
        c_r, a_r = ref.quantize_ref(x, cb)
        np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
