"""Optimizer behaviour: 8-bit vs 32-bit parity, convergence, overrides,
memory accounting, ablation modes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optim import (Block8bitOptimizer, Full32Leaf, OptimConfig,
                              Pool32Leaf, PooledQuantLeaf, Quant8Leaf,
                              make_optimizer, unpool_state)


def _params(key=0):
    k = jax.random.PRNGKey(key)
    ks = jax.random.split(k, 3)
    return {
        "dense": {"w": jax.random.normal(ks[0], (64, 128))},
        "embed": {"w": jax.random.normal(ks[1], (128, 64))},
        "bias": jnp.zeros((10,)),
    }


def _loss(p, target):
    return sum(jnp.sum((a - b) ** 2)
               for a, b in zip(jax.tree_util.tree_leaves(p),
                               jax.tree_util.tree_leaves(target)))


def _run(name, steps=150, lr=3e-2, **kw):
    params = _params()
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    opt = make_optimizer(name, lr=lr, min_8bit_size=1024, **kw)
    st = opt.init(params)
    grad = jax.jit(jax.grad(lambda p: _loss(p, target)))
    p = params
    for _ in range(steps):
        p, st = opt.apply(grad(p), st)
    return float(_loss(p, target)), opt, st


def test_adam8_matches_adam32():
    l32, _, _ = _run("adam32")
    l8, _, _ = _run("adam8")
    assert abs(l8 - l32) / max(l32, 1e-6) < 0.5


def test_momentum_converges():
    l8, _, _ = _run("momentum8", lr=1e-2)
    assert l8 < 1e-3


@pytest.mark.parametrize("name", ["lamb8", "adagrad8", "adafactor32",
                                  "lars8", "adamw8"])
def test_all_optimizers_decrease_loss(name):
    params = _params()
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    l0 = float(_loss(params, target))
    lend, _, _ = _run(name, steps=100, lr=1e-2)
    assert lend < l0


def test_stable_embedding_override_is_32bit():
    """Paper §2.3: embedding leaves keep 32-bit optimizer state.  Under the
    pooled dispatch the quantized leaf is a PooledQuantLeaf (arena slice)
    and the small leaf pools into the fp32 arena; the per-leaf canonical
    view recovers the classic containers."""
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024)
    st = opt.init(_params())
    assert isinstance(st.leaves["embed"]["w"], Full32Leaf)
    assert isinstance(st.leaves["dense"]["w"], PooledQuantLeaf)
    assert isinstance(st.leaves["bias"], Pool32Leaf)   # < min_8bit_size
    view = unpool_state(st)
    assert isinstance(view.leaves["embed"]["w"], Full32Leaf)
    assert isinstance(view.leaves["dense"]["w"], Quant8Leaf)
    assert isinstance(view.leaves["bias"], Full32Leaf)
    # ...and the per-leaf dispatch (the parity oracle) keeps them directly
    opt_pl = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024,
                            pooled=False)
    st_pl = opt_pl.init(_params())
    assert isinstance(st_pl.leaves["embed"]["w"], Full32Leaf)
    assert isinstance(st_pl.leaves["dense"]["w"], Quant8Leaf)
    assert isinstance(st_pl.leaves["bias"], Full32Leaf)


def test_memory_accounting():
    opt8 = make_optimizer("adam8", lr=1e-3, min_8bit_size=1,
                          override_32bit=lambda p: False)
    opt32 = make_optimizer("adam32", lr=1e-3)
    p = {"w": jnp.zeros((4096, 64))}           # 256k elements, 128 blocks
    b8 = opt8.state_bytes(opt8.init(p))
    b32 = opt32.state_bytes(opt32.init(p))
    # 2 states: 8-bit = 2*(1 + 4/2048) bytes/param vs 8 bytes/param
    assert b32["state_bytes"] == 8 * 4096 * 64
    assert b8["state_bytes"] == pytest.approx(2 * 4096 * 64 * (1 + 4 / 2048),
                                              rel=1e-6)
    assert b8["state_bytes"] < b32["state_bytes"] / 3.9


def test_bf16_master_mode():
    l8, opt, st = _run("adam8", master_dtype="bfloat16")
    assert st.leaves["dense"]["w"].master.dtype == jnp.bfloat16
    assert np.isfinite(l8)


def test_tensorwise_ablation_runs():
    l, _, _ = _run("adam8", blockwise_norm=False)
    assert np.isfinite(l)


def test_linear_qmap_ablation_runs():
    l, _, _ = _run("adam8", qmap_m="linear", qmap_r="linear")
    assert np.isfinite(l)


def test_stochastic_rounding_path():
    params = _params()
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    opt = make_optimizer("adagrad8", lr=1e-2, min_8bit_size=1024,
                         stochastic_rounding=True)
    st = opt.init(params)
    g = jax.grad(lambda p: _loss(p, target))(params)
    p2, st2 = opt.apply(g, st, key=jax.random.PRNGKey(0))
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(p2))


def test_params_view_matches_apply_output():
    params = _params()
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024)
    st = opt.init(params)
    view = opt.params_view(st)
    for a, b in zip(jax.tree_util.tree_leaves(view),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_shard_multiple_pads_blocks():
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1,
                         override_32bit=lambda p: False, shard_multiple=16)
    st = opt.init({"w": jnp.zeros((5000,))})
    assert st.arena.codes_m.shape[0] % 16 == 0
    assert all(s.n_blocks % 16 == 0 for s in st.arena.segments)
    assert unpool_state(st).leaves["w"].codes_m.shape[0] % 16 == 0


def test_stochastic_rounding_needs_no_key():
    """Seeds derive from the step counter when no key is given, so the
    train loop can run stochastic rounding without threading RNG state."""
    params = _params()
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    opt = make_optimizer("adam8", lr=1e-2, min_8bit_size=1024,
                         stochastic_rounding=True)
    st = opt.init(params)
    g = jax.grad(lambda p: _loss(p, target))(params)
    p1, st1 = opt.apply(g, st)
    p1b, st1b = opt.apply(g, st)          # same step -> same seed -> same codes
    codes = lambda s: np.asarray(unpool_state(s).leaves["dense"]["w"].codes_m)
    np.testing.assert_array_equal(codes(st1), codes(st1b))
    _, st2 = opt.apply(g, st1)            # next step -> different rounding
    assert not np.array_equal(codes(st1), codes(st2))


def test_percentile_clipping_state_and_scale():
    """gnorm history fills with squared global grad norms; once full, a
    spike step is scaled down to the percentile of the history."""
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024,
                         percentile_clipping=50, pclip_history=4)
    params = _params()
    st = opt.init(params)
    assert st.gnorm_vec is not None and st.gnorm_vec.shape == (4,)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    gn2 = sum(x.size for x in jax.tree_util.tree_leaves(params))
    for _ in range(4):                      # fill the history
        scale, _ = opt.percentile_clip(g, st)
        assert float(scale) == 1.0          # warmup / steady norms: no clip
        _, st = opt.apply(g, st)
    np.testing.assert_allclose(np.asarray(st.gnorm_vec), gn2, rtol=1e-6)
    g_spike = jax.tree_util.tree_map(lambda x: 10.0 * jnp.ones_like(x), params)
    scale, _ = opt.percentile_clip(g_spike, st)
    # clip to the 50th percentile of [gn2*4 (one slot now 100*gn2)]
    assert 0.0 < float(scale) < 1.0
    g_small = jax.tree_util.tree_map(lambda x: 0.1 * jnp.ones_like(x), params)
    scale_small, _ = opt.percentile_clip(g_small, st)
    assert float(scale_small) == 1.0        # below percentile: untouched


def test_percentile_clipping_warmup_never_clips():
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024,
                         percentile_clipping=5, pclip_history=8)
    params = _params()
    st = opt.init(params)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    scale, _ = opt.percentile_clip(g, st)
    assert float(scale) == 1.0              # history not full yet


def test_percentile_clipping_training_converges():
    l, _, st = _run("adam8", steps=60, percentile_clipping=95,
                    pclip_history=8)
    assert np.isfinite(l)
    assert st.gnorm_vec is not None
    assert float(jnp.min(st.gnorm_vec)) > 0.0   # history populated


def test_percentile_clipping_off_allocates_no_state():
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024)
    st = opt.init(_params())
    assert st.gnorm_vec is None


def test_adagrad_single_state():
    """AdaGrad is a one-state optimizer (accumulator in the m slot) — no
    second-moment arrays are allocated."""
    opt = make_optimizer("adagrad8", lr=1e-2, min_8bit_size=1024,
                         override_32bit=lambda p: False)
    st = opt.init(_params())
    assert st.arena.codes_r is None and st.arena.absmax_r is None
    leaf = unpool_state(st).leaves["dense"]["w"]
    assert leaf.codes_r is None and leaf.absmax_r is None


def test_bias_correction_first_step_magnitude():
    """After one step from zero state, Adam update ~= lr * sign(g)."""
    opt = make_optimizer("adam32", lr=0.1, weight_decay=0.0)
    p = {"w": jnp.zeros((8,))}
    st = opt.init(p)
    g = {"w": jnp.ones((8,)) * 3.0}
    p2, _ = opt.apply(g, st)
    np.testing.assert_allclose(np.asarray(p2["w"]), -0.1, rtol=1e-3)
