"""Serving correctness: prefill + decode must reproduce teacher-forced
forward logits across every architecture family (incl. SWA ring caches and
recurrent O(1) state)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServeEngine


def _mk(**kw):
    d = dict(arch_id="t", family="dense", n_layers=2, d_model=32, n_heads=4,
             n_kv_heads=2, d_ff=64, vocab_size=97, head_dim=8,
             compute_dtype="float32", remat="none", attn_chunk=16)
    d.update(kw)
    return ModelConfig(**d)


CASES = {
    "dense": _mk(),
    "swa_ring": _mk(attn_type="swa", window=8),
    "moe": _mk(n_experts=4, top_k=2, moe_dff=32, capacity_factor=4.0),
    "hybrid_rglru": _mk(n_layers=8, block_pattern=("rglru", "rglru", "attn"),
                        lru_width=32, attn_type="swa", window=8),
    "xlstm": _mk(n_layers=4, block_pattern=("mlstm", "slstm"), d_ff=0),
}


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    key = jax.random.PRNGKey(0)
    S, P = 20, 12
    tok = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
    params, _ = M.init_model(cfg, key)
    full, _ = M.forward(cfg, params, tok)
    logits_p, cache = M.prefill(cfg, params, tok[:, :P], max_len=S)
    errs = [float(jnp.abs(logits_p[:, -1] - full[:, P - 1]).max())]
    for t in range(P, S):
        lg, cache = M.decode_step(cfg, params, tok[:, t:t + 1], cache, t)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-5, errs


def test_swa_ring_cache_is_bounded():
    cfg = _mk(attn_type="swa", window=8)
    cache = M.init_cache(cfg, batch=2, max_len=1024)
    k = cache["scan"]["b0_attn"]["k"]
    assert k.shape[2] == 8     # (n_super, B, eff=window, KV, Dh)


def test_generate_greedy_deterministic():
    cfg = CASES["dense"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64))
    prompts = np.random.RandomState(0).randint(0, 97, (3, 10)).astype(np.int32)
    g1 = eng.generate(prompts, 6)
    g2 = eng.generate(prompts, 6)
    np.testing.assert_array_equal(g1, g2)
    assert g1.shape == (3, 6)


def test_generate_zero_new_tokens_is_empty():
    """max_new_tokens=0 must return shape (B, 0): the prefill-sampled token
    belongs to position P and must not leak into a 0-token request."""
    cfg = CASES["dense"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64))
    prompts = np.random.RandomState(0).randint(0, 97, (3, 10)).astype(np.int32)
    out = eng.generate(prompts, 0)
    assert out.shape == (3, 0) and out.dtype == np.int32


def test_generate_capacity_check_raises():
    """Capacity overrun raises ValueError naming the offending lengths
    (an assert would vanish under `python -O`); negative counts too."""
    cfg = CASES["dense"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=16))
    prompts = np.zeros((2, 10), np.int32)
    with pytest.raises(ValueError, match="10.*7.*16"):
        eng.generate(prompts, 7)
    with pytest.raises(ValueError, match="-1"):
        eng.generate(prompts, -1)


def test_serve_telemetry_latency_and_throughput():
    """§16 serving observability: per-request latency lands in the
    pre-binned histogram (cumulative across calls, incl. 0-token
    requests) and a generated-tokens/s gauge is published; every emitted
    event is schema-valid."""
    from repro.serve import engine as E
    from repro.telemetry import InMemorySink, MetricRegistry, validate_event

    cfg = CASES["dense"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    reg = MetricRegistry()
    sink = InMemorySink()
    reg.add_sink(sink)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64), registry=reg)
    prompts = np.random.RandomState(0).randint(0, 97, (3, 10)).astype(np.int32)
    eng.generate(prompts, 6)
    eng.generate(prompts, 0)

    m = reg.metrics()
    counts = np.asarray(m["serve/latency_ms"])
    assert counts.shape == (E.N_LATENCY_BINS,)
    assert counts.sum() == 6          # 3 requests per call, 2 calls
    assert m["serve/requests"] == 6
    assert m["serve/generated_tokens"] == 18
    assert m["serve/tokens_per_s"] > 0.0
    reg.flush(step=3)
    assert sink.events, "flush emitted no events"
    for ev in sink.events:
        assert validate_event(ev) == [], ev


def test_generate_sampled_calls_differ():
    """Regression: ``generate`` used to rebuild PRNGKey(seed) per call, so
    at temperature>0 every batch sampled IDENTICAL tokens.  Successive
    calls must draw from distinct streams (while greedy stays
    deterministic, covered above)."""
    cfg = CASES["dense"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, temperature=1.0,
                                               seed=3))
    prompts = np.random.RandomState(0).randint(0, 97, (3, 10)).astype(np.int32)
    g1 = eng.generate(prompts, 12)
    g2 = eng.generate(prompts, 12)
    assert not np.array_equal(g1, g2), \
        "two sampled generations returned identical tokens"
    # and the whole engine stays reproducible from a fresh instance
    eng2 = ServeEngine(cfg, params, ServeConfig(max_len=64, temperature=1.0,
                                                seed=3))
    np.testing.assert_array_equal(g1, eng2.generate(prompts, 12))


def test_latency_histogram_bin_edges():
    """Boundary semantics of the pre-binned latency histogram: an exact
    edge value lands in the bin to its RIGHT (bisect), and anything past
    10 s lands in the overflow bin."""
    from bisect import bisect
    from repro.serve.engine import LATENCY_BIN_EDGES_MS, N_LATENCY_BINS

    assert N_LATENCY_BINS == len(LATENCY_BIN_EDGES_MS) + 1
    assert bisect(LATENCY_BIN_EDGES_MS, 0.5) == 0
    for i, edge in enumerate(LATENCY_BIN_EDGES_MS):
        assert bisect(LATENCY_BIN_EDGES_MS, edge) == i + 1        # on-edge
        assert bisect(LATENCY_BIN_EDGES_MS, edge - 1e-9) == i     # below
    assert bisect(LATENCY_BIN_EDGES_MS, 10_000.0) == N_LATENCY_BINS - 1
    assert bisect(LATENCY_BIN_EDGES_MS, 3_600_000.0) == N_LATENCY_BINS - 1

    # drive the engine's binning directly: a fake 2 ms and a fake 2 h
    # request land in bin 1 and the overflow bin
    from repro.telemetry import MetricRegistry
    cfg = CASES["dense"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    reg = MetricRegistry()
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64), registry=reg)
    eng._observe_request(1, 10, 0.002)
    eng._observe_request(2, 10, 7200.0)
    counts = np.asarray(reg.metrics()["serve/latency_ms"])
    assert counts[1] == 1 and counts[N_LATENCY_BINS - 1] == 2
    assert counts.sum() == 3


def test_scheduler_telemetry_schema_valid(tmp_path):
    """Scheduler counters/gauges (occupancy, evictions, kv bytes/token,
    tokens/s) flush as schema-valid JSONL (§14 x §17)."""
    from repro.serve.kvcache import PagedKVConfig
    from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                       SchedulerConfig)
    from repro.telemetry import JsonlSink, MetricRegistry, validate_jsonl

    cfg = CASES["dense"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    reg = MetricRegistry()
    out = tmp_path / "serve_metrics.jsonl"
    reg.add_sink(JsonlSink(str(out)))
    kv = PagedKVConfig(page_size=4, n_pages=6, n_slots=2,
                       max_pages_per_seq=3)
    eng = ContinuousBatchingEngine(cfg, params, SchedulerConfig(kv=kv),
                                   registry=reg)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=tuple(rng.randint(0, 97, 5).tolist()),
                    max_new_tokens=6) for i in range(4)]
    eng.serve(reqs)

    m = reg.metrics()
    assert m["serve/sched/admitted"] >= 4
    assert m["serve/sched/completed"] == 4
    assert m["serve/requests"] == 4
    assert m["serve/generated_tokens"] == 24
    assert 0.0 <= m["serve/sched/slot_occupancy"] <= 1.0
    assert m["serve/sched/page_occupancy"] == 0.0   # all released at end
    assert m["serve/tokens_per_s"] > 0.0
    assert m["serve/kv_bytes_per_token"] > 0.0
    counts = np.asarray(m["serve/latency_ms"])
    assert counts.sum() == 4
    reg.flush(step=1)
    events, errors = validate_jsonl(str(out))
    assert events, "flush emitted no events"
    assert errors == [], errors
    names = {ev["name"] for ev in events}
    for required in ("serve/sched/admitted", "serve/sched/completed",
                     "serve/sched/slot_occupancy",
                     "serve/sched/page_occupancy", "serve/tokens_per_s",
                     "serve/kv_bytes_per_token", "serve/latency_ms"):
        assert required in names, (required, names)


def test_long_context_decode_small():
    """xlstm-style O(1) state: decode far past any attention window."""
    cfg = CASES["xlstm"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, batch=1, max_len=16)
    tok = jnp.zeros((1, 1), jnp.int32)
    for t in range(40):    # decode 40 tokens with max_len=16 cache structs
        lg, cache = M.decode_step(cfg, params, tok, cache, t)
    assert not bool(jnp.isnan(lg).any())
