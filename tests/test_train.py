"""End-to-end training behaviour: loss decreases toward the entropy floor;
8-bit Adam tracks 32-bit Adam (the paper's core claim at test scale);
grad accumulation is batch-equivalent; ablations rank as in Table 3."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.core.optim import make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.train import loop as L


def _setup(vocab=128, seq=32, batch=8, **cfg_kw):
    cfg = base.reduced(base.get_config("paper-lm-209m"), d_model=64,
                       n_layers=2, vocab_size=vocab, **cfg_kw)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=vocab, seq_len=seq,
                                          global_batch=batch))
    return cfg, pipe


def _train(cfg, pipe, opt_name, steps, hyper=None, **opt_kw):
    opt = make_optimizer(opt_name, lr=5e-3, min_8bit_size=1024, **opt_kw)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(L.make_train_step(cfg, opt, hyper or L.TrainHyper()))
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases_toward_floor():
    cfg, pipe = _setup()
    losses = _train(cfg, pipe, "adam32", 50)
    assert losses[-1] < losses[0] * 0.7
    assert losses[-1] > pipe.bigram_entropy() * 0.5   # can't beat the floor


def test_8bit_tracks_32bit():
    """Paper Table 1/3: 8-bit Adam matches 32-bit Adam."""
    cfg, pipe = _setup()
    l32 = _train(cfg, pipe, "adam32", 50)
    l8 = _train(cfg, pipe, "adam8", 50)
    assert abs(l8[-1] - l32[-1]) < 0.05 * l32[-1] + 0.05


def test_grad_accumulation_equivalent():
    cfg, pipe = _setup(batch=8)
    l1 = _train(cfg, pipe, "adam32", 10, hyper=L.TrainHyper(microbatches=1))
    l4 = _train(cfg, pipe, "adam32", 10, hyper=L.TrainHyper(microbatches=4))
    np.testing.assert_allclose(l1, l4, rtol=2e-3, atol=2e-3)


def test_linear_quantization_is_worse():
    """Table 3 ordering: linear-quantized 8-bit Adam is worse/less stable
    than dynamic-quantized 8-bit Adam."""
    cfg, pipe = _setup()
    l_dyn = _train(cfg, pipe, "adam8", 60)
    l_lin = _train(cfg, pipe, "adam8", 60, qmap_m="linear", qmap_r="linear")
    assert l_dyn[-1] <= l_lin[-1] + 0.02


def test_lr_schedule_applied():
    cfg, pipe = _setup()
    sched = L.warmup_cosine(5e-3, warmup=5, total=20)
    losses = _train(cfg, pipe, "adam32", 10,
                    hyper=L.TrainHyper(lr_schedule=sched))
    assert all(np.isfinite(losses))


def test_grad_clip_engages():
    cfg, pipe = _setup()
    opt = make_optimizer("adam32", lr=5e-3)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(L.make_train_step(cfg, opt, L.TrainHyper(grad_clip=1e-6)))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    _, m = step(state, batch)
    assert float(m["grad_norm"]) > 1e-6    # reported norm is pre-clip


@pytest.mark.parametrize("opt_name,expected", [
    ("adam8", 2 * (1 + 4 / 2048)),   # two 8-bit states + amortized absmax
    ("adafactor32", None),           # factored baseline: > 4 B/param (m) only
])
def test_state_bytes_per_param_metric_emitted(opt_name, expected):
    """The measured state_bytes_per_param metric is the paper's Table 1
    comparison; it must be emitted by BOTH engines — the quantized one and
    the 32-bit memory-efficient Adafactor baseline (whose state_bytes used
    to omit n_params, silently dropping the metric)."""
    cfg, pipe = _setup()
    opt = make_optimizer(opt_name, lr=5e-3, min_8bit_size=1024)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(L.make_train_step(cfg, opt))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    _, m = step(state, batch)
    assert "state_bytes_per_param" in m, opt_name
    if opt_name == "adam8":
        # pooled dispatch: the whole quantized tree is ONE fused launch
        assert float(m["opt_fused_dispatches"]) == 1.0
    bpp = float(m["state_bytes_per_param"])
    if expected is not None:
        # mixed 8-bit/32-bit tree: quantized leaves sit at `expected`,
        # overrides above it — the measured value must be in between
        assert expected * 0.9 < bpp < 8.0
    else:
        # Adafactor: full first moment (4 B) + factored second moment
        assert 4.0 < bpp < 4.5


def test_vlm_embeds_path_trains():
    cfg, pipe = _setup()
    import dataclasses
    cfg = dataclasses.replace(cfg, frontend="vision", frontend_tokens=4)
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024)
    # rebuild: frontend needs params
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(L.make_train_step(cfg, opt))
    b = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    b["embeds"] = jnp.ones((8, 4, cfg.d_model)) * 0.1
    _, m = step(state, b)
    assert bool(jnp.isfinite(m["loss"]))
