"""Muon matrix-optimizer subsystem (DESIGN.md §11).

Contracts under test:
  * Newton–Schulz kernel parity: Pallas-interpret and jnp NS(5) are
    bit-exact, and the result approximately orthogonalizes.
  * The ("muon", impl) fused-update registry entries are bit-exact across
    impls, incl. stochastic rounding and packed k-bit momentum.
  * Per-leaf routing on a mixed model (2-D, 1-D, sub-min_quantized_size
    leaves): matrix leaves get one-state quantized momentum, element-wise
    leaves fall through to the fused adamw path incl. the pooled arena.
  * pooled == per-leaf, bitwise, and elastic checkpoint interchange on the
    2-device conftest mesh.
  * Quantized Muon trains within 5% of the fp32-Muon loss (smoke task).
  * make_optimizer accepts config objects as the single entry point.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qmap
from repro.core.lowbit import PackedCodes
from repro.core.optim import (Adafactor, AdafactorConfig, Block8bitOptimizer,
                              Full32Leaf, MuonOptimizer, OptimConfig,
                              Pool32Leaf, PooledQuantLeaf, Quant8Leaf,
                              make_optimizer, unpool_state)
from repro.kernels import newton_schulz as ns
from repro.kernels import ops, ref
from repro.train import checkpoint as C

QS = jnp.asarray(qmap.get_qmap("dynamic", True))


# ----------------------------------------------------- Newton–Schulz kernel
@pytest.mark.parametrize("shape", [(48, 130), (130, 48), (8, 256), (33, 33)])
def test_newton_schulz_parity_interpret_jnp(shape):
    """Tiled Pallas NS(5) (interpret) == the tile-replaying jnp path,
    bit-for-bit — incl. non-tile-multiple shapes and the transpose path."""
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    oj = ns.newton_schulz(x, impl="jnp")
    oi = ns.newton_schulz(x, impl="interpret")
    np.testing.assert_array_equal(np.asarray(oj), np.asarray(oi))


def test_newton_schulz_orthogonalizes():
    """NS(5) with the Muon quintic drives the singular values into a band
    around 1 and lands near the polar factor UV^T."""
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 192))
    o = np.asarray(ns.newton_schulz(x, impl="jnp"), np.float64)
    s = np.linalg.svd(o, compute_uv=False)
    assert 0.3 < s.min() and s.max() < 1.4, (s.min(), s.max())
    u, _, vt = np.linalg.svd(np.asarray(x, np.float64), full_matrices=False)
    tgt = u @ vt
    cos = (o * tgt).sum() / (np.linalg.norm(o) * np.linalg.norm(tgt))
    assert cos > 0.95, cos


def test_newton_schulz_ref_is_jnp_path():
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 40))
    np.testing.assert_array_equal(
        np.asarray(ref.newton_schulz_ref(x)),
        np.asarray(ns.newton_schulz(x, impl="jnp")))


def test_rms_scale():
    assert ns.rms_scale((128, 64)) == pytest.approx(2 ** 0.5)
    assert ns.rms_scale((64, 128)) == 1.0


# ------------------------------------------------- fused muon registry entry
def _muon_inputs(shape, seed=0, bits=8):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    p = jax.random.normal(ks[0], shape)
    g = jax.random.normal(ks[1], shape) * 0.1
    n = shape[0] * shape[1]
    nb, bsz = -(-n // 256), 256
    qmap_m = jnp.asarray(qmap.get_qmap("dynamic", True, bits=bits))
    m0 = jnp.pad(jax.random.normal(ks[2], (n,)) * 0.01,
                 (0, nb * bsz - n)).reshape(nb, bsz)
    cm, am = ref.quantize_ref(m0, qmap_m)
    if bits < 8:
        cm = PackedCodes.from_codes(cm, bits)
    return p, g, cm, am, qmap_m


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("stochastic", [False, True])
def test_muon_fused_update_parity(bits, stochastic):
    """("muon", interpret) == ("muon", jnp) bit-for-bit: params, codes,
    absmax — incl. stochastic rounding and packed 4-bit momentum."""
    p, g, cm, am, qm = _muon_inputs((48, 66), bits=bits)
    kw = dict(lr=1e-2, beta1=0.95, weight_decay=0.01, gnorm_scale=0.7,
              stochastic=stochastic, seed=123)
    a = ops.fused_update("muon", p, g, cm, am, qmap_m=qm, impl="interpret",
                         **kw)
    b = ops.fused_update("muon", p, g, cm, am, qmap_m=qm, impl="jnp", **kw)
    for name, x1, x2 in zip(a._fields, a, b):
        if x1 is None:
            assert x2 is None, name
            continue
        if isinstance(x1, PackedCodes):
            assert x1.bits == bits == x2.bits
            x1, x2 = x1.packed, x2.packed
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2),
                                      err_msg=name)
    if stochastic:
        c = ops.fused_update("muon", p, g, cm, am, qmap_m=qm, impl="jnp",
                             **{**kw, "seed": 124})
        c1 = c.codes_m.packed if bits < 8 else c.codes_m
        b1 = b.codes_m.packed if bits < 8 else b.codes_m
        assert int((np.asarray(c1) != np.asarray(b1)).sum()) > 0


def test_muon_registered_all_impls():
    assert [("muon", i) for i in ("interpret", "jnp", "pallas")] == \
        ops.registered("muon")
    from repro.kernels import fused_update as kfu
    assert kfu.ALGO_SPECS["muon"].matrix
    assert kfu.ALGO_SPECS["muon"].n_states == 1


def test_muon_rejects_tensorwise():
    p, g, cm, am, qm = _muon_inputs((16, 16))
    with pytest.raises(NotImplementedError):
        ops.fused_update("muon", p, g, cm, am, qmap_m=qm, lr=1e-2,
                         blockwise=False, impl="jnp")
    with pytest.raises(ValueError):
        make_optimizer("muon8", blockwise_norm=False)


def test_base_engine_rejects_matrix_algo():
    """Constructing the element-wise engine directly with a matrix-class
    algo must fail loudly — it has no matrix routing, and the flat block
    arena is 2-D, so Newton–Schulz would silently orthogonalize it."""
    with pytest.raises(ValueError, match="matrix-class"):
        Block8bitOptimizer(OptimConfig(algo="muon", bits=8))


# ------------------------------------------------ mixed-class engine routing
def _params(key=0):
    """2-D (muon), 1-D quantized (adamw arena), sub-min (fp32 pool/leaf),
    and an embedding override (adamw fp32)."""
    k = jax.random.PRNGKey(key)
    ks = jax.random.split(k, 5)
    return {
        "dense": {"w": jax.random.normal(ks[0], (64, 128)),
                  "v": jax.random.normal(ks[1], (48, 64))},
        "vec": jax.random.normal(ks[2], (2048,)),
        "embed": {"w": jax.random.normal(ks[3], (128, 64))},
        "bias": jnp.zeros((10,)),
        "small2d": jax.random.normal(ks[4], (4, 4)) * 0.1,
    }


def _loss(p, target):
    return sum(jnp.sum((a - b) ** 2)
               for a, b in zip(jax.tree_util.tree_leaves(p),
                               jax.tree_util.tree_leaves(target)))


def _train(opt, params, steps=3):
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    grad = jax.jit(jax.grad(lambda p: _loss(p, target)))
    st = opt.init(params)
    p = params
    for _ in range(steps):
        p, st = opt.apply(grad(p), st)
    return p, st


def _assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def test_muon_routing_table():
    """The per-leaf routing split (DESIGN.md §11): 2-D leaves carry a
    single quantized momentum slot; element-wise leaves keep the existing
    adamw containers (pooled arena / fp32 pool / Full32 override)."""
    opt = make_optimizer("muon8", lr=1e-2, min_8bit_size=1024)
    assert isinstance(opt, MuonOptimizer)
    st = opt.init(_params())
    lv = st.leaves
    assert isinstance(lv["dense"]["w"], Quant8Leaf)       # matrix, per-leaf
    assert lv["dense"]["w"].codes_r is None               # one-state
    assert isinstance(lv["vec"], PooledQuantLeaf)         # ew -> arena
    assert st.arena is not None and st.arena.codes_r is not None  # adamw
    assert isinstance(lv["embed"]["w"], Full32Leaf)       # override
    assert lv["embed"]["w"].r is not None                 # ...adamw, 2-state
    assert isinstance(lv["bias"], Pool32Leaf)             # sub-min 1-D
    assert isinstance(lv["small2d"], Full32Leaf)          # sub-min 2-D
    assert lv["small2d"].r is None                        # ...fp32 muon
    # fp32-Muon baseline: every matrix leaf is a one-state Full32Leaf
    st32 = make_optimizer("muon32", lr=1e-2).init(_params())
    assert st32.leaves["dense"]["w"].r is None
    assert st32.leaves["vec"].r is not None


@pytest.mark.parametrize("state_bits", [None, (4, 8)])
def test_muon_pooled_matches_per_leaf_bit_exact(state_bits):
    """Pooled apply == per-leaf apply bitwise on the mixed model, incl.
    stochastic rounding and packed momentum (flatten-order seeds match)."""
    kw = dict(lr=1e-2, min_8bit_size=1024, stochastic_rounding=True)
    if state_bits:
        kw["state_bits"] = state_bits
    p_a, st_a = _train(make_optimizer("muon8", pooled=True, **kw), _params())
    p_b, st_b = _train(make_optimizer("muon8", pooled=False, **kw), _params())
    assert st_a.arena is not None and st_a.pool32 is not None
    _assert_trees_equal(p_a, p_b, "params")
    _assert_trees_equal(unpool_state(st_a).leaves, st_b.leaves, "state")


def test_muon_dispatch_count():
    """Pooled muon step = one fused arena launch (all ew leaves) + one NS
    launch per matrix leaf — the ew fallback still pools (DESIGN.md §11)."""
    params = _params()
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    grad = jax.grad(lambda p: _loss(p, target))(params)
    opt = make_optimizer("muon8", lr=1e-2, min_8bit_size=1024)
    st = opt.init(params)
    ops.reset_fused_update_count()
    jax.jit(lambda g, s: opt.apply(g, s)).lower(grad, st)   # trace only
    n_matrix = 2    # dense/w, dense/v
    assert ops.fused_update_count() == n_matrix + 1


def test_muon_state_bytes_one_state_momentum():
    """Measured memory: a quantized matrix leaf stores ~bits_m/8 bytes per
    param of statistics (single momentum slot), vs 2 slots for adamw."""
    p = {"w": jnp.zeros((512, 64))}     # 32768 elems, 16 blocks
    opt = make_optimizer("muon8", lr=1e-2, min_8bit_size=1,
                         override_32bit=lambda s: False)
    sb = opt.state_bytes(opt.init(p))
    n = 512 * 64
    assert sb["state_bytes"] == pytest.approx(n * (1 + 4 / 2048), rel=1e-6)
    opt4 = make_optimizer("muon8", lr=1e-2, min_8bit_size=1,
                          override_32bit=lambda s: False, state_bits=(4, 8))
    sb4 = opt4.state_bytes(opt4.init(p))
    assert sb4["state_bytes"] == pytest.approx(n * (0.5 + 4 / 2048),
                                               rel=1e-6)


# --------------------------------------------- checkpoint + sharding (mesh)
from helpers import mesh_of as _mesh_of  # noqa: E402  (shared sub-meshes)


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("state_bits", [None, (4, 8)])
def test_muon_checkpoint_interchange_on_mesh(tmp_path, state_bits, n_dev):
    """Save per-leaf muon -> restore pooled on {1,2,4}-device meshes (and
    the resumed step stays bit-exact vs the uninterrupted run), incl.
    packed momentum.  Matrix momentum leaves shard their block dim like
    every other quantized state."""
    from repro.sharding import rules
    mesh = _mesh_of(n_dev)
    kw = dict(lr=1e-2, min_8bit_size=256, override_32bit=lambda p: False,
              shard_multiple=n_dev, stochastic_rounding=True)
    if state_bits:
        kw["state_bits"] = state_bits
    params = {"w": jnp.ones((64, 64)), "v": jax.random.normal(
        jax.random.PRNGKey(0), (48, 32)), "b": jnp.zeros((8,)),
        "vec": jnp.ones((512,))}
    opt_pl = make_optimizer("muon8", pooled=False, **kw)
    opt_po = make_optimizer("muon8", pooled=True, **kw)
    _, st = _train(opt_pl, params, 3)
    d = str(tmp_path)
    C.save(d, 3, st)

    template = jax.eval_shape(lambda: opt_po.init(params))
    pshard = jax.tree_util.tree_map(
        lambda p: jax.sharding.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec()),
        params)
    shardings = rules.opt_state_shardings(template, pshard, mesh,
                                          rules.ShardingPolicy())
    # matrix momentum leaves: block dim over the mesh
    wshard = shardings.leaves["w"]
    got = wshard.codes_m.packed if state_bits else wshard.codes_m
    assert got.spec[0] == ("data",)
    st_po = C.restore(d, 3, template, shardings)
    _assert_trees_equal(unpool_state(st_po).leaves, st.leaves,
                        "restored pooled != saved per-leaf")
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    g = jax.jit(jax.grad(lambda p: _loss(p, target)))(opt_pl.params_view(st))
    _, st_a = opt_pl.apply(g, st)
    _, st_b = opt_po.apply(g, st_po)
    _assert_trees_equal(st_a.leaves, unpool_state(st_b).leaves,
                        "resumed step diverged")


# --------------------------------------------------------- smoke-task gate
def test_muon8_within_5pct_of_muon32_on_smoke_train_task():
    """Acceptance: quantized Muon converges within 5% of fp32-Muon loss on
    the smoke LM task (same seeds, same data)."""
    from benchmarks.common import small_lm, train_lm
    cfg, pipe = small_lm(vocab=128, d_model=64, seq=32, batch=8)
    l32, _, d32 = train_lm(cfg, pipe, "muon32", steps=25, lr=2e-2)
    l8, _, d8 = train_lm(cfg, pipe, "muon8", steps=25, lr=2e-2)
    assert not d32 and not d8
    assert abs(l8 - l32) / l32 < 0.05, (l8, l32)


# -------------------------------------------------- make_optimizer(config)
def test_make_optimizer_accepts_config_objects():
    """The single construction entry point dispatches on the config type /
    algo — Block8bit, Muon and Adafactor all construct through it."""
    assert isinstance(make_optimizer(OptimConfig(algo="adam", bits=8)),
                      Block8bitOptimizer)
    opt = make_optimizer(OptimConfig(algo="muon", bits=8), lr=3e-3)
    assert isinstance(opt, MuonOptimizer) and opt.cfg.lr == 3e-3
    assert isinstance(make_optimizer(AdafactorConfig(lr=1e-3)), Adafactor)
    # name path recurses through the config path (same defaults)
    assert isinstance(make_optimizer("muon32"), MuonOptimizer)
    with pytest.raises(ValueError, match="unknown optimizer"):
        make_optimizer("muon16")


def test_muon_train_step_metrics():
    """Muon rides the train loop unchanged: state_bytes_per_param and the
    dispatch-count metric come out of the jitted step."""
    from benchmarks.common import small_lm
    from repro.train import loop as L
    cfg, pipe = small_lm(vocab=128, d_model=64, seq=32, batch=8)
    opt = make_optimizer("muon8", lr=1e-3, min_8bit_size=1024)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(L.make_train_step(cfg, opt))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    _, m = step(state, batch)
    sb = opt.state_bytes(state.opt_state)
    assert float(m["state_bytes_per_param"]) == pytest.approx(
        sb["state_bytes"] / sb["n_params"], rel=1e-6)
    assert float(m["opt_fused_dispatches"]) >= 1
    assert np.isfinite(float(m["loss"]))
