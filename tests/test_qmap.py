"""Codebook (qmap) unit + property tests."""
import numpy as np
import pytest

from repro.core import qmap


@pytest.mark.parametrize("name", ["dynamic", "inverse_dynamic", "linear",
                                  "quantile_normal"])
@pytest.mark.parametrize("signed", [True, False])
def test_qmap_basic_properties(name, signed):
    m = qmap.get_qmap(name, signed)
    assert m.shape == (256,)
    assert m.dtype == np.float32
    assert np.all(np.diff(m) >= 0), "codebook must be sorted"
    assert m.max() == pytest.approx(1.0)
    if signed:
        assert m.min() < -0.5
    else:
        assert m.min() >= 0.0


def test_dynamic_signed_matches_reference_construction():
    """Structure of the bitsandbytes dynamic map: 7 exponent levels,
    2^i fraction values per level per sign, plus {0, 1.0}."""
    m = qmap.dynamic_map(signed=True)
    pos = m[m > 0]
    assert len(pos) == 128                       # 127 + the appended 1.0
    assert np.isclose(pos.min(), 0.55e-6)        # 10^-6 * mid(0.1, 1.0)
    assert pos.max() == 1.0
    neg = m[m < 0]
    assert len(neg) == 127
    # max-magnitude negative code is NOT -1 (reference asymmetry)
    assert np.isclose(neg.min(), -0.9929, atol=1e-3)
    assert (m == 0).sum() == 1
    # dynamic range ~7 orders of magnitude (paper §1.3)
    assert pos.max() / pos.min() > 1e6


def test_dynamic_unsigned_extra_fraction_bit():
    """Unsigned map re-purposes the sign bit: twice the fraction resolution
    per level (paper §2.2)."""
    u = qmap.dynamic_map(signed=False)
    s = qmap.dynamic_map(signed=True)
    assert (u >= 0).all()
    # unsigned has ~2x the codes in (0.1, 1.0) vs the signed positives
    u_top = ((u >= 0.1) & (u < 1.0)).sum()
    s_top = ((s >= 0.1) & (s < 1.0)).sum()
    assert u_top == 2 * s_top


def test_inverse_dynamic_precision_at_small_end():
    """Inverse map gives more resolution to small magnitudes (App F.1)."""
    inv = qmap.inverse_dynamic_map(signed=False)
    dyn = qmap.dynamic_map(signed=False)
    thr = 1e-4
    assert (inv[(inv > 0) & (inv < thr)].size
            > dyn[(dyn > 0) & (dyn < thr)].size)


def test_quantile_map_equal_mass():
    """Quantile map: standard-normal samples normalized by the map's own
    normalizer hit all codes roughly uniformly (minimum-entropy encoding,
    App F.2)."""
    m = qmap.normal_quantile_map(signed=True)
    k = 256
    qs = qmap._norm_ppf(np.linspace(1.0 / (k + 1), k / (k + 1), k + 1))
    norm_const = np.max(np.abs((qs[:-1] + qs[1:]) / 2.0))
    rng = np.random.RandomState(0)
    x = np.clip(rng.randn(200_000).astype(np.float32) / norm_const, -1, 1)
    bounds = qmap.boundaries(m)
    codes = np.searchsorted(bounds, x, side="right")
    counts = np.bincount(codes, minlength=256)
    mid = counts[8:-8]
    assert mid.min() > 0.3 * x.size / 256
    assert mid.max() < 3.0 * x.size / 256


def test_boundaries_are_nearest_neighbour():
    m = qmap.dynamic_map(signed=True)
    b = qmap.boundaries(m)
    rng = np.random.RandomState(1)
    x = rng.uniform(-1, 1, 1000).astype(np.float32)
    codes = np.searchsorted(b, x, side="right")
    brute = np.argmin(np.abs(m[None, :] - x[:, None]), axis=1)
    # ties can differ by one index with equal |error|
    err_fast = np.abs(m[codes] - x)
    err_brute = np.abs(m[brute] - x)
    assert np.allclose(err_fast, err_brute, atol=1e-7)
