"""Telemetry (DESIGN.md §14): typed registry, JSONL schema, qhealth
probes vs an oracle, step-phase tracing, and the zero-overhead guard.

The central contract: with telemetry off, the jitted train step lowers to
byte-identical StableHLO (so the goldens and every perf number are
untouched); with it on, the probes run as a separate jitted executable on
the host schedule and the recorded health matches an independent
numpy/jnp oracle exactly — including packed sub-byte codes and the
ZeRO-1 partitioned arena on a 4-device mesh."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import mesh_of, tiny_cfg, tiny_pipe
from repro import telemetry as tel
from repro.core.lowbit import unpack_codes, unwrap_codes
from repro.core.optim import make_optimizer
from repro.core.optim.base import Quant8Leaf
from repro.telemetry import tracing
from repro.telemetry.export import append_json_trajectory, validate_event
from repro.train import loop as L


# ------------------------------------------------------------- registry
def test_registry_typed_metrics_round_trip():
    reg = tel.MetricRegistry()
    sink = tel.InMemorySink()
    reg.add_sink(sink)
    assert reg.counter("serve/requests").inc(3) == 3
    assert reg.counter("serve/requests").inc() == 4      # get-or-create
    reg.gauge("train/loss").set(jnp.float32(2.5))        # jax scalar ok
    reg.histogram("q/util", n_bins=4).observe_counts([1, 0, 2, 7])
    reg.flush(step=5)
    assert reg.metrics() == {"serve/requests": 4, "train/loss": 2.5,
                             "q/util": [1, 0, 2, 7]}
    assert reg.get("train/loss") == 2.5
    assert reg.get("never/registered") is None
    evs = sink.events
    assert len(evs) == 3
    by_name = {e["name"]: e for e in evs}
    assert by_name["serve/requests"]["type"] == "counter"
    assert by_name["serve/requests"]["value"] == 4
    assert by_name["q/util"]["value"] == [1, 0, 2, 7]
    for e in evs:
        assert validate_event(e) == [], e
        assert e["step"] == 5


def test_registry_type_mismatch_raises():
    reg = tel.MetricRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    reg.histogram("h", n_bins=16)
    with pytest.raises(TypeError):
        reg.histogram("h", n_bins=256)     # bin-count mismatch
    with pytest.raises(TypeError):
        reg.counter("h")


def test_record_scalars_routes_gauges_and_skips_arrays():
    reg = tel.MetricRegistry()
    sink = tel.InMemorySink()
    reg.add_sink(sink)
    reg.record_scalars(3, {"loss": jnp.float32(1.5),
                           "grad_norm": np.float64(0.25),
                           "not_scalar": jnp.zeros((4,))}, prefix="train/")
    assert reg.get("train/loss") == 1.5
    assert reg.get("train/grad_norm") == 0.25
    assert reg.get("train/not_scalar") is None
    assert {e["name"] for e in sink.events} == {"train/loss",
                                                "train/grad_norm"}
    assert all(e["step"] == 3 and validate_event(e) == []
               for e in sink.events)


# ----------------------------------------------------------- JSONL schema
def test_jsonl_sink_and_schema_validation(tmp_path):
    path = str(tmp_path / "t.jsonl")
    reg = tel.MetricRegistry()
    reg.add_sink(tel.JsonlSink(path))
    reg.gauge("a").set(1.0)
    reg.flush(step=0)
    reg.emit_event({"kind": "phase", "step": 1, "phase": "step",
                    "wall_s": 0.01})
    reg.emit_event({"kind": "trace", "step": 1, "phases": []})
    reg.close()
    events, errors = tel.validate_jsonl(path)
    assert errors == []
    assert [e["kind"] for e in events] == ["metric", "phase", "trace"]
    assert all(e["schema"] == tel.SCHEMA for e in events)


def test_validate_event_rejects_malformed():
    assert validate_event("not a dict")
    assert validate_event({"kind": "nope"})
    # missing required fields + missing schema stamp
    errs = validate_event({"kind": "qhealth", "step": 1})
    assert any("missing field" in e for e in errs)
    assert any("schema" in e for e in errs)
    # bad metric type / non-int step
    assert validate_event({"kind": "metric", "schema": tel.SCHEMA,
                           "step": "x", "name": "a", "type": "timer",
                           "value": 1})
    # histogram value must be a list
    assert validate_event({"kind": "metric", "schema": tel.SCHEMA,
                           "step": 1, "name": "a", "type": "histogram",
                           "value": 3})


def test_validate_jsonl_flags_bad_lines(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"kind": "phase", "schema": tel.SCHEMA,
                            "step": 0, "phase": "x", "wall_s": 0.1}) + "\n")
        f.write("not json\n")
        f.write(json.dumps({"kind": "metric", "schema": tel.SCHEMA,
                            "step": 0}) + "\n")
    events, errors = tel.validate_jsonl(path)
    assert len(events) == 2
    assert any("not JSON" in e for e in errors)
    assert any("missing field" in e for e in errors)


def test_append_json_trajectory_dedupes(tmp_path):
    path = str(tmp_path / "B.json")
    append_json_trajectory(path, {"bench": "a", "git_sha": "s1", "v": 1},
                           dedupe_fields=("bench", "git_sha"))
    append_json_trajectory(path, {"bench": "a", "git_sha": "s1", "v": 2},
                           dedupe_fields=("bench", "git_sha"))
    append_json_trajectory(path, {"bench": "a", "git_sha": "s2", "v": 3},
                           dedupe_fields=("bench", "git_sha"))
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert [(e["git_sha"], e["v"]) for e in entries] == [("s1", 2),
                                                         ("s2", 3)]
    # corrupt file tolerated: starts a fresh trajectory
    with open(path, "w") as f:
        f.write("{broken")
    append_json_trajectory(path, {"bench": "a", "git_sha": "s1", "v": 9},
                           dedupe_fields=("bench", "git_sha"),
                           defaults={"tag": "d"})
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert entries == [{"bench": "a", "git_sha": "s1", "v": 9, "tag": "d"}]


def test_append_json_trajectory_stamps_unknown_git_sha(tmp_path):
    """Entries written without a resolvable git_sha (detached/missing
    checkout) are stamped "unknown" — git_sha is a dedupe key and must
    always be present (§16 satellite)."""
    path = str(tmp_path / "B.json")
    append_json_trajectory(path, {"bench": "a", "v": 1},
                           dedupe_fields=("bench",))
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert entries[0]["git_sha"] == "unknown"
    # an explicit sha is never clobbered
    append_json_trajectory(path, {"bench": "b", "git_sha": "cafe", "v": 2},
                           dedupe_fields=("bench",))
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert entries[1]["git_sha"] == "cafe"


def test_bench_json_sink_routes_events(tmp_path):
    path = str(tmp_path / "B.json")
    reg = tel.MetricRegistry()
    reg.add_sink(tel.BenchJsonSink(path, dedupe_fields=("name",),
                                   defaults={"git_sha": "deadbeef"}))
    reg.gauge("x").set(1.0)
    reg.flush(step=0)
    reg.gauge("x").set(2.0)
    reg.flush(step=1)
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert len(entries) == 1                 # deduped on name
    assert entries[0]["value"] == 2.0
    assert entries[0]["git_sha"] == "deadbeef"


# --------------------------------------------------- qhealth vs an oracle
def _oracle_events(opt, state):
    """Independent numpy recomputation of every arena qhealth field."""
    arena = state.arena
    out = {}
    for slot, codes, absmax, qmap in (
            ("m", arena.codes_m, arena.absmax_m, opt._qmap1),
            ("r", arena.codes_r, arena.absmax_r, opt._qmap2)):
        if codes is None:
            continue
        raw, rbits, _ = unwrap_codes(codes)
        bits = rbits if rbits is not None else 8
        c = np.asarray(unpack_codes(raw, bits)).astype(np.int64)
        q = np.abs(np.asarray(qmap))
        n_bins = q.shape[-1]
        is_edge = q[c] >= q.max()
        am = np.asarray(absmax)
        bsz = c.shape[1]
        for s in arena.segments:
            nvb = max(min(-(-s.n // bsz), s.n_blocks), 1)
            cs = c[s.offset:s.offset + nvb]
            es = is_edge[s.offset:s.offset + nvb]
            valid = (np.arange(nvb * bsz).reshape(nvb, bsz) < s.n)
            out[(s.path, slot)] = {
                "bits": bits, "n_bins": n_bins,
                "saturation_fraction": float(
                    np.sum(np.any(es & valid, axis=1)) / nvb),
                "edge_code_fraction": float(np.sum(es & valid)
                                            / np.sum(valid)),
                "util_hist": np.bincount(cs.reshape(-1)[valid.reshape(-1)],
                                         minlength=n_bins)[:n_bins],
                "absmax_mean": float(np.mean(am[s.offset:s.offset + nvb])),
            }
    return out


def _probe_map(events):
    return {(e["segment"], e["slot"]): e for e in events
            if e["target"] == "arena"}


def _check_probe_vs_oracle(opt, state, step=1):
    probe = tel.QHealthProbe(opt)
    got = _probe_map(probe.probe(state, step=step))
    want = _oracle_events(opt, state)
    assert set(got) == set(want)
    assert len(want) > 0
    for key, w in want.items():
        g = got[key]
        assert g["bits"] == w["bits"], key
        assert g["n_bins"] == w["n_bins"], key
        np.testing.assert_array_equal(np.asarray(g["util_hist"]),
                                      w["util_hist"], err_msg=str(key))
        np.testing.assert_allclose(g["saturation_fraction"],
                                   w["saturation_fraction"], rtol=1e-6)
        np.testing.assert_allclose(g["edge_code_fraction"],
                                   w["edge_code_fraction"], rtol=1e-6)
        np.testing.assert_allclose(g["absmax_mean"], w["absmax_mean"],
                                   rtol=1e-5)
        assert g["absmax_drift"] == 1.0      # first probe: EMA baseline
        assert g["util_fraction"] == pytest.approx(
            float(np.mean(w["util_hist"] > 0)))
    return got


def _arena_opt(**kw):
    return make_optimizer("adam8", lr=1e-2, min_8bit_size=256,
                          override_32bit=lambda p: False, **kw)


def _params():
    key = jax.random.PRNGKey(7)
    return {"a": jax.random.normal(key, (3000,)),          # padded tail
            "b": jax.random.normal(jax.random.fold_in(key, 1), (64, 48))}


def test_qhealth_probe_matches_oracle_8bit():
    opt = _arena_opt()
    state = opt.init(_params())
    _, state = opt.apply(jax.tree_util.tree_map(lambda p: p * 0.01,
                                                _params()), state)
    got = _check_probe_vs_oracle(opt, state)
    # padding is masked: histogram counts == live elements, not capacity
    for (path, slot), e in got.items():
        n = {"a": 3000, "b": 64 * 48}[path]
        assert sum(e["util_hist"]) == n, (path, slot)
        # masters-backed m slot carries the sampled round-trip error
        if slot == "m":
            assert 0.0 < e["rms_error"] < 0.2, e["rms_error"]
            assert e["rms_sample_blocks"] >= 1


def test_qhealth_probe_matches_oracle_packed_4bit():
    opt = _arena_opt(state_bits=(4, 8))
    state = opt.init(_params())
    _, state = opt.apply(jax.tree_util.tree_map(lambda p: p * 0.01,
                                                _params()), state)
    got = _check_probe_vs_oracle(opt, state)
    bins = {e["slot"]: e["n_bins"] for e in got.values()}
    assert bins == {"m": 16, "r": 256}       # 2^bits bins per slot


def test_qhealth_probe_partitioned_matches_unpartitioned():
    """ZeRO-1 partitioned state probes to the same health numbers as the
    unpartitioned oracle run (the probe replicates the arena through the
    §12 reduction-order mechanism; shard_multiple padding is excluded by
    the live-block masks)."""
    mesh = mesh_of(4)
    params = _params()
    grads = jax.tree_util.tree_map(lambda p: p * 0.01, params)

    opt_u = _arena_opt()
    st_u = opt_u.init(params)
    _, st_u = opt_u.apply(grads, st_u)
    base = _probe_map(tel.QHealthProbe(opt_u).probe(st_u, step=1))

    opt_p = _arena_opt(mesh=mesh, partition=True, partition_shards=4)
    st_p = opt_p.init(params)
    _, st_p = opt_p.apply(grads, st_p)
    part = _probe_map(tel.QHealthProbe(opt_p, mesh=mesh).probe(st_p,
                                                               step=1))

    assert set(base) == set(part)
    for key in base:
        for f in ("saturation_fraction", "edge_code_fraction",
                  "absmax_mean", "util_fraction"):
            np.testing.assert_allclose(part[key][f], base[key][f],
                                       rtol=1e-6, err_msg=f"{key} {f}")
        np.testing.assert_array_equal(part[key]["util_hist"],
                                      base[key]["util_hist"],
                                      err_msg=str(key))


def test_qhealth_probe_muon_leaf_events():
    """Muon matrix leaves live per-leaf (Quant8Leaf): the probe must emit
    target="leaf" events for them with the m-slot round-trip error, plus
    arena events for the pooled element-wise leaves."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (32, 64)),
              "v": jax.random.normal(jax.random.fold_in(key, 1), (1024,))}
    opt = make_optimizer("muon8", lr=1e-2, min_8bit_size=256,
                         override_32bit=lambda p: False)
    state = opt.init(params)
    _, state = opt.apply(jax.tree_util.tree_map(lambda p: p * 0.01, params),
                         state)
    assert any(isinstance(l, Quant8Leaf)
               for l in jax.tree_util.tree_leaves(
                   state.leaves,
                   is_leaf=lambda x: isinstance(x, Quant8Leaf)))
    events = tel.QHealthProbe(opt).probe(state, step=0)
    leaf = [e for e in events if e["target"] == "leaf"]
    assert {e["segment"] for e in leaf} == {"w"}
    assert {e["slot"] for e in leaf} == {"m"}    # single-moment muon
    assert all(len(e["util_hist"]) == 256 for e in leaf)
    assert all(sum(e["util_hist"]) == 32 * 64 for e in leaf)
    assert all("rms_error" in e for e in leaf)
    arena = [e for e in events if e["target"] == "arena"]
    assert {e["segment"] for e in arena} == {"v"}
    for e in events:
        assert validate_event({**e, "schema": tel.SCHEMA}) == [], e


def test_qhealth_drift_ema():
    probe = tel.QHealthProbe(_arena_opt(), ema_decay=0.5)
    key = ("arena", "x", "m")
    assert probe._drift(key, 2.0) == 1.0          # first probe: baseline
    assert probe._drift(key, 4.0) == pytest.approx(2.0)   # 4.0 / ema(2.0)
    # ema after the 2nd read: 0.5*2 + 0.5*4 = 3
    assert probe._drift(key, 3.0) == pytest.approx(1.0)


# ------------------------------------------------- zero-overhead guard
def test_telemetry_off_step_lowers_byte_identical():
    """telemetry_every is host-schedule only: configs 0 vs 2 lower the
    jitted train step to the SAME StableHLO, with the same donation
    aliasing — the §14 zero-overhead contract (pattern: the §13c
    donation_aliases audit)."""
    from repro.analysis import contracts

    cfg = tiny_cfg()
    pipe = tiny_pipe(vocab_size=cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    texts, aliases = {}, []
    for every in (0, 2):
        opt = make_optimizer("adam8", lr=5e-3, min_8bit_size=1024,
                             telemetry_every=every)
        state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        lowered = L.jit_train_step(cfg, opt).lower(state, batch)
        texts[every] = lowered.as_text()
        aliases.append(L.donation_aliases(lowered))
    # the §14 guard is now the lowering_invariant contract (DESIGN.md §15)
    ok, detail = contracts.lowering_invariant(texts)
    assert ok, detail
    assert "tel." not in texts[0]        # annotations are literal no-ops
    assert aliases[0] == aliases[1] > 0


def test_phase_tracing_scopes_and_bit_identical_loss():
    """With tracing enabled at trace time the compiled step carries the
    tel.* scopes and the trace events record the fused dispatches — and
    the computed values are bit-identical to the untraced step."""
    cfg = tiny_cfg()
    pipe = tiny_pipe(vocab_size=cfg.vocab_size)

    def run(trace):
        opt = make_optimizer("adam8", lr=5e-3, min_8bit_size=1024)
        state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        with tracing.phase_tracing(trace):
            tracing.reset_trace_events()
            step = L.jit_train_step(cfg, opt)
            losses = []
            for i in range(2):
                batch = {k: jnp.asarray(v)
                         for k, v in pipe.batch_at(i).items()}
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
            evs = tracing.trace_events()
            compiled = L.jit_train_step(cfg, opt, donate=False).lower(
                state, batch).compile()
        return losses, evs, compiled.as_text()

    losses_off, evs_off, text_off = run(False)
    losses_on, evs_on, text_on = run(True)
    assert losses_on == losses_off            # scopes never change values
    assert evs_off == []
    # named scopes ride op metadata: visible in the compiled HLO only
    assert "tel." not in text_off
    assert "tel." in text_on
    phases = {e["phase"] for e in evs_on}
    assert "forward_backward" in phases
    assert "optimizer_update" in phases
    assert any(p.startswith("fused_update.") for p in phases)
    # dispatch accounting rides the trace events (DESIGN.md §10)
    assert sum(e["dispatches"] for e in evs_on
               if e["phase"] == "optimizer_update") >= 1
    ev = tracing.trace_event_dict(0)
    assert ev["kind"] == "trace" and isinstance(ev["phases"], list)


def test_annotate_noop_when_disabled():
    tracing.reset_trace_events()
    with tracing.annotate("x"):
        pass
    assert tracing.trace_events() == []
    with tracing.phase_tracing(True):
        tracing.reset_trace_events()
        with tracing.annotate("x"):
            pass
        evs = tracing.trace_events()
    assert [e["phase"] for e in evs] == ["x"]
    assert evs[0]["dispatches"] == 0
    tracing.reset_trace_events()


def test_host_phase_timeline():
    with tracing.host_phase("probe", step=3):
        pass
    evs = tracing.drain_phase_events()
    assert len(evs) == 1
    assert evs[0]["kind"] == "phase" and evs[0]["phase"] == "probe"
    assert evs[0]["step"] == 3 and evs[0]["wall_s"] >= 0.0
    assert tracing.drain_phase_events() == []     # drained


# ------------------------------------------------------------ StepTimer
def test_step_timer_compile_split_and_straggler():
    t = tracing.StepTimer(window=5, z_threshold=3.0)
    t.record(10.0)                    # compile step
    assert t.compile_s == 10.0
    assert np.isnan(t.steady_ms())    # no steady samples yet
    # jittered steady steps, like a real clock (the exactly-constant
    # window is pinned separately by the zero-variance regression test)
    steady = [0.1, 0.11, 0.09, 0.1, 0.105, 0.095, 0.1, 0.11]
    for dt in steady:
        t.record(dt)
    assert t.steady_ms() == pytest.approx(1e3 * np.mean(steady))
    assert not t.is_straggler
    t.record(5.0)                     # ~50x the window: straggler
    assert t.is_straggler and t.straggler_z > 3.0
    assert t.compile_s == 10.0        # unchanged by steady steps
    s = t.summary()
    assert s["compile_s"] == 10.0 and s["n_steps"] == 10


def test_step_timer_zero_variance_window_scores_zero():
    """A zero-variance trailing window has no scale to judge deviation
    against: the z-score must be 0.0 ("no evidence"), not the inf/NaN an
    epsilon divide produced (§16 satellite regression)."""
    t = tracing.StepTimer(window=5, z_threshold=3.0)
    t.record(1.0)                     # compile step
    for _ in range(8):
        t.record(0.1)                 # bit-identical steps: std == 0
    t.record(50.0)                    # 500x jump, but no variance baseline
    assert t.straggler_z == 0.0
    assert np.isfinite(t.straggler_z)
    assert not t.is_straggler


def test_step_timer_context_manager():
    t = tracing.StepTimer()
    with t.step():
        pass
    with t.step():
        pass
    assert t.compile_s is not None and len(t.times) == 1


# -------------------------------------------------------- serve counters
def test_serve_engine_counters():
    from repro.configs.base import ModelConfig
    from repro.models import model as M
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=97,
                      head_dim=8, compute_dtype="float32", remat="none",
                      attn_chunk=16)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    reg = tel.MetricRegistry()
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64), registry=reg)
    prompts = np.ones((3, 4), np.int32)
    eng.generate(prompts, max_new_tokens=5)
    eng.generate(prompts, max_new_tokens=0)   # counted as a request too
    assert reg.get("serve/requests") == 6
    assert reg.get("serve/prompt_tokens") == 2 * 3 * 4
    assert reg.get("serve/generated_tokens") == 3 * 5
    # no registry -> no counters, no crash
    eng2 = ServeEngine(cfg, params, ServeConfig(max_len=64))
    eng2.generate(prompts, max_new_tokens=1)
