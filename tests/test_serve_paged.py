"""Differential suite for the paged quantized KV serving stack (§17).

The lock: 8-bit paged-KV greedy decode is TOKEN-EXACT against the fp32
contiguous-cache oracle across a parameterized matrix (page sizes, odd
prompt lengths, page-boundary-straddling decodes, scrambled physical
page order, SWA/hybrid architectures), 4-bit holds a bounded logit
drift, and the page-table bookkeeping (allocate/extend/evict/free) obeys
its invariants under random schedules — hypothesis when available, a
seeded sweep of the same property otherwise (never skipped).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.errors import ConfigError, FormatError
from repro.kernels import paged_kv
from repro.models import layers as L
from repro.models import model as M
from repro.serve.kvcache import (PageAllocator, PagedKVCache, PagedKVConfig,
                                 kv_bytes_per_token)
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   SchedulerConfig)


def _mk(**kw):
    d = dict(arch_id="t", family="dense", n_layers=2, d_model=32, n_heads=4,
             n_kv_heads=2, d_ff=64, vocab_size=97, head_dim=8,
             compute_dtype="float32", remat="none", attn_chunk=16)
    d.update(kw)
    return ModelConfig(**d)


ARCHS = {
    "dense": _mk(),
    "swa_ring": _mk(attn_type="swa", window=8),
    "hybrid_rglru": _mk(n_layers=6, block_pattern=("rglru", "attn"),
                        lru_width=32, attn_type="swa", window=8),
}


@pytest.fixture(scope="module")
def models():
    return {name: (cfg,) + M.init_model(cfg, jax.random.PRNGKey(0))[:1]
            for name, cfg in ARCHS.items()}


def _oracle_greedy(cfg, params, prompt, n_new):
    """fp32 contiguous-cache reference: greedy tokens + per-step logits."""
    P = len(prompt)
    logits, cache = M.prefill(cfg, params,
                              jnp.asarray(np.asarray(prompt)[None]),
                              max_len=P + n_new)
    toks, rows = [int(np.argmax(np.asarray(logits[0, -1])))], \
        [np.asarray(logits[0, -1])]
    for i in range(n_new - 1):
        lg, cache = M.decode_step(cfg, params,
                                  jnp.asarray([[toks[-1]]], jnp.int32),
                                  cache, P + i)
        toks.append(int(np.argmax(np.asarray(lg[0, 0]))))
        rows.append(np.asarray(lg[0, 0]))
    return np.asarray(toks, np.int32), np.stack(rows)


def _paged_greedy(cfg, params, prompt, n_new, page_size, kv_bits,
                  scramble=False, teacher_tokens=None, impl="jnp"):
    """Single-slot paged decode: prefill-commit then n_new paged steps.

    ``scramble`` permutes the physical page order (the table, not the
    data) so logical/physical page mapping is actually exercised.
    ``teacher_tokens`` forces the input tokens (for 4-bit logit-drift
    measurement on the oracle's trajectory)."""
    P = len(prompt)
    total = P + n_new
    n_pages = -(-total // page_size) + 2
    table = np.full((1, -(-total // page_size)), -1, np.int32)
    order = np.arange(n_pages, dtype=np.int32)
    if scramble:
        order = np.random.RandomState(7).permutation(n_pages).astype(
            np.int32)
    table[0, :] = order[:table.shape[1]]
    caches = M.init_paged_cache(cfg, 1, n_pages, page_size, kv_bits)
    cfg16 = dataclasses.replace(cfg, kv_cache_bits=16)
    logits, dense = M.prefill(cfg16, params,
                              jnp.asarray(np.asarray(prompt)[None]),
                              max_len=P)
    caches = M.commit_prefill_to_paged(cfg, caches, dense, 0,
                                       jnp.asarray(table[0]), P,
                                       kv_bits=kv_bits)
    toks = [int(np.argmax(np.asarray(logits[0, -1])))]
    rows = [np.asarray(logits[0, -1])]
    for i in range(n_new - 1):
        paged = L.PagedContext(jnp.asarray(table),
                               jnp.asarray([P + i], np.int32), impl=impl)
        feed = toks[-1] if teacher_tokens is None else \
            int(teacher_tokens[i])
        lg, caches = M.paged_decode_step(cfg, params,
                                         jnp.asarray([[feed]], jnp.int32),
                                         caches, paged)
        toks.append(int(np.argmax(np.asarray(lg[0, 0]))))
        rows.append(np.asarray(lg[0, 0]))
    return np.asarray(toks, np.int32), np.stack(rows)


# ------------------------------------------------ row quantizer + kernels

def test_rows_roundtrip_and_packing():
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (5, 3, 16)))
    for bits, tol in ((8, 0.02), (4, 0.2)):
        codes, absmax = paged_kv.quantize_rows(jnp.asarray(x), bits)
        assert codes.shape == (5, 3, 16 * bits // 8)
        back = np.asarray(paged_kv.dequantize_rows(codes, absmax,
                                                   jnp.float32, bits))
        rel = np.abs(back - x).max() / np.abs(x).max()
        assert rel < tol, (bits, rel)
    with pytest.raises(FormatError):
        paged_kv.packed_row_width(16, 3)
    with pytest.raises(FormatError):
        paged_kv.bits_of(16, 5)
    assert paged_kv.bits_of(16, 16) == 8 and paged_kv.bits_of(16, 8) == 4


@pytest.mark.parametrize("bits", [8, 4])
def test_gather_pallas_interpret_matches_jnp(bits):
    """The Pallas gather-dequant kernel (scalar-prefetched page table) is
    bit-exact against the XLA oracle, scrambled table included."""
    key = jax.random.PRNGKey(2)
    n_pages, page, KV, Dh = 6, 4, 2, 8
    rows = jax.random.normal(key, (n_pages, page, KV, Dh))
    codes, absmax = paged_kv.quantize_rows(rows, bits)
    table = jnp.asarray([[3, 0, 5], [1, 4, 2]], jnp.int32)
    a = paged_kv.gather_pages(codes, absmax, table, bits=bits, impl="jnp")
    b = paged_kv.gather_pages(codes, absmax, table, bits=bits,
                              impl="interpret")
    assert float(jnp.abs(a - b).max()) == 0.0


def test_append_drops_inactive_slot_sentinel():
    """An out-of-range page id (the scheduler's inactive-slot sentinel)
    must be DROPPED by the append scatter — never clamped onto a live
    page."""
    codes = jnp.zeros((2, 4, 2, 8), jnp.uint8)
    absmax = jnp.zeros((2, 4, 2), jnp.float32)
    rows = jnp.ones((1, 2, 8), jnp.float32)
    c2, a2 = paged_kv.append_rows(codes, absmax, rows,
                                  jnp.asarray([2], jnp.int32),
                                  jnp.asarray([0], jnp.int32), bits=8)
    assert int(jnp.sum(c2)) == 0 and float(jnp.sum(a2)) == 0.0
    c3, a3 = paged_kv.append_rows(codes, absmax, rows,
                                  jnp.asarray([1], jnp.int32),
                                  jnp.asarray([3], jnp.int32), bits=8)
    assert float(a3[1, 3, 0]) == 1.0 and float(jnp.sum(a3[0])) == 0.0


# -------------------------------------------------- differential matrix

# (arch, page_size, prompt_len, n_new): odd prompts, pages from 2 to
# larger-than-prompt, and decode runs that straddle several page
# boundaries; scrambled physical order everywhere
MATRIX = [
    ("dense", 2, 5, 9),
    ("dense", 4, 7, 9),
    ("dense", 8, 3, 13),
    ("dense", 16, 7, 6),       # page larger than prompt
    ("swa_ring", 4, 7, 9),     # window smaller than the sequence
    ("swa_ring", 8, 11, 7),
    ("hybrid_rglru", 4, 7, 9),  # recurrent slot state + paged attn
]


@pytest.mark.parametrize("arch,page,P,n_new", MATRIX)
def test_paged8_greedy_token_exact(models, arch, page, P, n_new):
    cfg = ARCHS[arch]
    params = models[arch][1]
    prompt = np.random.RandomState(P * page).randint(
        0, cfg.vocab_size, P).astype(np.int32)
    exp, _ = _oracle_greedy(cfg, params, prompt, n_new)
    got, _ = _paged_greedy(cfg, params, prompt, n_new, page, 8,
                           scramble=True)
    np.testing.assert_array_equal(exp, got)


@pytest.mark.parametrize("arch,page,P,n_new", MATRIX[:4])
def test_paged4_logit_drift_bounded(models, arch, page, P, n_new):
    """4-bit KV: teacher-forced on the oracle trajectory, per-step logit
    drift stays bounded (the 16-level codebook loses tokens-exactness but
    not calibration)."""
    cfg = ARCHS[arch]
    params = models[arch][1]
    prompt = np.random.RandomState(P * page).randint(
        0, cfg.vocab_size, P).astype(np.int32)
    toks, rows = _oracle_greedy(cfg, params, prompt, n_new)
    _, rows4 = _paged_greedy(cfg, params, prompt, n_new, page, 4,
                             scramble=True, teacher_tokens=toks[:-1])
    drift = np.abs(rows4 - rows).max()
    spread = rows.max() - rows.min()
    assert drift < 0.15 * spread, (drift, spread)
    # 8-bit on the same trajectory must be an order of magnitude tighter
    _, rows8 = _paged_greedy(cfg, params, prompt, n_new, page, 8,
                             scramble=True, teacher_tokens=toks[:-1])
    assert np.abs(rows8 - rows).max() < 0.2 * drift


def test_paged8_pallas_impl_token_exact(models):
    """The Pallas-interpret gather inside the full decode returns the
    same tokens as the XLA path."""
    cfg = ARCHS["dense"]
    params = models["dense"][1]
    prompt = np.random.RandomState(0).randint(0, 97, 7).astype(np.int32)
    a, _ = _paged_greedy(cfg, params, prompt, 8, 4, 8, scramble=True)
    b, _ = _paged_greedy(cfg, params, prompt, 8, 4, 8, scramble=True,
                         impl="interpret")
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------ engine-level parity

def test_scheduler_greedy_matches_oracle(models):
    """Mixed-length continuous batching, 8-bit pages: every request's
    greedy completion is token-exact vs the fp32 oracle."""
    cfg = ARCHS["dense"]
    params = models["dense"][1]
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i, prompt=tuple(rng.randint(0, 97, p).tolist()),
                    max_new_tokens=n)
            for i, (p, n) in enumerate([(7, 9), (12, 4), (3, 12), (10, 1),
                                        (5, 6), (9, 8)])]
    kv = PagedKVConfig(page_size=4, n_pages=24, n_slots=3,
                       max_pages_per_seq=8, kv_bits=8)
    eng = ContinuousBatchingEngine(cfg, params, SchedulerConfig(kv=kv))
    out = eng.serve(reqs)
    for r in reqs:
        exp, _ = _oracle_greedy(cfg, params, np.asarray(r.prompt),
                                r.max_new_tokens)
        np.testing.assert_array_equal(exp, out[r.rid], err_msg=f"rid {r.rid}")
    eng.kv.check_invariants()
    assert eng.kv.n_active == 0 and eng.kv.alloc.n_free == kv.n_pages


def test_scheduler_eviction_is_token_invariant(models):
    """A pool too small for the working set forces LIFO preemption; the
    restart-safe sampling contract makes the output IDENTICAL to the
    big-pool run — scheduling must never change tokens."""
    cfg = ARCHS["dense"]
    params = models["dense"][1]
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i, prompt=tuple(rng.randint(0, 97, p).tolist()),
                    max_new_tokens=n)
            for i, (p, n) in enumerate([(7, 9), (12, 4), (3, 12)])]
    from repro.telemetry import MetricRegistry
    big = ContinuousBatchingEngine(cfg, params, SchedulerConfig(
        kv=PagedKVConfig(page_size=4, n_pages=24, n_slots=3,
                         max_pages_per_seq=8)))
    ref = big.serve(reqs)
    reg = MetricRegistry()
    tight = ContinuousBatchingEngine(cfg, params, SchedulerConfig(
        kv=PagedKVConfig(page_size=4, n_pages=7, n_slots=3,
                         max_pages_per_seq=4)), registry=reg)
    out = tight.serve(reqs)
    assert reg.metrics()["serve/sched/evictions"] > 0, \
        "pool was not tight enough to exercise preemption"
    for r in reqs:
        np.testing.assert_array_equal(ref[r.rid], out[r.rid])
    tight.kv.check_invariants()


def test_scheduler_rejects_impossible_request():
    cfg = ARCHS["dense"]
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    kv = PagedKVConfig(page_size=4, n_pages=8, n_slots=2,
                       max_pages_per_seq=4)
    eng = ContinuousBatchingEngine(cfg, params, SchedulerConfig(kv=kv))
    with pytest.raises(ConfigError, match="pool caps"):
        eng.serve([Request(rid=0, prompt=tuple(range(20)),
                           max_new_tokens=10)])
    with pytest.raises(ConfigError, match="positive"):
        eng.serve([Request(rid=0, prompt=(1, 2), max_new_tokens=0)])


def test_kv_bytes_per_token_accounting():
    cfg = _mk(head_dim=64, d_model=128, n_heads=2, n_kv_heads=2)
    base = kv_bytes_per_token(cfg, 16)
    assert base == 2 * 2 * 128 * 2      # k+v, 2 kv heads, 2B*64, 2 layers
    assert kv_bytes_per_token(cfg, 8) / base == pytest.approx(68 / 128)
    assert kv_bytes_per_token(cfg, 4) / base == pytest.approx(36 / 128)
    assert kv_bytes_per_token(cfg, 4) / base <= 0.30


# -------------------------------------- allocator / page-table invariants

def _random_schedule(seed: int, n_ops: int = 120):
    """Drive PagedKVCache through a random admit/extend/advance/release
    schedule, checking the §17 invariants after every transition."""
    rng = np.random.RandomState(seed)
    kvc = PagedKVConfig(page_size=int(rng.choice([2, 4, 8])),
                        n_pages=int(rng.randint(4, 24)),
                        n_slots=int(rng.randint(1, 5)),
                        max_pages_per_seq=int(rng.randint(2, 8)))
    kv = PagedKVCache(kvc)
    next_rid = 0
    live: list = []
    for _ in range(n_ops):
        op = rng.randint(4)
        if op == 0:    # admit
            cap = min(kvc.max_pages_per_seq, kvc.n_pages) * kvc.page_size
            P = int(rng.randint(1, max(2, cap)))
            slot = kv.admit(next_rid, P)
            if slot is not None:
                assert kv.slot_of(next_rid) == slot
                live.append(next_rid)
                next_rid += 1
        elif op == 1 and live:   # advance + lazy extend
            rid = int(rng.choice(live))
            st = kv.slots[kv.slot_of(rid)]
            if st.position + 1 < kvc.max_tokens_per_seq():
                if kv.extend(rid):
                    kv.advance(rid)
        elif op == 2 and live:   # release (completion or eviction)
            rid = live.pop(int(rng.randint(len(live))))
            kv.release(rid)
        elif op == 3 and live:   # double-free must raise, state unchanged
            rid = int(rng.choice(live))
            pages = list(kv.slots[kv.slot_of(rid)].pages)
            kv.release(rid)
            live.remove(rid)
            with pytest.raises(ConfigError, match="double-free"):
                kv.alloc.free(pages)
        kv.check_invariants()
        assert kv.alloc.n_free + kv.alloc.n_allocated == kvc.n_pages
    for rid in live:
        kv.release(rid)
    kv.check_invariants()
    assert kv.alloc.n_free == kvc.n_pages and kv.n_active == 0


@pytest.mark.parametrize("seed", range(8))
def test_page_table_invariants_seeded(seed):
    _random_schedule(seed)


def test_page_table_invariants_hypothesis():
    """Hypothesis variant of the schedule property; falls back to a wider
    seeded sweep when hypothesis isn't installed (the property still
    runs — no skip)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for seed in range(8, 40):
            _random_schedule(seed, n_ops=60)
        return

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def prop(seed):
        _random_schedule(seed, n_ops=60)

    prop()


def test_allocator_edges():
    with pytest.raises(ConfigError):
        PageAllocator(0)
    a = PageAllocator(3)
    assert a.alloc(4) is None and a.n_free == 3    # all-or-nothing
    got = a.alloc(3)
    assert sorted(got) == [0, 1, 2] and a.occupancy == 1.0
    assert a.alloc(1) is None
    with pytest.raises(ConfigError):
        a.free([5])
    a.free(got)
    with pytest.raises(ConfigError, match="double-free"):
        a.free(got)
    with pytest.raises(ConfigError):
        PagedKVConfig(kv_bits=5)
