"""Per-arch smoke tests: REDUCED config of the same family, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement). Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import base
from repro.core.optim import make_optimizer
from repro.models import model as M
from repro.train import loop as L

ARCHS = base.list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_reduced_forward(arch):
    cfg = base.reduced(base.get_config(arch))
    key = jax.random.PRNGKey(0)
    params, specs = M.init_model(cfg, key)
    tok = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    embeds = (jnp.zeros((2, cfg.frontend_tokens, cfg.d_model))
              if cfg.frontend_tokens else None)
    logits, _ = M.forward(cfg, params, tok, embeds=embeds)
    assert logits.shape == (2, 16 + cfg.frontend_tokens, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # logical specs mirror params
    np_leaves = len(jax.tree_util.tree_leaves(params))
    sp_leaves = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda t: isinstance(t, tuple)
        and all(isinstance(e, str) for e in t)))
    assert np_leaves == sp_leaves


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_reduced_train_step(arch):
    cfg = base.reduced(base.get_config(arch))
    key = jax.random.PRNGKey(0)
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=512)
    state, _ = L.init_train_state(cfg, opt, key)
    step = jax.jit(L.make_train_step(cfg, opt))
    batch = {"tokens": jax.random.randint(key, (2, 17), 0, cfg.vocab_size)}
    if cfg.frontend_tokens:
        batch["embeds"] = jnp.zeros((2, cfg.frontend_tokens, cfg.d_model))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1


def test_param_counts_close_to_nominal():
    """Analytic param counts should be near the arch's nominal size."""
    expected = {
        "qwen1.5-32b": (29e9, 40e9), "stablelm-1.6b": (1.3e9, 2.1e9),
        "granite-3-8b": (6.5e9, 9.5e9), "command-r-35b": (28e9, 40e9),
        "llava-next-34b": (30e9, 38e9), "recurrentgemma-9b": (7.5e9, 11e9),
        "musicgen-medium": (1.0e9, 2.0e9), "xlstm-350m": (0.28e9, 0.45e9),
        "mixtral-8x22b": (120e9, 150e9), "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
    }
    for arch, (lo, hi) in expected.items():
        n = base.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_moe_capacity_drop_metric():
    cfg = base.reduced(base.get_config("mixtral-8x22b"), capacity_factor=0.5)
    key = jax.random.PRNGKey(0)
    params, _ = M.init_model(cfg, key)
    tok = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    _, mx = M.forward(cfg, params, tok)
    assert 0.0 <= float(mx["moe_drop_frac"]) <= 1.0
    assert float(mx["moe_drop_frac"]) > 0.0   # cf=0.5 must drop tokens


def test_remat_matches_no_remat():
    cfg = base.reduced(base.get_config("paper-lm-209m"))
    import dataclasses
    cfg_r = dataclasses.replace(cfg, remat="full")
    key = jax.random.PRNGKey(0)
    params, _ = M.init_model(cfg, key)
    tok = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    l1, _ = M.forward(cfg, params, tok)
    l2, _ = M.forward(cfg_r, params, tok)
    assert jnp.allclose(l1, l2, atol=1e-5)


def test_stable_vs_baseline_embedding_variance():
    """Stable embedding (§2.3) keeps output variance ~1 at init."""
    key = jax.random.PRNGKey(0)
    import dataclasses
    cfg_s = base.reduced(base.get_config("paper-lm-209m"), d_model=256)
    cfg_b = dataclasses.replace(cfg_s, stable_embedding=False)
    from repro.models import embedding as E
    tok = jax.random.randint(key, (4, 64), 0, cfg_s.vocab_size)
    ps, _ = E.init_embedding(key, cfg_s)
    pb, _ = E.init_embedding(key, cfg_b)
    xs = E.apply_embedding(ps, tok, cfg_s)
    xb = E.apply_embedding(pb, tok, cfg_b)
    vs = float(jnp.var(xs.astype(jnp.float32)))
    vb = float(jnp.var(xb.astype(jnp.float32)))
    assert 0.5 < vs < 2.0          # layer norm pins variance
    assert 0.2 < vb < 5.0          # baseline also ~1 at init (by scaling)
