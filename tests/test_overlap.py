"""Bucketed overlap, ZeRO-2 grad sharding, donation — DESIGN.md §13.

The contract under test: ``OptimConfig.overlap_buckets`` changes only HOW
MANY dispatches the partitioned arena update is cut into (uniform local-
row chunks of every owned span), ``shard_grads`` changes only WHERE the
accumulated gradients live (the arena's flat block domain, owned-span
sharded, instead of a replicated param-shaped pytree), and the donated
train step changes only WHERE the state's buffers are written (in place).
Losses, codes, absmax, masters, stochastic rounding, trust ratios and the
clip histories stay bit-identical to the sequential PR-5 oracle on the
mesh-free unrolled path and on {1,2,4}-device meshes, including packed
(4, 8) states and muon matrix routing.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.optim import make_optimizer, make_partition, unpool_state
from repro.core.optim.base import make_buckets
from repro.core.optim.blockopt import GradBuffer
from repro.train import loop as L

from helpers import assert_trees_equal, mesh_of, tiny_cfg, tiny_pipe

from test_partition import _params, _train, _canon


# ---------------------------------------------- bucket assignment property
def _check_plan(total, shards, n_buckets, grid, n_matrix=0):
    owners = tuple((f"m{k}", k % shards) for k in range(n_matrix))
    part = make_partition(total, shards, grid, matrix_owners=owners)
    plan = make_buckets(part, n_buckets, grid=grid)
    # ranges are non-empty, disjoint, grid-aligned and tile [0, span_pad)
    prev = 0
    for k0, k1 in plan.ranges:
        assert k0 == prev and k1 > k0, plan
        assert k0 % grid == 0, plan
        prev = k1
    assert prev == part.span_pad, plan
    assert len(plan.ranges) <= max(n_buckets, 1), plan
    # every arena row lands in exactly one (owner, bucket) cell
    for row in range(total):
        k = plan.bucket_of(row, part)
        k0, k1 = plan.ranges[k]
        local = row - part.owner_of(row) * part.span_pad
        assert k0 <= local < k1
        assert sum(a <= local < b for a, b in plan.ranges) == 1
    # every matrix leaf lands in exactly one bucket
    assert len(plan.matrix_buckets) == n_matrix
    for _, bk in plan.matrix_buckets:
        assert 0 <= bk < n_buckets
    # the (span, bucket) pieces used by the unrolled dispatch cover the
    # real rows exactly once, in arena order
    pieces = [(start + k0, min(n, k1) - k0)
              for start, n in part.spans
              for k0, k1 in plan.ranges]
    covered = []
    for start, n in pieces:
        if n > 0:
            covered.extend(range(start, start + n))
    assert covered == sorted(covered)
    assert covered == [r for r in range(part.padded_total)
                       if r - part.owner_of(r) * part.span_pad
                       < part.spans[part.owner_of(r)][1]]


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("n_buckets", [1, 2, 3, 5])
def test_bucket_assignment_property_cases(shards, n_buckets):
    for total in (0, 1, 7, 16, 31, 64, 97):
        for grid in (1, 4):
            _check_plan(total, shards, n_buckets, grid, n_matrix=3)


def test_bucket_assignment_property_hypothesis():
    """Hypothesis variant of the bucket-coverage property; falls back to a
    seeded random sweep of the same checks when hypothesis isn't
    installed (the property still runs — no skip)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        rng = np.random.RandomState(0)
        for _ in range(60):
            _check_plan(int(rng.randint(0, 200)),
                        int(rng.choice([1, 2, 3, 4])),
                        int(rng.randint(1, 9)),
                        int(rng.choice([1, 2, 4])),
                        n_matrix=int(rng.randint(0, 4)))
        return

    @settings(max_examples=60, deadline=None)
    @given(total=st.integers(0, 200), shards=st.integers(1, 4),
           n_buckets=st.integers(1, 8), grid=st.sampled_from([1, 2, 4]),
           n_matrix=st.integers(0, 3))
    def prop(total, shards, n_buckets, grid, n_matrix):
        _check_plan(total, shards, n_buckets, grid, n_matrix)

    prop()


# -------------------------------------- bucketed dispatch bit-exactness
@pytest.mark.parametrize("shards,buckets", [(2, 2), (3, 2), (4, 3)])
def test_bucketed_unrolled_matches_single_dispatch(shards, buckets):
    """Mesh-free unrolled path: bucket-order execution (one launch per
    (span, bucket) piece) is bitwise equal to the one-launch-per-span
    dispatch AND the unpartitioned pooled oracle — odd bucket counts on
    uneven arenas included."""
    kw = dict(lr=1e-2, min_8bit_size=1024, stochastic_rounding=True)
    p_a, st_a = _train(make_optimizer("adamw8", partition=True,
                                      partition_shards=shards,
                                      overlap_buckets=buckets, **kw),
                       _params())
    p_b, st_b = _train(make_optimizer("adamw8", partition=True,
                                      partition_shards=shards, **kw),
                       _params())
    p_c, st_c = _train(make_optimizer("adamw8", partition=False, **kw),
                       _params())
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b),
                       f"bucketed vs single {shards}/{buckets}")
    assert_trees_equal(_canon(p_a, st_a), _canon(p_c, st_c),
                       f"bucketed vs oracle {shards}/{buckets}")


@pytest.mark.parametrize("n_dev", [2, 4])
def test_bucketed_mesh_matches_oracle(n_dev):
    """shard_map path with an odd bucket count: one local fused launch per
    bucket per device, stitched back bit-identical to the oracle (lamb
    covers the globally-finalized trust-ratio pass)."""
    mesh = mesh_of(n_dev)
    kw = dict(lr=1e-2, min_8bit_size=1024, stochastic_rounding=True)
    p_a, st_a = _train(make_optimizer("lamb8", mesh=mesh, partition=True,
                                      overlap_buckets=3, **kw), _params())
    p_b, st_b = _train(make_optimizer("lamb8", partition=False, **kw),
                       _params())
    assert_trees_equal(_canon(p_a, st_a), _canon(p_b, st_b),
                       f"mesh{n_dev} buckets3")


# ------------------------------------------------- ZeRO-2 grad buffer
def _grads_of(params, key=1):
    k = jax.random.PRNGKey(key)
    leaves, tdef = jax.tree_util.tree_flatten(params)
    ks = jax.random.split(k, len(leaves))
    return jax.tree_util.tree_unflatten(
        tdef, [jax.random.normal(kk, l.shape) * 0.02
               for kk, l in zip(ks, leaves)])


def test_grad_buffer_accumulate_and_norm_match_pytree():
    """Microbatch accumulation into the owned-span buffer is bit-identical
    to accumulating param-shaped, and the buffer norm equals
    train.loop.global_norm on the equivalent pytree."""
    params = _params()
    opt = make_optimizer("adamw8", lr=1e-2, min_8bit_size=1024,
                         partition=True, partition_shards=3,
                         shard_grads=True, overlap_buckets=2)
    st = opt.init(params)
    g1, g2 = _grads_of(params, 1), _grads_of(params, 2)
    buf = opt.init_grad_buffer(st)
    buf = opt.accumulate_grads(buf, g1)
    buf = opt.accumulate_grads(buf, g2)
    gsum = jax.tree_util.tree_map(lambda a, b: a + b, g1, g2)
    views = list(opt._grad_views(buf))
    leaves = jax.tree_util.tree_leaves(gsum)
    assert len(views) == len(leaves)
    for v, l in zip(views, leaves):
        np.testing.assert_array_equal(np.asarray(v), np.asarray(l))
    np.testing.assert_array_equal(
        np.asarray(opt.grad_buffer_norm(buf)),
        np.asarray(L.global_norm(gsum)))


@pytest.mark.parametrize("mesh_dev", [0, 2, 4])
def test_buffer_apply_matches_sequential(mesh_dev):
    """apply(GradBuffer) — the full ZeRO-2 path (packed (4, 8) states,
    stochastic rounding, bucketed dispatch) — is bitwise equal to the
    sequential pytree apply, mesh-free and on {2,4}-device meshes."""
    mesh = mesh_of(mesh_dev) if mesh_dev else None
    params = _params()
    kw = dict(lr=1e-2, min_8bit_size=1024, state_bits=(4, 8),
              stochastic_rounding=True, partition=True,
              partition_shards=mesh_dev or 3)
    opt_s = make_optimizer("adam8", mesh=mesh, **kw)
    opt_o = make_optimizer("adam8", mesh=mesh, shard_grads=True,
                           overlap_buckets=2, **kw)
    grads = _grads_of(params)
    st_s = opt_s.init(params)
    st_o = opt_o.init(params)
    p_s, st_s2 = jax.jit(lambda g, s: opt_s.apply(g, s))(grads, st_s)
    buf = opt_o.accumulate_grads(opt_o.init_grad_buffer(st_o), grads)
    p_o, st_o2 = jax.jit(lambda b, s: opt_o.apply(b, s))(buf, st_o)
    assert_trees_equal(_canon(p_s, st_s2), _canon(p_o, st_o2),
                       f"buffer apply mesh{mesh_dev}")


def test_muon_buffer_apply_matches_sequential():
    """Muon under ZeRO-2: matrix leaves ride the buffer param-shaped and
    stay whole-leaf owner-routed; the element-wise arena comes from the
    block buffer.  Bitwise equal to the sequential muon path."""
    params = _params()
    kw = dict(lr=1e-2, min_8bit_size=256, override_32bit=lambda p: False,
              stochastic_rounding=True, partition=True, partition_shards=2)
    opt_s = make_optimizer("muon8", **kw)
    opt_o = make_optimizer("muon8", shard_grads=True, overlap_buckets=2,
                           **kw)
    grads = _grads_of(params)
    st_s = opt_s.init(params)
    st_o = opt_o.init(params)
    p_s, st_s2 = jax.jit(lambda g, s: opt_s.apply(g, s))(grads, st_s)
    buf = opt_o.accumulate_grads(opt_o.init_grad_buffer(st_o), grads)
    p_o, st_o2 = jax.jit(lambda b, s: opt_o.apply(b, s))(buf, st_o)
    assert_trees_equal(_canon(p_s, st_s2), _canon(p_o, st_o2), "muon zero2")


def test_deferred_params_view_matches_eager():
    """materialize_params=False returns (None, state); params_view at
    first use reconstructs exactly what the eager apply returned."""
    params = _params()
    opt = make_optimizer("adamw8", lr=1e-2, min_8bit_size=1024)
    st = opt.init(params)
    grads = _grads_of(params)
    p_e, st_e = jax.jit(lambda g, s: opt.apply(g, s))(grads, st)
    p_d, st_d = jax.jit(
        lambda g, s: opt.apply(g, s, materialize_params=False))(grads, st)
    assert p_d is None
    assert_trees_equal(p_e, opt.params_view(st_d), "deferred view")
    assert_trees_equal(unpool_state(st_e).leaves, unpool_state(st_d).leaves,
                       "deferred state")


# ------------------------------------------ end-to-end train-loop parity
def _loop_train(opt, steps=4, microbatches=2, trace=("loss", "grad_norm")):
    cfg = tiny_cfg()
    pipe = tiny_pipe(vocab_size=cfg.vocab_size)
    hyper = L.TrainHyper(microbatches=microbatches)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = L.jit_train_step(cfg, opt, hyper)
    traces = {n: [] for n in trace}
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        for n in trace:
            traces[n].append(float(m[n]))
    return state, m, traces


def test_zero2_train_loop_matches_sequential():
    """Full train-step parity with grad accumulation: the shard_grads
    branch (buffer scan carry, buffer clip, deferred params view,
    donated state) reproduces the sequential loop's losses, grad norms
    and final state bit-for-bit."""
    kw = dict(lr=5e-3, min_8bit_size=1024, stochastic_rounding=True,
              partition=True, partition_shards=2)
    st_s, m_s, tr_s = _loop_train(make_optimizer("adamw8", **kw))
    st_o, m_o, tr_o = _loop_train(make_optimizer(
        "adamw8", shard_grads=True, overlap_buckets=2, **kw))
    assert tr_s == tr_o, (tr_s, tr_o)
    assert_trees_equal(unpool_state(st_s.opt_state).leaves,
                       unpool_state(st_o.opt_state).leaves, "final state")
    assert float(m_o["peak_grad_bytes"]) < float(
        m_o["replicated_grad_bytes"])


def test_zero2_pclip_history_matches_sequential():
    """Percentile clipping driven off the GradBuffer: the squared-gnorm
    history and clip scales stay bit-identical to the pytree path."""
    kw = dict(lr=5e-3, min_8bit_size=1024, percentile_clipping=50,
              pclip_history=3, partition=True, partition_shards=2)
    st_s, _, tr_s = _loop_train(make_optimizer("adamw8", **kw),
                                trace=("loss", "pclip_scale"))
    st_o, _, tr_o = _loop_train(
        make_optimizer("adamw8", shard_grads=True, **kw),
        trace=("loss", "pclip_scale"))
    assert tr_s == tr_o, (tr_s, tr_o)
    assert_trees_equal(st_s.opt_state.gnorm_vec, st_o.opt_state.gnorm_vec,
                       "gnorm history")


# -------------------------------------------------------- donation audit
def test_train_step_donation_aliases():
    """The jitted train step donates the TrainState: the lowered StableHLO
    carries input/output buffer aliasings for the state (DESIGN.md §13c),
    and the undonated variant carries none."""
    cfg = tiny_cfg()
    pipe = tiny_pipe(vocab_size=cfg.vocab_size)
    opt = make_optimizer("adamw8", lr=5e-3, min_8bit_size=1024)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    donated = L.jit_train_step(cfg, opt).lower(state, batch)
    n = L.donation_aliases(donated)
    n_state_bufs = len(jax.tree_util.tree_leaves(state))
    assert n > 0, "donated step established no buffer aliasing"
    # every aliasing points at a state buffer; most state buffers alias
    # (masters/codes/absmax keep shape+dtype across the step)
    assert n <= n_state_bufs
    assert n >= n_state_bufs // 2, (n, n_state_bufs)

    plain = L.jit_train_step(cfg, opt, donate=False).lower(state, batch)
    assert L.donation_aliases(plain) == 0

    # donated executables also report the aliasing post-compilation
    compiled = donated.compile()
    text = compiled.as_text()
    assert "input_output_alias" in text


def test_donated_step_runs_and_matches_undonated():
    """Donation changes buffer reuse, not values: a short donated run
    produces the same losses as the undonated one."""
    opt_kw = dict(lr=5e-3, min_8bit_size=1024)
    cfg = tiny_cfg()
    pipe = tiny_pipe(vocab_size=cfg.vocab_size)

    def run(donate):
        opt = make_optimizer("adamw8", **opt_kw)
        state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        step = L.jit_train_step(cfg, opt, donate=donate)
        losses = []
        for i in range(3):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        return losses

    assert run(True) == run(False)


# ----------------------------------------------------------- config guard
def test_shard_grads_requires_pooled():
    with pytest.raises(ValueError, match="shard_grads"):
        make_optimizer("adamw8", shard_grads=True, pooled=False)


def test_quickstart_rejects_shard_grads_without_pooled():
    """examples/quickstart.py mirrors the --partition guard: ZeRO-2
    accumulates in the arena's block domain, so --no-pooled is rejected
    at argparse time with a pointer to DESIGN.md §13."""
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run(
        [sys.executable, os.path.join(root, "examples", "quickstart.py"),
         "--shard-grads", "--no-pooled"],
        capture_output=True, text=True, env=env)
    assert r.returncode == 2, (r.returncode, r.stderr)
    assert "--no-pooled" in r.stderr and "13" in r.stderr, r.stderr


def test_grad_buffer_bytes_scaling():
    """Static ZeRO-2 accounting: 4-way sharded grad bytes fall below
    0.35x of the replicated pytree on an arena-dominated model."""
    params = {f"w{i}": jnp.zeros((64, 256)) for i in range(8)}
    opt = make_optimizer("adam8", min_8bit_size=256,
                         override_32bit=lambda p: False, partition=True,
                         partition_shards=4, shard_grads=True)
    st = opt.init(params)
    gbb = opt.grad_buffer_bytes(st)
    assert gbb["grad_partition_shards"] == 4
    assert gbb["sharded_grad_bytes"] <= 0.35 * gbb["replicated_grad_bytes"]
