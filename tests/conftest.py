"""Force 4 host CPU devices so mesh-placement tests (elastic restore,
pooled<->per-leaf checkpoint interchange, partitioned ZeRO-1 dispatch on
{1,2,4}-device meshes) exercise real multi-device meshes.  Must run before
jax initializes its backends — conftest import time is the only reliable
hook.  Tests build sub-meshes via ``tests.helpers.mesh_of(n)`` rather than
assuming the global device count.

Also registers ``--regen-golden`` for tests/test_golden.py: regenerate the
committed fixed-seed trajectory files instead of asserting against them.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()


def pytest_addoption(parser):
    parser.addoption(
        "--regen-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json from the current code instead "
             "of asserting against the committed trajectories")
