"""Force 2 host CPU devices so mesh-placement tests (elastic restore,
pooled<->per-leaf checkpoint interchange) exercise a real 2-device mesh.
Must run before jax initializes its backends — conftest import time is the
only reliable hook."""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()
