"""Block-wise quantization core: roundtrip, outlier isolation, hypothesis
property tests on the system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property tests need the `test` extra (pip install -e '.[test]'); without
# it, skip this module instead of failing collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import blockwise as bw
from repro.core import qmap

jax.config.update("jax_platform_name", "cpu")


def test_roundtrip_relative_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (50_000,)) * 0.01
    qt = bw.quantize(x)
    xd = bw.dequantize(qt)
    rel = jnp.abs(xd - x) / (jnp.abs(x) + 1e-12)
    # dynamic map mean relative error is a few percent (paper App F, Table 6)
    assert float(jnp.mean(rel)) < 0.05


def test_positive_blockmax_exact():
    """Paper §2.1: the (positive) max-magnitude value per block is
    represented without error."""
    key = jax.random.PRNGKey(1)
    x = jnp.abs(jax.random.normal(key, (8192,))) + 0.1
    qt = bw.quantize(x, signed=False, qmap_name="dynamic")
    xd = bw.dequantize(qt)
    blocks = bw.pad_to_blocks(x, 2048)
    dblocks = bw.pad_to_blocks(xd, 2048)
    idx = jnp.argmax(jnp.abs(blocks), axis=-1)
    rows = jnp.arange(blocks.shape[0])
    assert jnp.allclose(blocks[rows, idx], dblocks[rows, idx])


def test_outlier_isolation():
    """An outlier in one block must not degrade other blocks (§2.1)."""
    rng = np.random.RandomState(0)
    x = rng.randn(4096).astype(np.float32) * 0.01
    x_out = x.copy()
    x_out[100] = 100.0                      # huge outlier in block 0
    e_clean = float(bw.quantization_error(jnp.asarray(x)[2048:],
                                          bw.quantize(jnp.asarray(x[2048:]))))
    e_block1_with_outlier = float(bw.quantization_error(
        jnp.asarray(x_out)[2048:],
        bw.QuantizedTensor(
            codes=bw.quantize(jnp.asarray(x_out)).codes[1:],
            absmax=bw.quantize(jnp.asarray(x_out)).absmax[1:],
            shape=(2048,), qmap_name="dynamic", signed=True)))
    assert e_block1_with_outlier == pytest.approx(e_clean, rel=1e-5)


def test_tensorwise_outlier_hurts():
    """Contrast (paper §2.1): with a tensor-wide absmax an outlier wastes
    the quantization range of every other value; with block-wise absmax the
    damage is confined to the outlier's block.  Measured on the outlier-free
    second block, for both linear and dynamic codebooks."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4096).astype(np.float32) * 0.01)
    x_out = x.at[0].set(100.0)
    for name, min_ratio in [("linear", 50.0), ("dynamic", 5.0)]:
        cb = jnp.asarray(qmap.get_qmap(name, True))
        codes, absmax = bw.quantize_blocks(x_out.reshape(1, -1), cb)
        xd = bw.dequantize_blocks(codes, absmax, cb).reshape(-1)
        err_tensorwise = float(jnp.mean(jnp.abs(xd[2048:] - x_out[2048:])))
        qt = bw.quantize(x_out, qmap_name=name, block_size=2048)
        d = bw.dequantize(qt)
        err_blockwise = float(jnp.mean(jnp.abs(d[2048:] - x_out[2048:])))
        assert err_tensorwise > min_ratio * err_blockwise, (
            name, err_tensorwise, err_blockwise)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 5000),
       scale=st.floats(1e-6, 1e3),
       seed=st.integers(0, 2**30))
def test_property_roundtrip_bounded(n, scale, seed):
    """For any input, block-wise dynamic quantization error is bounded by
    the local absmax times the largest codebook gap."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(n).astype(np.float32) * scale)
    qt = bw.quantize(x)
    xd = bw.dequantize(qt)
    blocks = bw.pad_to_blocks(x, qt.block_size)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    cb = qmap.get_qmap("dynamic", True)
    max_gap = float(np.max(np.diff(cb))) / 2 + 1e-7
    bound = absmax[:, None] * max_gap
    err = jnp.abs(bw.pad_to_blocks(xd, qt.block_size) - blocks)
    assert bool(jnp.all(err <= bound + 1e-12))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30), block_size=st.sampled_from([256, 512, 2048]))
def test_property_block_independence(seed, block_size):
    """Changing one block's contents never changes other blocks' codes."""
    rng = np.random.RandomState(seed)
    x = rng.randn(4 * block_size).astype(np.float32)
    y = x.copy()
    y[:block_size] *= 1000.0
    qx = bw.quantize(jnp.asarray(x), block_size=block_size)
    qy = bw.quantize(jnp.asarray(y), block_size=block_size)
    assert bool(jnp.all(qx.codes[1:] == qy.codes[1:]))
    assert bool(jnp.all(qx.absmax[1:] == qy.absmax[1:]))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_property_sign_preserved(seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(3000).astype(np.float32))
    xd = bw.dequantize(bw.quantize(x))
    assert bool(jnp.all(jnp.sign(xd) * jnp.sign(x) >= 0))


def test_unsigned_nonnegative():
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (5000,)))
    xd = bw.dequantize(bw.quantize(x, signed=False))
    assert bool(jnp.all(xd >= 0))


def test_stochastic_rounding_unbiased():
    key = jax.random.PRNGKey(0)
    x = jnp.full((2048,), 0.3)      # sits between two codes
    cb = jnp.asarray(qmap.get_qmap("dynamic", True))
    outs = []
    for i in range(200):
        c, a = bw.quantize_blocks(x.reshape(1, -1), cb,
                                  stochastic_rounding=True,
                                  key=jax.random.fold_in(key, i))
        outs.append(float(bw.dequantize_blocks(c, a, cb).mean()))
    est = np.mean(outs)
    det_c, det_a = bw.quantize_blocks(x.reshape(1, -1), cb)
    det = float(bw.dequantize_blocks(det_c, det_a, cb).mean())
    # stochastic mean should be closer to the true value than deterministic
    assert abs(est - 0.3) <= abs(det - 0.3) + 1e-4


def test_zeros_like_quantized():
    x = jnp.ones((3, 1000))
    z = bw.zeros_like_quantized(x)
    assert float(jnp.abs(bw.dequantize(z)).max()) == 0.0
    assert bw.dequantize(z).shape == (3, 1000)
