"""k-bit code-format subsystem (DESIGN.md §9): qmap level counts, pack/
unpack round-trips (property-style over odd block counts, all bitwidths),
kernel parity for packed states, optimizer wiring, checkpoint elastic
restore of packed leaves, and sharding rules for packed arrays."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qmap
from repro.core.lowbit import (SUPPORTED_BITS, CodeFormat, PackedCodes,
                               pack_codes, packed_width, unpack_codes)
from repro.errors import ConfigError
from repro.core.optim import (Full32Leaf, OptimConfig, Quant8Leaf,
                              make_optimizer, unpool_state)
from repro.kernels import ops, ref


# ------------------------------------------------------------------- qmaps
@pytest.mark.parametrize("bits", SUPPORTED_BITS)
@pytest.mark.parametrize("signed", [True, False])
def test_kbit_qmap_levels(bits, signed):
    for name in ["dynamic", "inverse_dynamic", "linear", "quantile_normal"]:
        m = qmap.get_qmap(name, signed, bits=bits)
        assert m.shape == (2 ** bits,)
        assert np.all(np.diff(m) >= 0)
        assert m[-1] == pytest.approx(1.0)


@pytest.mark.parametrize("bits", SUPPORTED_BITS)
def test_kbit_dynamic_map_has_zero_and_sign_structure(bits):
    s = qmap.get_qmap("dynamic", True, bits=bits)
    assert 0.0 in s and 1.0 in s
    # signed map is (almost) antisymmetric: every positive level has its
    # mirror except the appended 1.0
    pos = s[s > 0]
    neg = -s[s < 0]
    np.testing.assert_allclose(np.sort(pos)[:-1], np.sort(neg), rtol=1e-6)


def test_default_qmap_unchanged():
    """bits=8 must reproduce the paper's 256-entry maps bit-for-bit."""
    np.testing.assert_array_equal(qmap.get_qmap("dynamic", True),
                                  qmap.get_qmap("dynamic", True, bits=8))
    assert qmap.get_qmap("dynamic", True).shape == (256,)


# ----------------------------------------------------------------- packing
@pytest.mark.parametrize("bits", SUPPORTED_BITS)
@pytest.mark.parametrize("n_blocks", [1, 3, 5, 7, 13])
def test_pack_unpack_roundtrip_odd_block_counts(bits, n_blocks):
    """Property-style sweep: random codes over odd block counts round-trip
    exactly for every supported bitwidth."""
    rng = np.random.RandomState(bits * 100 + n_blocks)
    for bsz in (8, 24, 256):
        codes = rng.randint(0, 2 ** bits, size=(n_blocks, bsz))
        packed = pack_codes(jnp.asarray(codes), bits)
        assert packed.shape == (n_blocks, packed_width(bsz, bits))
        assert packed.dtype == jnp.uint8
        out = unpack_codes(packed, bits)
        np.testing.assert_array_equal(np.asarray(out), codes)


def test_pack_unpack_roundtrip_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(bits=st.sampled_from(SUPPORTED_BITS),
           n_blocks=st.integers(1, 9),
           bsz_mult=st.integers(1, 8),
           seed=st.integers(0, 2 ** 16))
    def roundtrip(bits, n_blocks, bsz_mult, seed):
        bsz = 8 * bsz_mult
        rng = np.random.RandomState(seed)
        codes = rng.randint(0, 2 ** bits, size=(n_blocks, bsz))
        out = unpack_codes(pack_codes(jnp.asarray(codes), bits), bits)
        np.testing.assert_array_equal(np.asarray(out), codes)

    roundtrip()


def test_packed_codes_container():
    codes = jnp.asarray(np.random.RandomState(0).randint(0, 16, (5, 64)))
    pc = PackedCodes.from_codes(codes, 4)
    assert pc.shape == (5, 64)
    assert pc.packed.shape == (5, 32)
    assert pc.nbytes() == 5 * 32
    np.testing.assert_array_equal(np.asarray(pc.unpack()), np.asarray(codes))
    # pytree: exactly one array leaf, static aux survives a map
    leaves = jax.tree_util.tree_leaves(pc)
    assert len(leaves) == 1
    pc2 = jax.tree_util.tree_map(lambda x: x, pc)
    assert (pc2.bits, pc2.n_codes) == (4, 64)


def test_code_format_accounting():
    f4 = CodeFormat(bits=4, signed=True)
    f8 = CodeFormat(bits=8, signed=True)
    assert f4.n_levels == 16 and f4.max_code == 15
    assert f4.bytes_per_param(2048) < 0.55 * f8.bytes_per_param(2048)
    init = f4.init_codes(6, 2048)
    assert isinstance(init, PackedCodes)
    assert np.all(np.asarray(init.unpack()) == f4.zero_code())
    assert isinstance(f8.init_codes(6, 2048), jnp.ndarray)


# ----------------------------------------------------------- kernel parity
HYPER = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8,
             weight_decay=0.01, step=7.0, trust_coeff=1e-3)


def _kbit_inputs(algo, bits, nb=3, bsz=256):
    qs = jnp.asarray(qmap.get_qmap("dynamic", True, bits=bits))
    qu = jnp.asarray(qmap.get_qmap("dynamic", False, bits=bits))
    k = jax.random.PRNGKey(0)
    p = jax.random.normal(k, (nb, bsz))
    g = jax.random.normal(jax.random.PRNGKey(1), (nb, bsz)) * 0.01
    two = algo in ("adam", "adamw", "lamb")
    q1 = qu if algo == "adagrad" else qs
    x1 = jnp.abs(p) * 1e-3 if algo == "adagrad" else p * 0.01
    c1, a1 = ref.quantize_ref(x1, q1)
    cm = PackedCodes.from_codes(c1, bits)
    cr = ar = None
    if two:
        c2, a2 = ref.quantize_ref(jnp.abs(p) * 1e-4, qu)
        cr, ar = PackedCodes.from_codes(c2, bits), a2
    return p, g, cm, a1, cr, ar, q1, qu


@pytest.mark.parametrize("bits", [4, 5, 6])
@pytest.mark.parametrize("algo", ["adam", "lamb", "adagrad"])
def test_kbit_fused_update_parity(bits, algo):
    """Packed k-bit fused update: Pallas-interpret (in-kernel unpack/pack)
    vs the jnp oracle must agree bit-for-bit on the packed codes."""
    args = _kbit_inputs(algo, bits)
    out_k = ops.fused_update(algo, *args, impl="interpret", **HYPER)
    out_r = ops.fused_update(algo, *args, impl="jnp", **HYPER)
    assert isinstance(out_k.codes_m, PackedCodes)
    assert out_k.codes_m.packed.shape == (3, 256 * bits // 8)
    np.testing.assert_array_equal(np.asarray(out_k.codes_m.packed),
                                  np.asarray(out_r.codes_m.packed))
    np.testing.assert_allclose(np.asarray(out_k.p), np.asarray(out_r.p),
                               atol=5e-6, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out_k.absmax_m),
                               np.asarray(out_r.absmax_m),
                               atol=5e-6, rtol=1e-5)


def test_kbit_fused_update_stochastic_parity():
    args = _kbit_inputs("adam", 4)
    out_k = ops.fused_update("adam", *args, impl="interpret",
                             stochastic=True, seed=123, **HYPER)
    out_r = ops.fused_update("adam", *args, impl="jnp",
                             stochastic=True, seed=123, **HYPER)
    np.testing.assert_array_equal(np.asarray(out_k.codes_m.packed),
                                  np.asarray(out_r.codes_m.packed))


def test_kbit_qmap_level_mismatch_rejected():
    args = list(_kbit_inputs("adam", 4))
    args[6] = jnp.asarray(qmap.get_qmap("dynamic", True, bits=5))  # qmap_m
    with pytest.raises(ValueError, match="levels"):
        ops.fused_update("adam", *args, impl="jnp", **HYPER)


# -------------------------------------------------------- optimizer wiring
def _params():
    k = jax.random.PRNGKey(0)
    return {"dense": {"w": jax.random.normal(k, (64, 128))},
            "bias": jnp.zeros((10,))}


def _loss(p, target):
    return sum(jnp.sum((a - b) ** 2)
               for a, b in zip(jax.tree_util.tree_leaves(p),
                               jax.tree_util.tree_leaves(target)))


def test_state_bits_containers_and_bytes():
    opt8 = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024,
                          override_32bit=lambda p: False)
    opt4 = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024,
                          override_32bit=lambda p: False, state_bits=(4, 8))
    st8, st4 = opt8.init(_params()), opt4.init(_params())
    assert isinstance(st4.arena.codes_m, PackedCodes)
    assert st4.arena.codes_m.bits == 4
    leaf = unpool_state(st4).leaves["dense"]["w"]
    assert isinstance(leaf, Quant8Leaf)
    assert isinstance(leaf.codes_m, PackedCodes) and leaf.codes_m.bits == 4
    assert not isinstance(leaf.codes_r, PackedCodes)  # 8-bit slot unchanged
    b8 = opt8.state_bytes(st8)
    b4 = opt4.state_bytes(st4)
    assert b8["n_params"] == b4["n_params"]
    # packed m is half the bytes; r and absmax shared
    full4 = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024,
                           override_32bit=lambda p: False, state_bits=4)
    bf4 = full4.state_bytes(full4.init(_params()))
    assert bf4["state_bytes"] <= 0.55 * b8["state_bytes"]


def test_state_bits_config_validation():
    # typed exception (repro.errors): asserts vanish under python -O
    with pytest.raises(ConfigError):
        OptimConfig(algo="adam", state_bits=3)
    assert OptimConfig(algo="adam", state_bits=4).state_bits_pair == (4, 4)
    assert OptimConfig(algo="adam",
                       state_bits=(4, 8)).state_bits_pair == (4, 8)
    cfg = OptimConfig(algo="adam", state_bits=(4, 8), block_size=2048)
    assert cfg.state_bytes_per_param() == pytest.approx(
        0.5 + 1.0 + 2 * 4 / 2048)


def test_min_quantized_size_canonical_name():
    """bitsandbytes-style small-tensor threshold under its canonical name;
    the legacy min_8bit_size keeps working as an alias."""
    opt = make_optimizer("adam8", lr=1e-3, min_quantized_size=32,
                         override_32bit=lambda p: False)
    st = unpool_state(opt.init({"big": jnp.zeros((64,)),
                                "small": jnp.zeros((8,))}))
    assert isinstance(st.leaves["big"], Quant8Leaf)
    assert isinstance(st.leaves["small"], Full32Leaf)
    # canonical name wins over the alias
    assert OptimConfig(min_quantized_size=7, min_8bit_size=9).min_quant_size == 7
    assert OptimConfig(min_8bit_size=9).min_quant_size == 9


@pytest.mark.parametrize("bits", [(4, 8), 6])
def test_kbit_adam_converges(bits):
    params = _params()
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.5, params)
    opt = make_optimizer("adam8", lr=3e-2, min_8bit_size=1024,
                         state_bits=bits)
    st = opt.init(params)
    grad = jax.jit(jax.grad(lambda p: _loss(p, target)))
    p = params
    l0 = float(_loss(p, target))
    for _ in range(100):
        p, st = opt.apply(grad(p), st)
    # 16-level first-moment codes cap the final precision on a synthetic
    # quadratic; a 4x reduction shows the packed update is *optimizing*
    # (the 5%-of-8-bit acceptance runs on the LM smoke task below).
    assert float(_loss(p, target)) < 0.25 * l0


def test_kbit_matches_8bit_on_smoke_train_task():
    """Acceptance: 4-bit(m)/8-bit(r) Adam converges within 5% of the 8-bit
    loss curve on the smoke LM task."""
    from benchmarks.common import small_lm, train_lm
    cfg, pipe = small_lm(vocab=128, d_model=64, seq=32, batch=8)
    l8, _, d8 = train_lm(cfg, pipe, "adam8", steps=25)
    l4, _, d4 = train_lm(cfg, pipe, "adam8", steps=25, state_bits=(4, 8))
    assert not d8 and not d4
    assert abs(l4 - l8) / l8 < 0.05


def test_state_bytes_per_param_metric():
    """train/loop surfaces measured state bytes/param from inside jit."""
    from benchmarks.common import small_lm
    from repro.train import loop as L
    cfg, pipe = small_lm(vocab=128, d_model=64, seq=32, batch=8)
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=1024,
                         state_bits=(4, 8))
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(L.make_train_step(cfg, opt))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    _, m = step(state, batch)
    sb = opt.state_bytes(state.opt_state)
    assert float(m["state_bytes_per_param"]) == pytest.approx(
        sb["state_bytes"] / sb["n_params"], rel=1e-6)


# ------------------------------------------------- checkpoint + sharding
def test_checkpoint_packed_roundtrip_elastic(tmp_path):
    """Packed 4-bit states: save -> elastic restore onto a different mesh
    must be bit-exact, with the packing recorded in the manifest."""
    from repro.train import checkpoint as C
    params = {"w": jnp.ones((64, 64)), "b": jnp.zeros((8,))}
    opt = make_optimizer("adam8", lr=1e-2, min_8bit_size=256,
                         override_32bit=lambda p: False, state_bits=(4, 8))
    st = opt.init(params)
    grad = jax.jit(jax.grad(lambda p: sum(
        jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))))
    p = params
    for _ in range(3):
        p, st = opt.apply(grad(p), st)
    d = str(tmp_path)
    final = C.save(d, 3, st)
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    packed_entries = [e for e in manifest["index"] if "packed" in e]
    assert packed_entries and all(e["packed"]["bits"] == 4
                                  for e in packed_entries)
    # elastic restore onto an explicit (degenerate) mesh placement
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree_util.tree_map(lambda x: sh, st)
    st_b = C.restore(d, 3, jax.eval_shape(lambda s: s, st), shardings)
    assert isinstance(st_b.arena.codes_m, PackedCodes)
    assert isinstance(unpool_state(st_b).leaves["w"].codes_m, PackedCodes)
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(st_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ...and a resumed step is identical to the uninterrupted one
    pa, sta = opt.apply(grad(p), st)
    pb, stb = opt.apply(grad(p), st_b)
    for a, b in zip(jax.tree_util.tree_leaves((pa, sta)),
                    jax.tree_util.tree_leaves((pb, stb))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_packed_bits_mismatch_rejected(tmp_path):
    from repro.train import checkpoint as C
    params = {"w": jnp.ones((64, 64))}
    opt4 = make_optimizer("adam8", lr=1e-2, min_8bit_size=256,
                          override_32bit=lambda p: False, state_bits=(4, 8))
    # 5-bit template has the same absmax/master shapes but different packed
    # widths AND different bits; both must be rejected, not reinterpreted.
    opt5 = make_optimizer("adam8", lr=1e-2, min_8bit_size=256,
                          override_32bit=lambda p: False, state_bits=(5, 8))
    st4 = opt4.init(params)
    d = str(tmp_path)
    C.save(d, 1, st4)
    with pytest.raises(ValueError):
        C.restore(d, 1, jax.eval_shape(lambda: opt5.init(params)))
    # packedness itself must agree: a packed checkpoint cannot load into a
    # plain-8-bit template (and vice versa), even where byte shapes allow
    opt8 = make_optimizer("adam8", lr=1e-2, min_8bit_size=256,
                          override_32bit=lambda p: False)
    with pytest.raises(ValueError, match="packed"):
        C.restore(d, 1, jax.eval_shape(lambda: opt8.init(params)))
    C.save(d, 2, opt8.init(params))
    with pytest.raises(ValueError, match="packed"):
        C.restore(d, 2, jax.eval_shape(lambda: opt4.init(params)))


def test_opt_state_shardings_packed_block_axis():
    """Sharding rules treat packed codes like plain codes: the block-count
    axis is sharded over all mesh axes, the byte axis never is."""
    from repro.sharding import rules
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"w": jnp.zeros((64, 64))}
    opt = make_optimizer("adam8", lr=1e-3, min_8bit_size=256,
                         override_32bit=lambda p: False, state_bits=(4, 8))
    st = opt.init(params)
    abstract = jax.eval_shape(lambda: opt.init(params))
    pshard = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec())}
    shd = rules.opt_state_shardings(abstract, pshard, mesh,
                                    rules.ShardingPolicy())
    codes_shd = shd.arena.codes_m
    assert isinstance(codes_shd, PackedCodes)
    spec = codes_shd.packed.spec
    assert spec[0] == ("data", "model")
    assert len(spec) == 1 or spec[1] is None
    # structure mirrors the state: device_put works leafwise
    st_placed = jax.device_put(st, shd)
    np.testing.assert_array_equal(
        np.asarray(st_placed.arena.codes_m.packed),
        np.asarray(st.arena.codes_m.packed))
