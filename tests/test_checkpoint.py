"""Fault tolerance: atomic checkpointing, bit-exact restart, pruning,
elastic restore (different sharding target)."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.core.optim import make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.train import checkpoint as C
from repro.train import loop as L


@pytest.fixture
def setup(tmp_path):
    cfg = base.reduced(base.get_config("paper-lm-209m"), d_model=32,
                       n_layers=2, vocab_size=64)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=64, seq_len=16,
                                          global_batch=4))
    opt = make_optimizer("adam8", lr=5e-3, min_8bit_size=256)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(L.make_train_step(cfg, opt))
    return cfg, pipe, opt, state, step, str(tmp_path)


def _run(step, pipe, state, lo, hi):
    for i in range(lo, hi):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, _ = step(state, batch)
    return state


def test_restart_equivalence_bit_exact(setup):
    cfg, pipe, opt, state, step, d = setup
    state = _run(step, pipe, state, 0, 5)
    C.save(d, 5, state)
    final_a = _run(step, pipe, state, 5, 9)
    template = jax.eval_shape(lambda s: s, state)
    state_b = C.restore(d, 5, template)
    final_b = _run(step, pipe, state_b, 5, 9)
    for a, b in zip(jax.tree_util.tree_leaves(final_a),
                    jax.tree_util.tree_leaves(final_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_pruning(setup):
    _, _, _, state, _, d = setup
    for s in [1, 2, 3, 4, 5]:
        C.save(d, s, state, keep_last=2)
    assert C.all_steps(d) == [4, 5]
    assert C.latest_step(d) == 5


def test_atomic_no_partial_dirs(setup):
    _, _, _, state, _, d = setup
    C.save(d, 7, state)
    leftovers = [f for f in os.listdir(d) if f.startswith(".tmp_")]
    assert leftovers == []


def test_shape_mismatch_rejected(setup):
    _, _, _, state, _, d = setup
    C.save(d, 1, state)
    bad = jax.eval_shape(
        lambda: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape + (2,), x.dtype)
            if hasattr(x, "shape") and x.ndim > 0 else x,
            state))
    with pytest.raises((ValueError, KeyError)):
        C.restore(d, 1, bad)


@pytest.mark.parametrize("mesh_shape", [(1, 1), (2, 1), (4, 1), (2, 2)])
def test_elastic_restore_new_sharding(setup, mesh_shape):
    """Checkpoints hold full logical arrays -> restoring with different
    device placement ({1,2,4}-device meshes here; 512-dev in the dryrun)
    must be value-identical."""
    _, _, _, state, _, d = setup
    n = mesh_shape[0] * mesh_shape[1]
    if jax.device_count() < n:
        pytest.skip("needs more forced host devices")
    C.save(d, 3, state)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree_util.tree_map(lambda x: sh, state)
    state_b = C.restore(d, 3, jax.eval_shape(lambda s: s, state), shardings)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(state_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_percentile_clipping_state_roundtrip(tmp_path):
    """The gnorm history (OptState.gnorm_vec) is ordinary state: it must
    survive save/restore bit-exactly, and a restored run must continue
    identically to the uninterrupted one."""
    d = str(tmp_path)
    params = {"w": jnp.ones((64, 64)), "b": jnp.zeros((8,))}
    opt = make_optimizer("adam8", lr=1e-2, min_8bit_size=256,
                         percentile_clipping=50, pclip_history=4,
                         override_32bit=lambda p: False)
    st = opt.init(params)
    grad = jax.jit(jax.grad(lambda p: sum(
        jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))))
    p = params
    for _ in range(5):
        p, st = opt.apply(grad(p), st)
    assert float(jnp.min(st.gnorm_vec)) > 0.0
    C.save(d, 5, st)
    st_b = C.restore(d, 5, jax.eval_shape(lambda s: s, st))
    np.testing.assert_array_equal(np.asarray(st.gnorm_vec),
                                  np.asarray(st_b.gnorm_vec))
    pa, sta = opt.apply(grad(p), st)
    pb, stb = opt.apply(grad(p), st_b)
    for a, b in zip(jax.tree_util.tree_leaves((pa, sta)),
                    jax.tree_util.tree_leaves((pb, stb))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_size_reflects_8bit_states(setup, tmp_path):
    """8-bit checkpoints are much smaller than 32-bit-state checkpoints.
    (The full opt_state is saved: under the pooled dispatch the quantized
    codes live in the arena, which `save` slices back per leaf.)"""
    cfg, _, _, state8, _, d = setup
    opt32 = make_optimizer("adam32", lr=5e-3)
    state32, _ = L.init_train_state(cfg, opt32, jax.random.PRNGKey(0))
    p8 = C.save(os.path.join(d, "c8"), 1, state8.opt_state)
    p32 = C.save(os.path.join(d, "c32"), 1, state32.opt_state)
    s8 = os.path.getsize(os.path.join(p8, "leaves.npz"))
    s32 = os.path.getsize(os.path.join(p32, "leaves.npz"))
    assert s8 < s32 * 0.62    # master f32 shared; stats are 8x smaller
