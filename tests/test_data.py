"""Data pipeline determinism + learnability properties."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLMPipeline


def test_deterministic_by_step():
    p1 = SyntheticLMPipeline(DataConfig(vocab_size=100, seq_len=32,
                                        global_batch=4, seed=7))
    p2 = SyntheticLMPipeline(DataConfig(vocab_size=100, seq_len=32,
                                        global_batch=4, seed=7))
    for step in [0, 3, 1000]:
        np.testing.assert_array_equal(p1.batch_at(step)["tokens"],
                                      p2.batch_at(step)["tokens"])


def test_different_steps_differ():
    p = SyntheticLMPipeline(DataConfig(vocab_size=100, seq_len=32,
                                       global_batch=4))
    assert not np.array_equal(p.batch_at(0)["tokens"],
                              p.batch_at(1)["tokens"])


def test_shapes_and_range():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    t = SyntheticLMPipeline(cfg).batch_at(0)["tokens"]
    assert t.shape == (8, 17)          # seq_len + 1 (inputs/labels shift)
    assert t.min() >= 0 and t.max() < 128


def test_bigram_structure_learnable():
    """Transitions follow the chain: successors come from the successor
    table, so entropy is far below uniform."""
    cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=16,
                     branching=4)
    p = SyntheticLMPipeline(cfg)
    t = p.batch_at(0)["tokens"]
    ok = 0
    total = 0
    for row in t:
        for a, b in zip(row[:-1], row[1:]):
            ok += int(b in p.succ[a])
            total += 1
    assert ok == total
    assert p.bigram_entropy() < np.log(64) * 0.6
