"""Shared tiny-train harness for end-to-end tests.

One pipeline/step setup (previously duplicated inside test_system and
needed again by the golden-trajectory and partition end-to-end tests):
build the reduced paper LM on synthetic data, jit one train step, run N
steps, optionally recording per-step metric traces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.train import loop as L


def tiny_cfg(d_model=64, n_layers=2, vocab_size=128):
    return base.reduced(base.get_config("paper-lm-209m"), d_model=d_model,
                        n_layers=n_layers, vocab_size=vocab_size)


def tiny_pipe(vocab_size=128, seq_len=32, global_batch=8):
    return SyntheticLMPipeline(DataConfig(vocab_size=vocab_size,
                                          seq_len=seq_len,
                                          global_batch=global_batch))


def mesh_of(n: int, axis: str = "data"):
    """An ``(n,)`` mesh on the forced host devices, or skip."""
    if jax.device_count() < n:
        pytest.skip(f"needs {n} devices "
                    f"(xla_force_host_platform_device_count)")
    return jax.make_mesh((n,), (axis,))


def tiny_train(opt, steps: int, *, cfg=None, pipe=None, seed=0, trace=()):
    """Init + run ``steps`` jitted train steps.

    Returns ``(state, metrics, traces)`` where ``metrics`` is the last
    step's metric dict and ``traces`` maps each name in ``trace`` to the
    per-step list of float values — the golden-trajectory probes.
    """
    cfg = cfg or tiny_cfg()
    pipe = pipe or tiny_pipe(vocab_size=cfg.vocab_size)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(seed))
    # donated step (DESIGN.md §13c) — the loop below rebinds state
    step = L.jit_train_step(cfg, opt)
    traces = {name: [] for name in trace}
    m = {}
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        for name in trace:
            traces[name].append(float(m[name]))
    return state, m, traces


def assert_trees_equal(a, b, msg=""):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)
