"""Numerics sentinel + flight recorder + run inspector (DESIGN.md §16).

Pins the observability tentpole end to end: the in-graph health counts
(bit-exact updates with the sentinel on, correct slot attribution for
injected NaNs), the host-side anomaly detectors, the flight-recorder
forensic dump (checkpoint-format bundle, bit-exact resume on the step
before the blow-up — pooled AND 4-device ZeRO-1), and the inspector's
exit-code contract over clean / anomalous / malformed artifacts.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import assert_trees_equal, mesh_of, tiny_cfg, tiny_pipe
from repro import telemetry as tel
from repro.core.optim import make_optimizer
from repro.kernels import fused_update as kfu
from repro.telemetry import inspect as insp
from repro.train import loop as L


# ------------------------------------------------------- in-graph health
def _params():
    key = jax.random.PRNGKey(7)
    return {"a": jax.random.normal(key, (3000,)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (64, 48))}


def _opt(**kw):
    return make_optimizer("adam8", lr=1e-2, min_8bit_size=256,
                          override_32bit=lambda p: False, **kw)


def test_sentinel_health_clean_run_and_bit_exact_params():
    """Sentinel on: apply returns (params, state, health); the params and
    state are BIT-EXACT vs sentinel off, and a clean run counts zero in
    every nonfinite/overflow slot."""
    params, grads = _params(), jax.tree_util.tree_map(
        lambda p: p * 0.01, _params())
    p_off, s_off = _opt().apply(grads, _opt().init(params))
    p_on, s_on, health = _opt(sentinel=True).apply(
        grads, _opt(sentinel=True).init(params))
    assert_trees_equal(p_on, p_off)
    assert_trees_equal(s_on.arena, s_off.arena)
    h = np.asarray(jax.device_get(health))
    assert h.shape == (kfu.N_HEALTH,)
    for slot in ("nonfinite_grad", "nonfinite_update", "absmax_overflow_m",
                 "absmax_overflow_r"):
        assert h[kfu.HEALTH_SLOTS.index(slot)] == 0.0, (slot, h)


def test_sentinel_health_counts_injected_nan():
    """A NaN planted in one grad element is counted in nonfinite_grad (and
    poisons its block's update => nonfinite_update fires too)."""
    params = _params()
    grads = jax.tree_util.tree_map(lambda p: p * 0.01, params)
    grads["a"] = grads["a"].at[123].set(jnp.nan)
    opt = _opt(sentinel=True)
    _, _, health = opt.apply(grads, opt.init(params))
    h = np.asarray(jax.device_get(health))
    assert h[kfu.HEALTH_SLOTS.index("nonfinite_grad")] >= 1.0
    assert h[kfu.HEALTH_SLOTS.index("nonfinite_update")] >= 1.0


# ------------------------------------------------------ anomaly detector
def test_detector_nonfinite_loss_is_fatal():
    det = tel.AnomalyDetector()
    evs = det.observe_step(3, {"loss": float("nan"), "grad_norm": 1.0})
    assert [e["reason"] for e in evs] == ["nonfinite_loss"]
    assert evs[0]["severity"] == "fatal" and evs[0]["step"] == 3
    assert tel.validate_event(evs[0]) == []
    assert det.worst_severity() == "fatal"


def test_detector_sentinel_counts_escalate():
    det = tel.AnomalyDetector()
    evs = det.observe_step(1, {"loss": 1.0, "grad_norm": 1.0,
                               "sent_nonfinite_grad": 2.0,
                               "sent_absmax_overflow_m": 1.0})
    reasons = {e["reason"]: e for e in evs}
    assert reasons["sentinel_nonfinite"]["severity"] == "fatal"
    assert reasons["sentinel_nonfinite"]["value"] == 2.0
    assert reasons["absmax_overflow"]["severity"] == "error"
    for ev in evs:
        assert tel.validate_event(ev) == [], ev


def test_detector_loss_spike_vs_trailing_window():
    det = tel.AnomalyDetector(window=5, loss_z=4.0)
    for i in range(5):
        assert det.observe_step(i, {"loss": 1.0 + 0.01 * (i % 2),
                                    "grad_norm": 1.0}) == []
    evs = det.observe_step(5, {"loss": 100.0, "grad_norm": 1.0})
    assert any(e["reason"] == "loss_spike" for e in evs)


def test_detector_zero_variance_loss_window_is_quiet():
    """A perfectly flat loss window must not divide by zero: the z-score
    convention matches StepTimer (0.0 == no evidence)."""
    det = tel.AnomalyDetector(window=5, loss_z=4.0)
    for i in range(5):
        det.observe_step(i, {"loss": 1.0, "grad_norm": 1.0})
    evs = det.observe_step(5, {"loss": 1.0, "grad_norm": 1.0})
    assert evs == []


def test_detector_gnorm_spike_pclip_crosscheck():
    det = tel.AnomalyDetector(window=5, gnorm_factor=10.0)
    for i in range(5):
        det.observe_step(i, {"loss": 1.0, "grad_norm": 1.0})
    # clip engaged (scale < 1): spike was absorbed -> warn
    evs = det.observe_step(5, {"loss": 1.0, "grad_norm": 50.0,
                               "pclip_scale": 0.2})
    spike = [e for e in evs if e["reason"] == "gnorm_spike"]
    assert spike and spike[0]["severity"] == "warn"
    # no clip in play -> error
    det2 = tel.AnomalyDetector(window=5, gnorm_factor=10.0)
    for i in range(5):
        det2.observe_step(i, {"loss": 1.0, "grad_norm": 1.0})
    evs2 = det2.observe_step(5, {"loss": 1.0, "grad_norm": 50.0})
    spike2 = [e for e in evs2 if e["reason"] == "gnorm_spike"]
    assert spike2 and spike2[0]["severity"] == "error"


def test_detector_qhealth_escalation():
    det = tel.AnomalyDetector(qhealth_edge=0.05)
    evs = det.observe_qhealth([
        # healthy segment: block-level saturation is ~1.0 by construction
        # (absmax puts every block max on the top code) and MUST NOT fire;
        # element-level edge fraction ~1/block_size stays below threshold
        {"kind": "qhealth", "step": 2, "target": "arena", "segment": "b",
         "slot": "m", "saturation_fraction": 1.0,
         "edge_code_fraction": 1.0 / 256},
        # clipping segment: element-level edge fraction way over 2x
        {"kind": "qhealth", "step": 2, "target": "arena", "segment": "a",
         "slot": "m", "saturation_fraction": 1.0,
         "edge_code_fraction": 0.5},
        # dynamic-range blow-up precursor: absmax 50x the EMA baseline
        {"kind": "qhealth", "step": 2, "target": "arena", "segment": "c",
         "slot": "r", "edge_code_fraction": 0.0, "absmax_drift": 50.0},
    ])
    assert len(evs) == 2
    assert evs[0]["reason"] == "qhealth_saturation"
    assert evs[0]["severity"] == "error"      # > 2x edge threshold
    assert "edge_code_fraction" in evs[0]["detail"]
    assert evs[1]["severity"] == "warn"
    assert "absmax_drift" in evs[1]["detail"]
    for ev in evs:
        assert tel.validate_event(ev) == []


# --------------------------------------------- anomaly-injection e2e
def _run_to_blowup(opt, tmp_path, tag):
    """Train with an absurd lr until a fatal anomaly fires; dump the
    flight bundle.  Returns (cfg, pipe, blowup step, dump dir, last
    healthy host state, blowup metrics)."""
    cfg = tiny_cfg()
    pipe = tiny_pipe(vocab_size=cfg.vocab_size)
    step_fn = L.jit_train_step(cfg, opt)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    det = tel.AnomalyDetector()
    fr = tel.FlightRecorder(ring=8)
    last_healthy = None
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step_fn(state, batch)
        evs = det.observe_step(i, m)
        for ev in evs:
            fr.note_anomaly(ev)
        fr.record(i, m)
        if any(e["severity"] == "fatal" for e in evs):
            dump = fr.dump(str(tmp_path / f"dump_{tag}"),
                           reason=evs[0]["reason"], trigger_step=i,
                           config=cfg)
            assert fr.snapshot_step == i - 1
            return cfg, pipe, i, dump, last_healthy, m
        fr.snapshot(i, state)
        last_healthy = jax.device_get(state)
    pytest.fail("absurd lr did not produce a fatal anomaly in 40 steps")


def _check_blowup_forensics(opt, tmp_path, tag):
    cfg, pipe, k, dump, last_healthy, m_blow = _run_to_blowup(
        opt, tmp_path, tag)
    # the bundle is self-describing and schema-valid
    manifest = tel.load_dump(dump)
    assert manifest["trigger_step"] == k
    assert manifest["snapshot_step"] == k - 1
    assert manifest["config_hash"] == tel.config_hash(cfg)
    assert [r["step"] for r in manifest["ring"]][-1] == k
    assert manifest["anomalies"], "dump recorded no anomalies"
    for ev in manifest["anomalies"]:
        assert tel.validate_event(ev) == [], ev
    # restore is bit-exact vs the live state on the step before blow-up
    state0, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    template = jax.eval_shape(lambda s: s, state0)
    snap_step, restored = tel.restore_state(dump, template)
    assert snap_step == k - 1
    assert_trees_equal(jax.device_get(restored), last_healthy)
    # ...and replaying the blow-up step reproduces it bit-for-bit
    step_fn = L.jit_train_step(cfg, opt)
    batch = {kk: jnp.asarray(v) for kk, v in pipe.batch_at(k).items()}
    _, m_replay = step_fn(restored, batch)
    np.testing.assert_array_equal(np.asarray(m_replay["loss"]),
                                  np.asarray(m_blow["loss"]))
    # the inspector renders the dump and exits nonzero (anomalies)
    assert insp.main(["--flight", dump]) == insp.EXIT_ANOMALIES


def test_anomaly_injection_e2e_pooled(tmp_path):
    opt = make_optimizer("adam8", lr=1e18, min_8bit_size=256,
                         override_32bit=lambda p: False, sentinel=True)
    _check_blowup_forensics(opt, tmp_path, "pooled")


def test_anomaly_injection_e2e_zero1(tmp_path):
    mesh = mesh_of(4)
    opt = make_optimizer("adam8", lr=1e18, min_8bit_size=256,
                         override_32bit=lambda p: False, sentinel=True,
                         mesh=mesh, partition=True, partition_shards=4)
    _check_blowup_forensics(opt, tmp_path, "zero1")


# --------------------------------------------------------- flight basics
def test_flight_ring_is_bounded_and_scalarized():
    fr = tel.FlightRecorder(ring=3)
    for i in range(10):
        fr.record(i, {"loss": jnp.float32(i), "junk": jnp.zeros((4,))},
                  wall_s=0.1)
    assert [r["step"] for r in fr._ring] == [7, 8, 9]
    assert fr._ring[-1]["loss"] == 9.0
    assert "junk" not in fr._ring[-1]        # non-scalars dropped


def test_flight_dump_without_snapshot(tmp_path):
    fr = tel.FlightRecorder()
    fr.record(0, {"loss": 1.0})
    d = fr.dump(str(tmp_path / "d"), reason="test", trigger_step=0)
    manifest = tel.load_dump(d)
    assert manifest["snapshot_step"] is None
    with pytest.raises(ValueError, match="no state snapshot"):
        tel.restore_state(d, template=None)


def test_flight_jsonl_tail_embedded(tmp_path):
    jl = tmp_path / "telemetry.jsonl"
    rows = [{"kind": "phase", "schema": tel.SCHEMA, "step": i,
             "phase": "step", "wall_s": 0.1} for i in range(5)]
    jl.write_text("".join(json.dumps(r) + "\n" for r in rows))
    fr = tel.FlightRecorder()
    d = fr.dump(str(tmp_path / "d"), reason="t", trigger_step=4,
                telemetry_path=str(jl), tail=3)
    manifest = tel.load_dump(d)
    assert [e["step"] for e in manifest["jsonl_tail"]] == [2, 3, 4]


# ----------------------------------------------------------- inspector
def _write_run(dirpath, events):
    os.makedirs(dirpath, exist_ok=True)
    path = os.path.join(dirpath, "telemetry.jsonl")
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps({"schema": tel.SCHEMA, **ev}) + "\n")
    return dirpath


def _clean_events():
    return [
        {"kind": "metric", "step": 9, "name": "train/loss",
         "type": "gauge", "value": 2.5},
        {"kind": "phase", "step": 1, "phase": "step", "wall_s": 0.2},
        {"kind": "trace", "step": 0,
         "phases": [{"phase": "optimizer_update", "dispatches": 3,
                     "trace_s": 0.01}]},
        {"kind": "qhealth", "step": 5, "target": "arena", "segment": "a",
         "slot": "m", "saturation_fraction": 0.01, "util_hist": [1, 2],
         "util_fraction": 0.5, "absmax_mean": 0.1, "absmax_drift": 1.0},
    ]


def test_inspector_exit_codes(tmp_path):
    clean = _write_run(str(tmp_path / "clean"), _clean_events())
    assert insp.main([clean]) == insp.EXIT_CLEAN

    anom = _write_run(str(tmp_path / "anom"), _clean_events() + [
        {"kind": "anomaly", "step": 7, "reason": "loss_spike",
         "severity": "warn", "value": 9.0}])
    assert insp.main([anom]) == insp.EXIT_ANOMALIES

    bad = _write_run(str(tmp_path / "bad"), [
        {"kind": "anomaly", "step": 7, "reason": "x",
         "severity": "catastrophic", "value": 1.0}])
    assert insp.main([bad]) == insp.EXIT_SCHEMA
    assert insp.main([str(tmp_path / "nonexistent")]) == insp.EXIT_SCHEMA


def test_inspector_validate_subcommand(tmp_path):
    """Satellite: export.validate_jsonl exposed as an exit-coded CLI."""
    clean = _write_run(str(tmp_path / "clean"), _clean_events())
    assert insp.main(["--validate", clean]) == insp.EXIT_CLEAN
    bad = _write_run(str(tmp_path / "bad"),
                     [{"kind": "metric", "step": 0}])
    assert insp.main(["--validate", bad]) == insp.EXIT_SCHEMA


def test_inspector_diff(tmp_path):
    a = _write_run(str(tmp_path / "a"), _clean_events())
    b = _write_run(str(tmp_path / "b"), _clean_events() + [
        {"kind": "anomaly", "step": 3, "reason": "gnorm_spike",
         "severity": "error", "value": 12.0}])
    assert insp.main(["--diff", a, a]) == insp.EXIT_CLEAN
    assert insp.main(["--diff", a, b]) == insp.EXIT_ANOMALIES
