"""Golden-trajectory regression tests.

Fixed-seed 30-step training traces (``loss``, ``pclip_scale``,
``opt_fused_dispatches``) for three optimizer configurations are committed
under ``tests/golden/*.json``.  Each test re-runs the trajectory through
the shared tiny-train harness (tests/helpers.py) and asserts the new trace
matches the committed one within tight tolerance — so kernel/dispatch
refactors cannot silently drift training trajectories, dispatch counts or
the percentile-clipping behaviour.

Regenerating (after an INTENTIONAL numerical change — say why in the
commit message):

    PYTHONPATH=src python -m pytest tests/test_golden.py --regen-golden

which rewrites the JSON files from the current code; commit the diff.
Tolerances: ``opt_fused_dispatches`` must match exactly (it is a
trace-time constant); ``loss``/``pclip_scale`` allow a few f32 ULP of
cross-platform slack (rtol 2e-4) — real drift is orders of magnitude
larger.
"""
import json
import os

import numpy as np
import pytest

from repro.core.optim import make_optimizer

from helpers import tiny_train

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
STEPS = 30
TRACE = ("loss", "pclip_scale", "opt_fused_dispatches")

# name -> make_optimizer kwargs.  percentile clipping is on so the
# pclip_scale metric is exercised; stochastic rounding is on so the
# counter-hash PRNG path is locked too (it is deterministic by design).
GOLDEN_CONFIGS = {
    "adamw8": dict(name="adamw8", lr=5e-3, min_8bit_size=1024,
                   stochastic_rounding=True, percentile_clipping=90,
                   pclip_history=10),
    "muon8": dict(name="muon8", lr=5e-3, min_8bit_size=1024,
                  stochastic_rounding=True, percentile_clipping=90,
                  pclip_history=10),
    "adam8_bits48": dict(name="adam8", lr=5e-3, min_8bit_size=1024,
                         state_bits=(4, 8), stochastic_rounding=True,
                         percentile_clipping=90, pclip_history=10),
}


def _run(cfg_key):
    kw = dict(GOLDEN_CONFIGS[cfg_key])
    name = kw.pop("name")
    opt = make_optimizer(name, **kw)
    _, _, traces = tiny_train(opt, STEPS, trace=TRACE)
    return traces


def _path(cfg_key):
    return os.path.join(GOLDEN_DIR, f"{cfg_key}.json")


@pytest.mark.parametrize("cfg_key", sorted(GOLDEN_CONFIGS))
def test_golden_trajectory(cfg_key, request):
    traces = _run(cfg_key)
    path = _path(cfg_key)
    if request.config.getoption("--regen-golden"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"config": {k: v for k, v in
                                  GOLDEN_CONFIGS[cfg_key].items()},
                       "steps": STEPS, "traces": traces}, f, indent=1)
        pytest.skip(f"regenerated {path}")
    assert os.path.exists(path), \
        f"{path} missing — run with --regen-golden to create it"
    with open(path) as f:
        golden = json.load(f)
    assert golden["steps"] == STEPS
    for name in TRACE:
        want = np.asarray(golden["traces"][name], np.float64)
        got = np.asarray(traces[name], np.float64)
        assert want.shape == got.shape, name
        if name == "opt_fused_dispatches":
            np.testing.assert_array_equal(got, want, err_msg=name)
        else:
            np.testing.assert_allclose(
                got, want, rtol=2e-4, atol=1e-6,
                err_msg=f"{cfg_key}/{name} drifted from the golden "
                        f"trajectory — if intentional, regen with "
                        f"--regen-golden and explain in the commit")


def test_golden_dispatch_counts_document_layout():
    """The committed dispatch counts encode the dispatch architecture:
    adamw8/adam8 pooled = 1 fused launch per step; muon8 = one per matrix
    leaf + 1 pooled arena launch."""
    with open(_path("adamw8")) as f:
        adamw = json.load(f)["traces"]["opt_fused_dispatches"]
    assert set(adamw) == {1.0}
    with open(_path("muon8")) as f:
        muon = json.load(f)["traces"]["opt_fused_dispatches"]
    assert len(set(muon)) == 1 and muon[0] > 1
