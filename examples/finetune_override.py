"""Per-layer 32-bit override example (the paper's GlobalOptimManager
pattern): quantize every state EXCEPT layers you name — here the embedding
(paper §2.3 stable-embedding rule) plus the final norm.

    PYTHONPATH=src python examples/finetune_override.py
"""
import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core.optim import (Quant8Leaf, Full32Leaf, make_optimizer,
                              unpool_state)
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.train import loop as L


def my_override(path: str) -> bool:
    return "embed" in path or "final_norm" in path


def main():
    cfg = base.reduced(base.get_config("granite-3-8b"),
                       d_model=128, n_layers=2, vocab_size=256)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=256, seq_len=32,
                                          global_batch=8))
    opt = make_optimizer("adamw8", lr=3e-3, weight_decay=0.01,
                         override_32bit=my_override)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    # unpool_state gives the per-leaf canonical view regardless of the
    # pooled dispatch (DESIGN.md §10), so the kinds read the same
    kinds = jax.tree_util.tree_map(
        lambda l: type(l).__name__,
        unpool_state(state.opt_state).leaves,
        is_leaf=lambda x: isinstance(x, (Quant8Leaf, Full32Leaf)))
    print("per-leaf state kinds:",
          {k: str(v)[:60] for k, v in kinds.items()})
    step = jax.jit(L.make_train_step(cfg, opt))
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
    print("final loss:", float(m["loss"]))


if __name__ == "__main__":
    main()
