"""Quickstart: the paper's "two-line code change".

Train the same tiny LM twice — once with the 32-bit optimizer, once with
its quantized twin (block-wise dynamic quantization + stable embedding).
Same hyperparameters, same data, same final loss, ~4x less optimizer-state
memory (more with sub-byte states).

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --bits 4   # packed 4-bit
                                                 # first moment, 8-bit second
    PYTHONPATH=src python examples/quickstart.py --algo muon  # quantized
                                  # matrix momentum + Newton-Schulz updates
                                  # on 2-D leaves (DESIGN.md §11)
    PYTHONPATH=src python examples/quickstart.py --no-pooled  # per-leaf
                                  # dispatch (debugging; bit-identical)
    PYTHONPATH=src python examples/quickstart.py --partition 4  # ZeRO-1
                                  # span-partitioned optimizer state: each
                                  # of 4 owners updates only its block
                                  # span (bit-identical; DESIGN.md §12)
    PYTHONPATH=src python examples/quickstart.py --partition 4 \
        --shard-grads --overlap 4  # ZeRO-2 + bucketed overlap: grads
                                  # accumulate owned-span sharded and the
                                  # update fires bucket-by-bucket behind
                                  # the reduce-scatter (bit-identical;
                                  # DESIGN.md §13)

``--algo`` accepts any registered algorithm (adam/adamw/momentum/lamb/
lars/adagrad/muon): the script compares ``<algo>32`` against ``<algo>8``
through the same ``make_optimizer`` entry point.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core.optim import ALGOS, make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.train import loop as L


def run(opt_name: str, steps: int = 80, **opt_kw):
    cfg = base.reduced(base.get_config("paper-lm-209m"),
                       d_model=128, n_layers=2, vocab_size=256)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=256, seq_len=64,
                                          global_batch=8))
    opt = make_optimizer(opt_name, lr=5e-3, **opt_kw)  # <- line 1 (the swap)
    state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = L.jit_train_step(cfg, opt)  # <- line 2 (unchanged API; donates
    #    the state in place and defers the params view — DESIGN.md §13)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
    sb = opt.state_bytes(state.opt_state)
    bytes_ = sb["state_bytes"]
    extra = ""
    if "owned_state_bytes" in sb:
        extra = (f"  (owned/device: {sb['owned_state_bytes'] / 1e6:.2f} MB "
                 f"over {sb['partition_shards']} owners)")
    print(f"{opt_name:8s} final loss {float(m['loss']):.4f}  "
          f"optimizer statistics: {bytes_ / 1e6:.2f} MB{extra}")
    return float(m["loss"]), bytes_


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="adam", choices=sorted(ALGOS),
                    help="algorithm to compare at 32 vs quantized state "
                         "(any registered algo, incl. the muon matrix "
                         "optimizer — DESIGN.md §11)")
    ap.add_argument("--bits", type=int, default=8, choices=[4, 5, 6, 8],
                    help="first-moment storage bitwidth for the quantized "
                         "run (second moment stays 8-bit; DESIGN.md §9)")
    ap.add_argument("--no-pooled", action="store_true",
                    help="per-leaf dispatch instead of the pooled arena "
                         "(one fused launch per leaf instead of one per "
                         "state format; bit-identical — DESIGN.md §10)")
    ap.add_argument("--partition", type=int, default=0, metavar="N",
                    help="ZeRO-1 partition of the pooled arena over N "
                         "owners: each owner updates only its contiguous "
                         "block span (bit-identical to the unpartitioned "
                         "run; on a data-parallel mesh the spans run one "
                         "local fused update per device — DESIGN.md §12)")
    ap.add_argument("--shard-grads", action="store_true",
                    help="ZeRO-2: accumulate grads in the arena's owned-"
                         "span block domain instead of a replicated "
                         "param-shaped pytree (bit-identical; "
                         "DESIGN.md §13)")
    overlap = ap.add_mutually_exclusive_group()
    overlap.add_argument("--overlap", type=int, default=1, metavar="N",
                         help="bucketed overlap: subdivide the partitioned "
                              "arena update into N buckets so each "
                              "bucket's reduce-scatter overlaps the next "
                              "(bit-identical; DESIGN.md §13)")
    overlap.add_argument("--no-overlap", action="store_true",
                         help="force the sequential single-dispatch path "
                              "(the PR-5 oracle)")
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()
    opt_kw = {} if args.bits == 8 else {"state_bits": (args.bits, 8)}
    if args.no_pooled:
        opt_kw["pooled"] = False
    if args.partition:
        if args.no_pooled:
            ap.error("--partition subdivides the pooled arena and cannot "
                     "combine with --no-pooled (DESIGN.md §12)")
        opt_kw.update(partition=True, partition_shards=args.partition)
    if args.shard_grads:
        if args.no_pooled:
            ap.error("--shard-grads accumulates gradients in the pooled "
                     "arena's block domain and cannot combine with "
                     "--no-pooled (DESIGN.md §13)")
        opt_kw["shard_grads"] = True
    if args.overlap > 1 and not args.no_overlap:
        if not args.partition:
            ap.error("--overlap N buckets the span-partitioned update; it "
                     "needs --partition N (DESIGN.md §13)")
        opt_kw["overlap_buckets"] = args.overlap
    l32, b32 = run(f"{args.algo}32", steps=args.steps)
    l8, b8 = run(f"{args.algo}8", steps=args.steps, **opt_kw)
    print(f"\nloss diff: {abs(l8 - l32):.4f}   state memory: {b32 / b8:.1f}x smaller")
