"""Quickstart: the paper's "two-line code change".

Train the same tiny LM twice — once with the 32-bit optimizer, once with
its quantized twin (block-wise dynamic quantization + stable embedding).
Same hyperparameters, same data, same final loss, ~4x less optimizer-state
memory (more with sub-byte states).

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --bits 4   # packed 4-bit
                                                 # first moment, 8-bit second
    PYTHONPATH=src python examples/quickstart.py --algo muon  # quantized
                                  # matrix momentum + Newton-Schulz updates
                                  # on 2-D leaves (DESIGN.md §11)
    PYTHONPATH=src python examples/quickstart.py --no-pooled  # per-leaf
                                  # dispatch (debugging; bit-identical)
    PYTHONPATH=src python examples/quickstart.py --partition 4  # ZeRO-1
                                  # span-partitioned optimizer state: each
                                  # of 4 owners updates only its block
                                  # span (bit-identical; DESIGN.md §12)
    PYTHONPATH=src python examples/quickstart.py --partition 4 \
        --shard-grads --overlap 4  # ZeRO-2 + bucketed overlap: grads
                                  # accumulate owned-span sharded and the
                                  # update fires bucket-by-bucket behind
                                  # the reduce-scatter (bit-identical;
                                  # DESIGN.md §13)

``--algo`` accepts any registered algorithm (adam/adamw/momentum/lamb/
lars/adagrad/muon): the script compares ``<algo>32`` against ``<algo>8``
through the same ``make_optimizer`` entry point.
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core.optim import ALGOS, make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro import telemetry as tel
from repro.telemetry import tracing
from repro.train import loop as L

# Registry gauges surfaced in the final summary table, in display order:
# (metric name, row label, format)
SUMMARY_ROWS = (
    ("train/loss", "final loss", "{:.4f}"),
    ("train/pclip_scale", "pclip scale", "{:.4f}"),
    ("train/state_bytes_per_param", "state bytes/param", "{:.3f}"),
    ("train/opt_owned_state_bytes_per_param", "owned bytes/param (ZeRO-1)",
     "{:.3f}"),
    ("train/opt_fused_dispatches", "fused dispatches/step", "{:.0f}"),
    ("train/steady_ms", "steady ms/step", "{:.1f}"),
)


def run(opt_name: str, steps: int = 80, registry=None, telemetry_dir=None,
        telemetry_every: int = 0, **opt_kw):
    cfg = base.reduced(base.get_config("paper-lm-209m"),
                       d_model=128, n_layers=2, vocab_size=256)
    pipe = SyntheticLMPipeline(DataConfig(vocab_size=256, seq_len=64,
                                          global_batch=8))
    if telemetry_every:
        opt_kw["telemetry_every"] = telemetry_every
    opt = make_optimizer(opt_name, lr=5e-3, **opt_kw)  # <- line 1 (the swap)
    reg = registry if registry is not None else tel.MetricRegistry()
    # Telemetry (DESIGN.md §14): JSONL sink + phase tracing enabled BEFORE
    # the step is traced; without --telemetry-dir the step lowers exactly
    # as before (zero-overhead contract).
    probe = None
    prev_tracing = tracing.phase_tracing_enabled()
    if telemetry_dir:
        reg.add_sink(tel.JsonlSink(
            os.path.join(telemetry_dir, f"{opt_name}.jsonl")))
        tracing.set_phase_tracing(True)
        tracing.reset_trace_events()
        if telemetry_every and getattr(opt, "_qmap1", None) is not None:
            probe = tel.QHealthProbe(opt)
    try:
        state, _ = L.init_train_state(cfg, opt, jax.random.PRNGKey(0))
        step = L.jit_train_step(cfg, opt)  # <- line 2 (unchanged API;
        #    donates the state in place and defers the params view — §13)
        timer = tracing.StepTimer()  # ms/step + compile_s (DESIGN.md §14)
        for i in range(steps):
            with timer.step():
                batch = {k: jnp.asarray(v)
                         for k, v in pipe.batch_at(i).items()}
                state, m = step(state, batch)
            if telemetry_dir:
                if i == 0:   # per-phase dispatch accounting of the compile
                    reg.emit_event(tracing.trace_event_dict(i))
                    tracing.reset_trace_events()
                reg.record_scalars(i, m, prefix="train/")
                reg.emit_event({"kind": "phase", "step": i, "phase": "step",
                                "wall_s": timer.last_dt})
                if probe is not None and (i + 1) % telemetry_every == 0:
                    with tracing.host_phase("qhealth_probe", step=i):
                        for ev in probe.probe(state.opt_state, step=i):
                            reg.emit_event(ev)
                    for ev in tracing.drain_phase_events():
                        reg.emit_event(ev)
    finally:
        tracing.set_phase_tracing(prev_tracing)
    reg.record_scalars(steps - 1, m, prefix="train/")
    reg.gauge("train/steady_ms").set(timer.steady_ms())
    if telemetry_dir:
        reg.flush(step=steps - 1)
    sb = opt.state_bytes(state.opt_state)
    bytes_ = sb["state_bytes"]
    extra = ""
    if "owned_state_bytes" in sb:
        extra = (f"  (owned/device: {sb['owned_state_bytes'] / 1e6:.2f} MB "
                 f"over {sb['partition_shards']} owners)")
    print(f"{opt_name:8s} final loss {float(m['loss']):.4f}  "
          f"optimizer statistics: {bytes_ / 1e6:.2f} MB{extra}")
    return float(m["loss"]), bytes_, reg


def summary_table(runs) -> str:
    """Health-at-a-glance table from the per-run registries: one column
    per run, one row per SUMMARY_ROWS gauge present in any registry."""
    names = [n for n, _ in runs]
    width = max(12, *(len(n) for n in names))
    lines = [" " * 28 + "  ".join(f"{n:>{width}}" for n in names)]
    for key, label, fmt in SUMMARY_ROWS:
        vals = [reg.get(key) for _, reg in runs]
        if all(v is None for v in vals):
            continue
        cells = [fmt.format(v) if v is not None else "-" for v in vals]
        lines.append(f"{label:<28}" + "  ".join(f"{c:>{width}}"
                                                for c in cells))
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="adam", choices=sorted(ALGOS),
                    help="algorithm to compare at 32 vs quantized state "
                         "(any registered algo, incl. the muon matrix "
                         "optimizer — DESIGN.md §11)")
    ap.add_argument("--bits", type=int, default=8, choices=[4, 5, 6, 8],
                    help="first-moment storage bitwidth for the quantized "
                         "run (second moment stays 8-bit; DESIGN.md §9)")
    ap.add_argument("--no-pooled", action="store_true",
                    help="per-leaf dispatch instead of the pooled arena "
                         "(one fused launch per leaf instead of one per "
                         "state format; bit-identical — DESIGN.md §10)")
    ap.add_argument("--partition", type=int, default=0, metavar="N",
                    help="ZeRO-1 partition of the pooled arena over N "
                         "owners: each owner updates only its contiguous "
                         "block span (bit-identical to the unpartitioned "
                         "run; on a data-parallel mesh the spans run one "
                         "local fused update per device — DESIGN.md §12)")
    ap.add_argument("--shard-grads", action="store_true",
                    help="ZeRO-2: accumulate grads in the arena's owned-"
                         "span block domain instead of a replicated "
                         "param-shaped pytree (bit-identical; "
                         "DESIGN.md §13)")
    overlap = ap.add_mutually_exclusive_group()
    overlap.add_argument("--overlap", type=int, default=1, metavar="N",
                         help="bucketed overlap: subdivide the partitioned "
                              "arena update into N buckets so each "
                              "bucket's reduce-scatter overlaps the next "
                              "(bit-identical; DESIGN.md §13)")
    overlap.add_argument("--no-overlap", action="store_true",
                         help="force the sequential single-dispatch path "
                              "(the PR-5 oracle)")
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="emit telemetry JSONL per run (metrics, step "
                         "phases, qhealth probes) into DIR/<run>.jsonl "
                         "(DESIGN.md §14)")
    ap.add_argument("--telemetry-every", type=int, default=0, metavar="N",
                    help="quantization-health probe every N steps "
                         "(0 = off; probes need --telemetry-dir)")
    args = ap.parse_args()
    opt_kw = {} if args.bits == 8 else {"state_bits": (args.bits, 8)}
    if args.no_pooled:
        opt_kw["pooled"] = False
    if args.partition:
        if args.no_pooled:
            ap.error("--partition subdivides the pooled arena and cannot "
                     "combine with --no-pooled (DESIGN.md §12)")
        opt_kw.update(partition=True, partition_shards=args.partition)
    if args.shard_grads:
        if args.no_pooled:
            ap.error("--shard-grads accumulates gradients in the pooled "
                     "arena's block domain and cannot combine with "
                     "--no-pooled (DESIGN.md §13)")
        opt_kw["shard_grads"] = True
    if args.overlap > 1 and not args.no_overlap:
        if not args.partition:
            ap.error("--overlap N buckets the span-partitioned update; it "
                     "needs --partition N (DESIGN.md §13)")
        opt_kw["overlap_buckets"] = args.overlap
    tel_kw = dict(telemetry_dir=args.telemetry_dir,
                  telemetry_every=args.telemetry_every)
    l32, b32, reg32 = run(f"{args.algo}32", steps=args.steps, **tel_kw)
    l8, b8, reg8 = run(f"{args.algo}8", steps=args.steps, **tel_kw,
                       **opt_kw)
    print(f"\nloss diff: {abs(l8 - l32):.4f}   state memory: {b32 / b8:.1f}x smaller")
    print("\n" + summary_table(((f"{args.algo}32", reg32),
                                (f"{args.algo}8", reg8))))
