"""Serving example: continuous batching over the paged 8-bit KV cache.

    PYTHONPATH=src python examples/serve_lm.py

A mixed-length request stream runs through the slot-based scheduler
(DESIGN.md §17): prompts admit as slots free up, KV pages are block-wise
quantized on append, and sampling streams are per-(request, token) so
preemption can never change the generated tokens.  The fixed-bucket
fp16 engine (ServeEngine) remains available for equal-length batches —
see ``repro.launch.serve`` for the A/B CLI.
"""
import numpy as np
import jax

from repro.configs import base
from repro.models import model as M
from repro.serve.kvcache import PagedKVConfig
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   SchedulerConfig)


def main():
    cfg = base.reduced(base.get_config("stablelm-1.6b"),
                       d_model=128, n_layers=2, vocab_size=512)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    requests = [
        Request(rid=i,
                prompt=tuple(rng.randint(0, cfg.vocab_size,
                                         [16, 8, 24, 12][i % 4]).tolist()),
                max_new_tokens=[24, 6, 12, 18][i % 4])
        for i in range(8)
    ]
    engine = ContinuousBatchingEngine(
        cfg, params,
        SchedulerConfig(kv=PagedKVConfig(page_size=8, n_pages=64,
                                         n_slots=4, max_pages_per_seq=8,
                                         kv_bits=8),
                        temperature=0.8, seed=1))
    results = engine.serve(requests)
    for r in requests:
        toks = results[r.rid]
        print(f"request {r.rid}: P={len(r.prompt):2d} "
              f"max_new={r.max_new_tokens:2d} -> {toks.tolist()}")
    print("latency:", engine.latency_percentiles())


if __name__ == "__main__":
    main()
