"""Serving example: batched generation with prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.configs import base
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    cfg = base.reduced(base.get_config("stablelm-1.6b"),
                       d_model=128, n_layers=2, vocab_size=512)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, ServeConfig(max_len=128,
                                                  temperature=0.8, seed=1))
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (4, 16)).astype(np.int32)
    out = engine.generate(prompts, max_new_tokens=24)
    for i, row in enumerate(out):
        print(f"request {i}: prompt={prompts[i][:6]}... -> {row.tolist()}")


if __name__ == "__main__":
    main()
